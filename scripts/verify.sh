#!/usr/bin/env bash
# Repo verification: tier-1 tests + reduced train/serve smokes THROUGH THE
# ENGINE API (the only code path the launchers and examples use).
#
# Each smoke group is an individually invocable target so CI jobs can run
# them in parallel instead of one serial script:
#
#     bash scripts/verify.sh            # everything (the pre-CI default)
#     bash scripts/verify.sh tests      # tier-1 pytest only
#     bash scripts/verify.sh train      # TrainEngine smokes (dp + zero_cdp)
#     bash scripts/verify.sh kernels    # pallas-kernel train smokes
#     bash scripts/verify.sh serve      # ServeEngine smokes (static + CB
#                                       # + paged KV block pool)
#     bash scripts/verify.sh chaos      # resilience: fault-injection suite
#                                       # + a seeded chaos train smoke
#     bash scripts/verify.sh rollout    # RL rollout loop smokes (dp +
#                                       # zero_cdp): reward must rise
#     bash scripts/verify.sh elastic    # elastic membership: kill-at-step-k
#                                       # recover smokes (dp + zero_cdp)
#                                       # + the elastic unit tests
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

run_tests() {
    echo "=== tier-1: pytest ==="
    python -m pytest -x -q
}

run_train() {
    echo "=== engine smoke: 3-step reduced train (TrainEngine) ==="
    python -m repro.launch.train --arch stablelm-1.6b --reduced \
        --steps 3 --batch 2 --seq 16 --mesh-data 2 --mesh-model 1 \
        --host-devices 2 --log-every 1

    echo "=== engine smoke: 3-step ZeRO-CDP reduced train (--plan zero_cdp) ==="
    python -m repro.launch.train --arch stablelm-1.6b --reduced \
        --plan zero_cdp --steps 3 --batch 4 --seq 16 --mesh-data 4 \
        --mesh-model 1 --host-devices 4 --log-every 1
}

run_kernels() {
    echo "=== kernel smoke: 2-step pallas-kernel train, attention arch ==="
    # interpret-mode Pallas on CPU: exercises the fused flash VJP
    # (block-sparse pruned grids) end-to-end through the jitted CDP step
    python -m repro.launch.train --arch stablelm-1.6b --reduced \
        --kernels pallas --steps 2 --batch 2 --seq 16 --mesh-data 1 \
        --mesh-model 1 --host-devices 1 --log-every 1

    echo "=== kernel smoke: 2-step pallas-kernel train, ssm arch ==="
    # exercises the fused gla_scan backward (reverse chunk-scan kernel)
    python -m repro.launch.train --arch xlstm-350m --reduced \
        --kernels ssm_scan=pallas --steps 2 --batch 2 --seq 16 --mesh-data 1 \
        --mesh-model 1 --host-devices 1 --log-every 1
}

run_serve() {
    echo "=== engine smoke: 4-token serve (ServeEngine, fused prefill) ==="
    python -m repro.launch.serve --arch stablelm-1.6b --reduced \
        --batch 2 --prompt-len 16 --gen 4 --mesh-data 2 --mesh-model 1 \
        --host-devices 2

    echo "=== engine smoke: continuous batching (slots + poisson arrivals) ==="
    # iteration-level scheduler: ragged prefill with per-row cache lengths,
    # requests admitted into freed decode slots mid-decode
    python -m repro.launch.serve --arch stablelm-1.6b --reduced \
        --max-slots 4 --arrival poisson --rate 0.5 --num-requests 6 \
        --prompt-len 16 --gen 12 --mesh-data 1 --mesh-model 1 \
        --host-devices 1

    echo "=== engine smoke: paged KV cache (block pool + prefix sharing) ==="
    # paged block-pool serving through the launcher (prints the paging
    # metrics line: peak occupancy, prefix hit rate, preemptions)
    python -m repro.launch.serve --arch stablelm-1.6b --reduced \
        --max-slots 4 --paged --kv-block-size 4 --num-requests 6 \
        --prompt-len 16 --gen 12 --mesh-data 1 --mesh-model 1 \
        --host-devices 1

    # the paged acceptance gates: warm shared-prefix hit rate > 0.9 and
    # peak pool occupancy independent of the engine's max_len headroom
    python -m pytest -x -q tests/test_paged_cache.py \
        -k "warm_hit_rate or peak_occupancy"

    echo "=== engine smoke: wall-clock serving (stream + slo + chunked) ==="
    # ServePolicy surface through the launcher: live token streaming,
    # deadline-aware (slo) admission, and chunked prefill interleaved
    # with decode — the wall-clock serving API end to end
    python -m repro.launch.serve --arch stablelm-1.6b --reduced \
        --max-slots 2 --arrival poisson --rate 0.5 --num-requests 4 \
        --prompt-len 16 --gen 8 --prefill-chunk 5 --clock virtual \
        --stream --policy slo --mesh-data 1 --mesh-model 1 \
        --host-devices 1

    # chunked-prefill bitwise parity + fused host sync acceptance gates
    python -m pytest -x -q tests/test_serving_api.py \
        -k "bitwise_parity or fused_host_transfer"
}

run_chaos() {
    echo "=== chaos: deterministic fault-injection suite ==="
    python -m pytest -x -q tests/test_resilience.py

    echo "=== chaos smoke: guarded train surviving an injected NaN step ==="
    # reduced shapes, fixed seed: the nan_loss fault at step 2 is skipped
    # by the health guard and the run finishes finite
    python -m repro.launch.train --arch stablelm-1.6b --reduced \
        --steps 4 --batch 2 --seq 16 --mesh-data 1 --mesh-model 1 \
        --host-devices 1 --log-every 1 --resilience nan_loss@2 \
        --keep-last 2 --seed 0
}

run_rollout() {
    echo "=== rollout smoke: 2-iteration RL loop, dp plan ==="
    # generate -> score -> train -> push on one device; the launcher exits
    # non-zero unless the mean group reward RISES across iterations
    python -m repro.launch.rollout --arch stablelm-1.6b --reduced \
        --plan dp --iters 2 --groups 2 --group-size 4 \
        --prompt-len 8 --gen 8 --mesh-data 1 --mesh-model 1 \
        --host-devices 1

    echo "=== rollout smoke: 2-iteration RL loop, zero_cdp plan ==="
    # the same loop with stage-sharded f32 masters: the weight push
    # all-gathers inside the compiled cast, under the transfer guard
    python -m repro.launch.rollout --arch stablelm-1.6b --reduced \
        --plan zero_cdp --iters 2 --groups 2 --group-size 4 \
        --prompt-len 8 --gen 8 --mesh-data 2 --mesh-model 1 \
        --host-devices 2
}

run_elastic() {
    echo "=== elastic: unit layer (snapshots, watchdog, re-cut) ==="
    python -m pytest -x -q tests/test_elastic.py \
        -k "not recovery and not watchdog and not rejoin and not falls_back and not shrink_mesh"

    echo "=== elastic smoke: dp rank death at step 3, re-form 2 -> 1 ==="
    # kill rank 1 mid-run; the engine restores the step-2 buddy snapshot,
    # re-forms the mesh on the survivor, and finishes all 6 steps
    python -m repro.launch.train --arch stablelm-1.6b --reduced \
        --steps 6 --batch 4 --seq 16 --mesh-data 2 --mesh-model 1 \
        --host-devices 2 --log-every 1 --elastic --snapshot-every 2 \
        --resilience rank_down@3:1

    echo "=== elastic smoke: zero_cdp rank death, ring re-forms 3 -> 2 ==="
    # the stage-sharded masters are re-cut to the N-1 layout; the re-formed
    # step stays permute-only (asserted by tests/test_elastic.py in CI)
    python -m repro.launch.train --arch stablelm-1.6b --reduced \
        --plan zero_cdp --steps 6 --batch 6 --seq 16 --mesh-data 3 \
        --mesh-model 1 --host-devices 3 --log-every 1 --elastic \
        --snapshot-every 2 --resilience rank_down@3:1
}

target="${1:-all}"
case "$target" in
    tests)   run_tests ;;
    train)   run_train ;;
    kernels) run_kernels ;;
    serve)   run_serve ;;
    chaos)   run_chaos ;;
    rollout) run_rollout ;;
    elastic) run_elastic ;;
    all)     run_tests; run_train; run_kernels; run_serve; run_chaos; run_rollout; run_elastic ;;
    *)
        echo "unknown target '$target' (expected tests|train|kernels|serve|chaos|rollout|elastic|all)" >&2
        exit 2
        ;;
esac

echo "verify.sh[$target]: OK"
