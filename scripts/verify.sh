#!/usr/bin/env bash
# Repo verification: tier-1 tests + a reduced train/serve smoke THROUGH THE
# ENGINE API (the only code path the launchers and examples use).
#
#     bash scripts/verify.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== tier-1: pytest ==="
python -m pytest -x -q

echo "=== engine smoke: 3-step reduced train (TrainEngine) ==="
python -m repro.launch.train --arch stablelm-1.6b --reduced \
    --steps 3 --batch 2 --seq 16 --mesh-data 2 --mesh-model 1 \
    --host-devices 2 --log-every 1

echo "=== engine smoke: 3-step ZeRO-CDP reduced train (--plan zero_cdp) ==="
python -m repro.launch.train --arch stablelm-1.6b --reduced \
    --plan zero_cdp --steps 3 --batch 4 --seq 16 --mesh-data 4 \
    --mesh-model 1 --host-devices 4 --log-every 1

echo "=== kernel smoke: 2-step pallas-kernel train, attention arch ==="
# interpret-mode Pallas on CPU: exercises the fused flash VJP (block-sparse
# pruned grids) end-to-end through the jitted CDP training step
python -m repro.launch.train --arch stablelm-1.6b --reduced \
    --kernels pallas --steps 2 --batch 2 --seq 16 --mesh-data 1 \
    --mesh-model 1 --host-devices 1 --log-every 1

echo "=== kernel smoke: 2-step pallas-kernel train, ssm arch ==="
# exercises the fused gla_scan backward (reverse chunk-scan kernel)
python -m repro.launch.train --arch xlstm-350m --reduced \
    --kernels ssm_scan=pallas --steps 2 --batch 2 --seq 16 --mesh-data 1 \
    --mesh-model 1 --host-devices 1 --log-every 1

echo "=== engine smoke: 4-token serve (ServeEngine, fused prefill) ==="
python -m repro.launch.serve --arch stablelm-1.6b --reduced \
    --batch 2 --prompt-len 16 --gen 4 --mesh-data 2 --mesh-model 1 \
    --host-devices 2

echo "verify.sh: OK"
