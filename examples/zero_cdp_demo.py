"""ZeRO-CDP demo (paper Sec. 4.4) on a REAL model through the plan API:
``--plan zero_cdp`` stage-shards a reduced StableLM's parameters over 4
data ranks and streams them point-to-point around the ring
(collective-permute), while ``--plan dp`` keeps the replicated layout and
merges gradients with an all-reduce burst. Both run through the one
TrainEngine code path; the HLO collective mix of each compiled train step
is printed via ``roofline.parse_collectives`` — ZeRO-CDP moves parameters
with ``collective-permute`` only, no per-stage ``all-gather`` broadcast
and no gradient ``all-reduce`` burst (the transposed ring returns each
stage's gradient to its owner).

    PYTHONPATH=src python examples/zero_cdp_demo.py
"""
from repro.engine import RunSpec

SPEC = RunSpec(arch="stablelm-1.6b", reduced=True,
               mesh_data=4, mesh_model=1, host_devices=4)


def main():
    SPEC.ensure_host_devices()          # before jax initialises devices
    from repro.engine import TrainEngine
    from repro.launch.roofline import parse_collectives

    results = {}
    for plan in ("zero_cdp", "dp"):
        engine = TrainEngine(SPEC, plan=plan, steps=5, batch=8, seq=32,
                             lr_schedule=lambda s: 0.05, donate=False,
                             log_every=1, verbose=False)
        # hlo_text() before run(): ONE compile serves both the collective
        # readout and the training steps (the engine keeps the executable)
        stats = parse_collectives(engine.hlo_text())
        engine.run()
        results[plan] = stats
        losses = [h["loss"] for h in engine.history]
        print(f"{plan:9s}: loss {losses[0]:.3f} -> {losses[-1]:.3f}  "
              f"collectives {stats.op_counts}  "
              f"largest all-reduce {stats.max_by_type['all-reduce']} B")

    cdp, dp = results["zero_cdp"], results["dp"]
    assert cdp.op_counts["collective-permute"] > 0, "stage streaming missing"
    assert cdp.op_counts["all-gather"] == 0, "ZeRO-CDP must not all-gather"
    # dp's gradient merge is an all-reduce burst of full-leaf size; under
    # zero_cdp the only all-reduces left are scalar loss/metric pmeans
    assert dp.max_by_type["all-reduce"] > 100 * cdp.max_by_type["all-reduce"]
    print("zero_cdp streams parameters point-to-point (collective-permute) "
          "with no all-gather broadcast; dp pays the all-reduce burst "
          "(paper Fig. 2d / Table 1).")


if __name__ == "__main__":
    main()
