"""ZeRO-CDP demo (paper Sec. 4.4): parameters stage-sharded over 8 ranks,
streamed point-to-point around the ring (collective-permute) while each rank
runs the cyclic schedule on its own micro-batch — vs baseline ZeRO-DP which
all-gathers every stage. Prints the HLO collective mix for both.

    PYTHONPATH=src python examples/zero_cdp_demo.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh as compat_make_mesh, shard_map as compat_shard_map
from repro.core.zero import roll_stage_params, zero_cdp_apply, zero_dp_apply
from repro.launch.roofline import parse_collectives


def main():
    n, d, b = 8, 64, 4
    mesh = compat_make_mesh((n,), ("data",))
    key = jax.random.PRNGKey(0)
    stages = {"w": 0.1 * jax.random.normal(key, (n, d, d)),
              "b": jnp.zeros((n, d))}
    x = jax.random.normal(jax.random.PRNGKey(1), (n, b, d))

    def stage_fn(p, h):
        return jnp.tanh(h @ p["w"] + p["b"])

    rolled = roll_stage_params(stages, n)
    specs = jax.tree.map(lambda _: P("data"), stages)

    def run_cdp(shard, xs):
        my_params = jax.tree.map(lambda t: t[0], shard)   # drop shard dim
        return zero_cdp_apply(stage_fn, my_params, xs[0], "data", n)[None]

    def run_dp(shard, xs):
        return zero_dp_apply(stage_fn,
                             jax.tree.map(lambda t: t[0], shard),
                             xs[0], "data", n)[None]

    results = {}
    for name, fn in (("zero_cdp", run_cdp), ("zero_dp", run_dp)):
        f = jax.jit(compat_shard_map(fn, mesh=mesh, in_specs=(specs, P("data")),
                                  out_specs=P("data"), axis_names={"data"},
                                  check_vma=False))
        y = f(rolled, x)
        stats = parse_collectives(f.lower(rolled, x).compile().as_text())
        results[name] = y
        print(f"{name}: collectives {stats.op_counts}  "
              f"bytes {stats.total_bytes}  max burst {stats.max_single_op_bytes}")

    np.testing.assert_allclose(np.asarray(results["zero_cdp"]),
                               np.asarray(results["zero_dp"]), rtol=1e-5)
    print("outputs identical; CDP uses point-to-point collective-permute, "
          "DP uses the all-gather broadcast (paper Fig. 2d).")


if __name__ == "__main__":
    main()
