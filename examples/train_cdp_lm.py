"""End-to-end driver: train a ~100M-parameter decoder LM with CDP-v2 on a
(data=2, model=2) mesh of virtual devices, with checkpointing and the sharded
data loader — the full production path at CPU scale.

    PYTHONPATH=src python examples/train_cdp_lm.py --steps 300
"""
import argparse
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.compat import make_mesh as compat_make_mesh
from repro.configs.base import FAMILY_DENSE, ModelConfig
from repro.core.trainer import TrainerConfig, init_state, jit_train_step
from repro.data import ShardedLoader, lm_batch_iterator, make_lm_data
from repro.models import init_params
from repro.models.common import count_params
from repro.optim import cosine_warmup, sgd_momentum

CFG_100M = ModelConfig(
    name="gpt-100m", family=FAMILY_DENSE, num_layers=10, d_model=640,
    num_heads=10, num_kv_heads=10, d_ff=2560, vocab_size=32768,
    dtype="float32", source="examples/train_cdp_lm.py")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--rule", default="cdp_v2")
    ap.add_argument("--ckpt-dir", default="/tmp/cdp_lm_ckpt")
    args = ap.parse_args()

    mesh = compat_make_mesh((2, 2), ("data", "model"))
    cfg = CFG_100M
    params = init_params(cfg, jax.random.PRNGKey(0))
    print(f"params: {count_params(params)/1e6:.1f}M  rule: {args.rule}")

    opt = sgd_momentum(0.9, weight_decay=1e-4)
    trainer = TrainerConfig(
        rule=args.rule,
        lr_schedule=cosine_warmup(0.05, args.steps // 10, args.steps))
    state = init_state(cfg, trainer, params, opt)

    tokens = make_lm_data(cfg.vocab_size, 2_000_000)
    it = lm_batch_iterator(tokens, args.batch, args.seq)
    batch0 = {k: jnp.asarray(v) for k, v in next(it).items()}
    step, _, bsh_fn = jit_train_step(cfg, trainer, mesh, opt, state, batch0)

    start = 0
    if ckpt.latest_step(args.ckpt_dir) is not None:
        state, start = ckpt.restore(args.ckpt_dir, state)
        print(f"resumed from step {start}")

    loader = ShardedLoader(({k: jnp.asarray(v) for k, v in b.items()}
                            for b in it), bsh_fn(batch0))
    t0 = time.time()
    for i in range(start, args.steps):
        state, metrics = step(state, next(loader))
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(metrics['loss']):.4f}  "
                  f"lr {float(metrics['lr']):.4f}  "
                  f"{time.time()-t0:.0f}s", flush=True)
        if (i + 1) % 100 == 0:
            ckpt.save(args.ckpt_dir, i + 1, state)
            print(f"checkpointed step {i+1}")
    loader.close()


if __name__ == "__main__":
    main()
