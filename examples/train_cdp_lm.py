"""End-to-end driver: train a ~100M-parameter decoder LM with CDP-v2 on a
(data=2, model=2) mesh of virtual devices through the TrainEngine — with
checkpointing, resume, and the sharded data loader. A custom ModelConfig
slots straight into RunSpec (``config=`` overrides the arch registry).

    PYTHONPATH=src python examples/train_cdp_lm.py --steps 300
"""
import argparse

from repro.configs.base import FAMILY_DENSE, ModelConfig
from repro.engine import RunSpec

CFG_100M = ModelConfig(
    name="gpt-100m", family=FAMILY_DENSE, num_layers=10, d_model=640,
    num_heads=10, num_kv_heads=10, d_ff=2560, vocab_size=32768,
    dtype="float32", source="examples/train_cdp_lm.py")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--plan", default="cdp_v2",
                    help="parallelism plan (repro.parallel registry)")
    ap.add_argument("--ckpt-dir", default="/tmp/cdp_lm_ckpt")
    args = ap.parse_args()

    spec = RunSpec(config=CFG_100M, mesh_data=2, mesh_model=2,
                   host_devices=4)
    spec.ensure_host_devices()
    from repro.engine import TrainEngine

    engine = TrainEngine(spec, plan=args.plan, steps=args.steps,
                         batch=args.batch, seq=args.seq, lr=0.05,
                         ckpt_dir=args.ckpt_dir, ckpt_every=100,
                         log_every=20, data_tokens=2_000_000)
    engine.run()


if __name__ == "__main__":
    main()
