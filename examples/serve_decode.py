"""Batched serving demo: prefill + KV-cache decode for a reduced config of
any assigned architecture (incl. the SSM/hybrid state-cache paths).

    PYTHONPATH=src python examples/serve_decode.py --arch zamba2-7b
"""
import argparse
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_reduced
from repro.models import decode_step, init_cache, init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-350m", choices=list(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=48)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B = args.batch
    cache = init_cache(cfg, B, 256)
    if cfg.family == "encdec":
        # stub encoder memory (precomputed frame embeddings -> encoder)
        cache["memory"] = 0.01 * jnp.ones_like(cache["memory"])

    step = jax.jit(lambda p, b, c: decode_step(cfg, p, b, c))
    key = jax.random.PRNGKey(1)
    tok = jax.random.randint(key, (B,), 0, cfg.vocab_size)

    logits, cache = step(params, {"token": tok}, cache)   # compile
    t0 = time.time()
    out = []
    for i in range(args.gen):
        key, sub = jax.random.split(key)
        tok = jax.random.categorical(sub, logits.astype(jnp.float32), -1)
        out.append(np.asarray(tok))
        logits, cache = step(params, {"token": tok}, cache)
    dt = time.time() - t0
    print(f"{args.arch}: {args.gen} tokens x batch {B} in {dt:.2f}s "
          f"({B*args.gen/dt:.1f} tok/s on CPU, reduced config)")
    print("sample:", np.stack(out, 1)[0][:16].tolist())


if __name__ == "__main__":
    main()
