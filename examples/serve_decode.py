"""Batched serving demo through the ServeEngine: fused prefill + KV-cache
decode for a reduced config of any assigned architecture (incl. the
SSM/hybrid state-cache paths and the Pallas decode_attn backend).

    PYTHONPATH=src python examples/serve_decode.py --arch zamba2-7b
    PYTHONPATH=src python examples/serve_decode.py --arch stablelm-1.6b \
        --kernels decode_attn=pallas
"""
import argparse

from repro.configs import ARCHS
from repro.engine import RunSpec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-350m", choices=list(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=48)
    ap.add_argument("--kernels", default=None,
                    help="per-op kernel backends, e.g. decode_attn=pallas")
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    spec = RunSpec(arch=args.arch, reduced=True, kernels=args.kernels,
                   mesh_data=2, mesh_model=2, host_devices=4)
    spec.ensure_host_devices()
    from repro.engine import ServeEngine

    engine = ServeEngine(spec, batch=args.batch, prompt_len=args.prompt_len,
                         gen=args.gen, temperature=args.temperature)
    result = engine.generate()
    print("sample:", result["tokens"][0][:16].tolist())


if __name__ == "__main__":
    main()
