"""Quickstart: the engine API in ~30 lines.

Everything runs through two classes sharing one ``RunSpec``:

  * ``RunSpec``     — WHAT to run and WHERE: arch (or explicit ModelConfig),
                      reduced/full, kernel backend registry (per-op
                      "jnp"|"pallas" for train_attn / prefill_attn /
                      decode_attn / ssm_scan), mesh shape, host-device
                      forcing, seed. ``spec.ensure_host_devices()`` must run
                      before jax touches device state.
  * ``TrainEngine`` — build -> jitted CDP step -> log/checkpoint/resume
                      loop. ``engine.run()`` trains; rerunning with the same
                      ckpt_dir resumes deterministically.
  * ``ServeEngine`` — fused prefill (one full-sequence pass fills every
                      layer's decode cache) + batched sampling decode;
                      reports prefill AND decode tok/s.

Here: train a tiny LM with Cyclic Data Parallelism on 4 virtual devices
(2 data-parallel ranks x 2 model shards), comparing the three update rules
from the paper, then serve a few tokens from the same spec.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.engine import RunSpec

SPEC = RunSpec(arch="stablelm-1.6b", reduced=True,
               mesh_data=2, mesh_model=2, host_devices=4,
               # kernel registry: flip any op to its fused Pallas kernel,
               # e.g. kernels="pallas" or kernels="decode_attn=pallas"
               kernels=None)


def main():
    SPEC.ensure_host_devices()          # before jax initialises devices
    from repro.engine import ServeEngine, TrainEngine

    # the parallelism strategy is a one-line plan selection (repro.parallel
    # registry: dp | cdp_v1 | cdp_v2 | cdp_random | zero1_ring | zero_cdp)
    for plan in ("dp", "cdp_v1", "cdp_v2"):
        engine = TrainEngine(SPEC, plan=plan, steps=40, batch=8, seq=64,
                             lr_schedule=lambda s: 0.05, donate=False,
                             log_every=1, verbose=False)
        engine.run()
        losses = [h["loss"] for h in engine.history]
        print(f"{plan:7s}: loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    print("All three plans train — the CDP delay is benign (paper Table 2).")

    serve = ServeEngine(SPEC, batch=4, prompt_len=32, gen=8)
    result = serve.generate()
    print(f"served {result['tokens'].shape} tokens "
          f"(prefill {result['prefill_tok_s']:.0f} tok/s, "
          f"decode {result['decode_tok_s']:.0f} tok/s)")


if __name__ == "__main__":
    main()
