"""Quickstart: train a tiny LM with Cyclic Data Parallelism on 4 virtual
devices (2 data-parallel ranks x 2 model shards), comparing the three update
rules from the paper.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp

from repro.compat import make_mesh as compat_make_mesh
from repro.configs import get_reduced
from repro.core.trainer import TrainerConfig, init_state, jit_train_step
from repro.data import lm_batch_iterator, make_lm_data
from repro.models import init_params
from repro.optim import sgd_momentum


def main():
    mesh = compat_make_mesh((2, 2), ("data", "model"))
    cfg = get_reduced("stablelm-1.6b")
    print(f"model: {cfg.name}, {cfg.num_layers} layers, d={cfg.d_model}")

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = sgd_momentum(momentum=0.9)
    tokens = make_lm_data(cfg.vocab_size, 100_000)
    it = lm_batch_iterator(tokens, batch=8, seq=64)
    batch0 = {k: jnp.asarray(v) for k, v in next(it).items()}

    for rule in ("dp", "cdp_v1", "cdp_v2"):
        trainer = TrainerConfig(rule=rule, lr_schedule=lambda s: 0.1,
                                donate=False)
        state = init_state(cfg, trainer, params, opt)
        step, _, _ = jit_train_step(cfg, trainer, mesh, opt, state, batch0)
        losses = []
        for i in range(40):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
        print(f"{rule:7s}: loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    print("All three rules train — the CDP delay is benign (paper Table 2).")


if __name__ == "__main__":
    main()
