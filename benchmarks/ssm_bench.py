"""ssm_scan micro-benchmark: fwd and fwd+bwd wall-clock for both
``ssm_scan`` backends ("jnp" chunked GLA scan vs the Pallas kernel pair
``ops.gla_scan``), plus a structural check that the Pallas backward is the
fused single-pass reverse chunk-scan.

The ``single_pass_bwd`` field is derived from the traced gradient: the
pallas path must contain exactly two pallas_calls (forward-with-checkpoints
+ reverse scan) and NO ``lax.scan`` — i.e. the backward never recomputes
through the jnp chunked scan. That property is what drops two full
forwards per training step on mLSTM/Mamba2/hybrid architectures.

Writes ``benchmarks/artifacts/ssm_bench.json`` and yields rows in the
``name,us_per_call,derived`` CSV convention of ``benchmarks/run.py``.
Off-TPU the Pallas rows run in interpreter mode (tagged ``"interpret":
true``) — correct but slow; never mistake them for kernel timings.
"""
from __future__ import annotations

import json
import os
import re

import jax
import jax.numpy as jnp

from benchmarks._util import ARTIFACTS, SMOKE, time_us

# B, S, H, dk, dv, chunk — mLSTM/Mamba2-ish training shapes
SHAPES = [
    (1, 256, 2, 32, 32, 64),
] if SMOKE else [
    (1, 2048, 4, 64, 64, 64),
    (2, 1024, 4, 32, 64, 64),
]


def _gla_flops(B, S, H, dk, dv, chunk, *, bwd=False):
    """Matmul MACs of the chunked scan per position: [Q,Q] scores (dk) +
    intra output (dv) + inter readout and state update (2*dk*dv);
    the fused backward re-does the contractions ~3x."""
    f = 2 * B * H * S * (chunk * (dk + dv) + 2 * dk * dv)
    return int(f * 3) if bwd else int(f)


def run():
    from repro.kernels import ops
    from repro.models.ssm import chunked_gla

    interpret = ops.default_interpret()
    records, rows = [], []
    for B, S, H, dk, dv, chunk in SHAPES:
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        q = jax.random.normal(ks[0], (B, S, H, dk), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, H, dk), jnp.float32) * 0.3
        v = jax.random.normal(ks[2], (B, S, H, dv), jnp.float32)
        g = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
        dy = jax.random.normal(ks[4], (B, S, H, dv), jnp.float32)
        tag = f"b{B}s{S}h{H}dk{dk}dv{dv}c{chunk}"

        backends = {
            "jnp": jax.jit(lambda q, k, v, g: chunked_gla(
                q, k, v, g, chunk=chunk)[0]),
            "pallas": jax.jit(lambda q, k, v, g: ops.gla_scan(
                q, k, v, g, chunk=chunk, interpret=interpret)),
        }
        for name, fwd in backends.items():
            fwd_us = time_us(fwd, q, k, v, g)
            loss = lambda q, k, v, g: jnp.sum(fwd(q, k, v, g) * dy)
            grad = jax.jit(jax.grad(loss, argnums=(0, 1, 2, 3)))
            fwdbwd_us = time_us(grad, q, k, v, g)
            rec = {
                "backend": name, "shape": tag,
                "B": B, "S": S, "H": H, "dk": dk, "dv": dv, "chunk": chunk,
                "interpret": bool(name == "pallas" and interpret),
                "fwd_us": round(fwd_us, 1),
                "fwdbwd_us": round(fwdbwd_us, 1),
                "fwd_achieved_gflops": round(
                    _gla_flops(B, S, H, dk, dv, chunk) / fwd_us * 1e-3, 2),
                "fwdbwd_achieved_gflops": round(
                    _gla_flops(B, S, H, dk, dv, chunk, bwd=True)
                    / fwdbwd_us * 1e-3, 2),
            }
            if name == "pallas":
                text = str(jax.make_jaxpr(
                    jax.grad(loss, argnums=(0, 1, 2, 3)))(q, k, v, g))
                n_calls = text.count("pallas_call")
                rec["bwd_pallas_calls"] = n_calls
                rec["single_pass_bwd"] = bool(
                    n_calls == 2 and not re.search(r"\bscan\[", text))
            records.append(rec)
            rows.append((f"ssm.{name}.{tag}.fwd", rec["fwd_us"],
                         f"{rec['fwd_achieved_gflops']}GFLOP/s"))
            rows.append((f"ssm.{name}.{tag}.fwdbwd", rec["fwdbwd_us"],
                         f"{rec['fwdbwd_achieved_gflops']}GFLOP/s"))
        sp = [r for r in records if r["shape"] == tag
              and r["backend"] == "pallas"][0]["single_pass_bwd"]
        rows.append((f"ssm.pallas.{tag}.single_pass_bwd", 0.0, str(sp)))

    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, "ssm_bench.json")
    with open(path, "w") as f:
        json.dump(records, f, indent=1)
    rows.append(("ssm.artifact", 0.0, path))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
