"""Paper Fig. 4: per-worker activation memory of DP vs CDP for N=4/8/32 on
ResNet-50 and ViT-B/16 analytic profiles; reproduces the ~42% (ViT) and ~30%
(ResNet, layer heterogeneity) reductions."""
from __future__ import annotations

import time

from repro.configs.paper_models import resnet50_profile, vit_b16_profile
from repro.core.memory_model import fig4_table


def run():
    rows = []
    for name, prof in (("resnet50", resnet50_profile()),
                       ("vit_b16", vit_b16_profile())):
        t0 = time.time()
        table = fig4_table(prof, ns=(4, 8, 32))
        us = (time.time() - t0) * 1e6
        for n, rep in table.items():
            rows.append((f"fig4.{name}.N{n}.dp_peak_MB", us,
                         round(rep.dp_per_worker_peak / 2**20, 2)))
            rows.append((f"fig4.{name}.N{n}.cdp_peak_MB", us,
                         round(rep.cdp_per_worker_peak / 2**20, 2)))
            rows.append((f"fig4.{name}.N{n}.reduction_pct", us,
                         round(100 * rep.reduction, 1)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
