"""Roofline benchmark: summarize the dry-run grid artifacts (§Roofline terms
per arch x shape), plus measured step timings of reduced configs on CPU."""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

ARTIFACTS = os.path.join(os.path.dirname(__file__), "artifacts")


def _summarize_dryrun():
    rows = []
    path = os.path.join(ARTIFACTS, "dryrun_grid_v3.json")   # final parser
    if not os.path.exists(path):
        path = os.path.join(ARTIFACTS, "dryrun_grid.json")
    if not os.path.exists(path):
        rows.append(("roofline.dryrun_grid", 0.0, "MISSING (run "
                     "`python -m repro.launch.dryrun --all --out "
                     "benchmarks/artifacts/dryrun_grid.json`)"))
        return rows
    with open(path) as f:
        recs = json.load(f)
    ok = [r for r in recs if r.get("ok")]
    rows.append(("roofline.pairs_ok", 0.0, f"{len(ok)}/{len(recs)}"))
    for r in ok:
        rl = r["roofline"]
        tag = f"roofline.{r['arch']}.{r['shape']}.{r['mesh']}"
        rows.append((f"{tag}.bottleneck", 0.0, rl["bottleneck"]))
        rows.append((f"{tag}.compute_ms", 0.0, round(rl["compute_s"] * 1e3, 3)))
        rows.append((f"{tag}.memory_ms", 0.0, round(rl["memory_s"] * 1e3, 3)))
        rows.append((f"{tag}.collective_ms", 0.0,
                     round(rl["collective_s"] * 1e3, 3)))
        rows.append((f"{tag}.useful_flops_ratio", 0.0,
                     round(rl["useful_ratio"], 3)))
    return rows


def _measured_step_time():
    """Wall-clock per train step of a reduced config on CPU (sanity anchor:
    the framework executes, not just lowers)."""
    from repro.configs import get_reduced
    from repro.models import init_params, loss_fn
    from repro.optim import sgd_momentum
    rows = []
    cfg = get_reduced("stablelm-1.6b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = sgd_momentum(0.9)
    state = opt.init(params)
    batch = {"tokens": jnp.zeros((4, 64), jnp.int32),
             "targets": jnp.zeros((4, 64), jnp.int32)}

    @jax.jit
    def step(params, state, batch):
        (l, _), g = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
        p2, s2 = opt.update(g, state, params, 1e-2)
        return p2, s2, l

    params, state, _ = step(params, state, batch)   # compile
    t0 = time.time()
    iters = 5
    for _ in range(iters):
        params, state, l = step(params, state, batch)
    jax.block_until_ready(l)
    us = (time.time() - t0) * 1e6 / iters
    rows.append(("roofline.cpu_reduced_train_step", round(us, 1), "measured"))
    return rows


def run():
    return _summarize_dryrun() + _measured_step_time()


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
