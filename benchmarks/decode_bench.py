"""Decode/serve micro-benchmark: decode tok/s and prefill latency for both
``decode_attn`` backends ("jnp" single-token attention vs the Pallas
flash-decode kernel ``ops.decode_attention``).

Three levels:

  * kernel  — one decode-attention call over a long KV cache (the
    memory-bound hot loop of batched serving), per backend;
  * model   — a reduced-config ``decode_step`` (tok/s) and the fused
    ``prefill_with_cache`` pass (prefill latency) through the registry,
    per backend;
  * serving — continuous batching vs the static-batch baseline on a
    staggered-length Poisson workload through ``ServeEngine.serve``
    (same jitted functions for both policies), recording throughput AND
    p50/p99 request latency;
  * serving_paged — the paged KV-cache engine on a shared-prefix chat
    workload (ONE system prompt x many user turns): a cold serve that
    populates the prefix registry, then a warm serve of fresh user turns
    against the same system prompt. Each record carries the paged schema
    ``{phase, n_requests, n_slots, pool_blocks, block_size,
    blocks_in_use_peak, prefix_hit_rate, prefill_tokens_requested,
    marginal_prefill_tokens, preemptions, decode_tok_s}`` — the warm
    phase is where prefix sharing shows: hit rate ~= system/(system+turn)
    tokens and marginal prefill tokens collapse to roughly the user-turn
    tail, while ``blocks_in_use_peak`` tracks live tokens only (pool
    occupancy is independent of the engine's ``max_len`` headroom).

Writes a JSON artifact to ``benchmarks/artifacts/decode_bench.json`` so the
serving-perf trajectory accumulates across PRs, and yields rows in the
``name,us_per_call,derived`` CSV convention of ``benchmarks/run.py``.

Off-TPU the Pallas rows run in interpreter mode (tagged ``"interpret":
true`` in the artifact) — correct but slow; never mistake them for kernel
timings.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks._util import ARTIFACTS, SMOKE, time_us

# B, T (cache len), H, KV, dh — decode-shaped (one query token)
KERNEL_SHAPES = [
    (4, 256, 8, 2, 64),
] if SMOKE else [
    (4, 1024, 8, 2, 64),
    (16, 512, 8, 8, 64),
]
ITERS = 3 if SMOKE else 10

# serving-level workload: staggered generation lengths (half short, half
# long) — the shape continuous batching wins on (a long row no longer
# holds every slot hostage)
SERVE_REQS, SERVE_SLOTS, SERVE_PROMPT, SERVE_GEN = \
    (6, 2, 8, 16) if SMOKE else (12, 4, 16, 48)

# paged shared-prefix workload: a 120-token system prompt + 8-token user
# turns at block size 8 -> 15 shareable full blocks per prompt, so the
# warm-serve prefix hit rate lands at 120/128 = 0.9375 (> 0.9, the bar
# the serving smoke asserts)
PAGED_SYS, PAGED_TURN, PAGED_BS = 120, 8, 8
PAGED_REQS, PAGED_SLOTS, PAGED_GEN = (4, 2, 8) if SMOKE else (8, 4, 16)


def run():
    from repro.kernels import ops
    from repro.kernels.registry import KernelSpec
    from repro.models import attention as attn

    interpret = ops.default_interpret()
    records, rows = [], []

    # ---- kernel level ----------------------------------------------------
    for B, T, H, KV, dh in KERNEL_SHAPES:
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, 1, H, dh), jnp.float32)
        k = jax.random.normal(ks[1], (B, T, KV, dh), jnp.float32)
        v = jax.random.normal(ks[2], (B, T, KV, dh), jnp.float32)
        cl = jnp.full((B,), T, jnp.int32)
        tag = f"b{B}t{T}h{H}kv{KV}d{dh}"
        backends = {
            "jnp": jax.jit(lambda q, k, v, cl: attn.decode_attention(
                q, k, v, cl, backend="jnp")),
            "pallas": jax.jit(lambda q, k, v, cl: ops.decode_attention(
                q, k, v, cl, interpret=interpret)),
        }
        for name, fn in backends.items():
            us = time_us(fn, q, k, v, cl, iters=ITERS)
            tok_s = B / (us * 1e-6)
            records.append({
                "level": "kernel", "backend": name, "shape": tag,
                "B": B, "T": T, "H": H, "KV": KV, "dh": dh,
                "interpret": bool(name == "pallas" and interpret),
                "us_per_call": round(us, 1),
                "decode_tok_s": round(tok_s, 1),
            })
            rows.append((f"decode.{name}.{tag}", round(us, 1),
                         f"{tok_s:.0f}tok/s"))

    # ---- model level (reduced config through the registry) --------------
    from repro.configs import get_reduced
    from repro.models import (decode_step, init_cache, init_params,
                              prefill_with_cache)
    cfg0 = get_reduced("stablelm-1.6b")
    params = init_params(cfg0, jax.random.PRNGKey(0))
    B, S, GEN = 4, 32, 8
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                 cfg0.vocab_size)
    for name in ("jnp", "pallas"):
        cfg = cfg0.with_(kernels=KernelSpec(decode_attn=name,
                                            prefill_attn="jnp"))
        pre = jax.jit(lambda p, b, c: prefill_with_cache(cfg, p, b, c))
        step = jax.jit(lambda p, b, c: decode_step(cfg, p, b, c))

        cache = init_cache(cfg, B, S + GEN)
        logits, cache = jax.block_until_ready(
            pre(params, {"tokens": prompts}, cache))         # compile
        cache0 = init_cache(cfg, B, S + GEN)
        t0 = time.perf_counter()
        logits, cache = jax.block_until_ready(
            pre(params, {"tokens": prompts}, cache0))
        prefill_us = (time.perf_counter() - t0) * 1e6

        tok = jnp.argmax(logits, -1)
        logits2, cache = step(params, {"token": tok}, cache)  # compile
        jax.block_until_ready(logits2)
        t0 = time.perf_counter()
        for _ in range(GEN):
            logits2, cache = step(params, {"token": tok}, cache)
            tok = jnp.argmax(logits2, -1)
        jax.block_until_ready(logits2)
        dt = time.perf_counter() - t0
        tok_s = B * GEN / dt
        records.append({
            "level": "model", "backend": name, "arch": cfg0.name,
            "B": B, "prompt_len": S, "gen": GEN,
            "interpret": bool(name == "pallas" and interpret),
            "prefill_us": round(prefill_us, 1),
            "decode_tok_s": round(tok_s, 1),
        })
        rows.append((f"decode.model.{name}.prefill", round(prefill_us, 1),
                     f"B{B}xS{S}"))
        rows.append((f"decode.model.{name}.decode",
                     round(dt * 1e6 / GEN, 1), f"{tok_s:.0f}tok/s"))

    # ---- serving level: continuous batching vs static batch --------------
    from repro.engine import RunSpec, ServePolicy
    from repro.engine.batching import synthetic_requests
    from repro.engine.serve import ServeEngine

    spec = RunSpec(arch="stablelm-1.6b", reduced=True, mesh_data=1,
                   mesh_model=1, host_devices=0)
    engine = ServeEngine(spec, batch=SERVE_SLOTS, prompt_len=SERVE_PROMPT,
                         gen=SERVE_GEN, verbose=False)
    # continuous FIRST so any residual process warmth (allocator, CPU
    # caches) biases AGAINST the policy whose win this records; compile is
    # excluded for both — serve() warms its jitted admit/step fns outside
    # the timed loop and both policies share the same executables
    for policy in ("continuous", "static"):
        reqs = synthetic_requests(SERVE_REQS, engine.cfg.vocab_size,
                                  SERVE_PROMPT, SERVE_GEN,
                                  arrival="poisson", rate=1.0, seed=0)
        m = engine.serve(reqs, policy=ServePolicy(
            max_slots=SERVE_SLOTS, policy=policy))["metrics"]
        records.append({
            "level": "serving", "policy": policy, "arch": "stablelm-1.6b",
            "n_requests": m["n_requests"], "n_slots": m["n_slots"],
            "prompt_len": SERVE_PROMPT, "gen": SERVE_GEN, "smoke": SMOKE,
            "total_generated": m["total_generated"],
            "decode_steps": m["decode_steps"],
            "prefill_calls": m["prefill_calls"],
            "admitted_mid_decode": m["admitted_mid_decode"],
            "decode_tok_s": m["decode_tok_s"],
            "p50_latency_s": m["latency_s"]["p50"],
            "p99_latency_s": m["latency_s"]["p99"],
            "p50_latency_steps": m["latency_steps"]["p50"],
            "p99_latency_steps": m["latency_steps"]["p99"],
        })
        rows.append((f"decode.serving.{policy}",
                     round(m["wall_s"] * 1e6, 1),
                     f"{m['decode_tok_s']:.0f}tok/s_p99_"
                     f"{m['latency_s']['p99']}s"))

    # ---- serving level: SLO-aware admission vs FCFS ----------------------
    import numpy as np

    from repro.engine import Request

    # deterministic virtual clock: two doomed requests (deadline shorter
    # than their own decode time) arrive first, feasible short ones queue
    # behind them. FCFS burns both slots on the doomed pair until the
    # doomed deadline expires — by then the tail of the feasible queue is
    # unservable; SLO's feasibility cull never admits the doomed pair.
    # Absolute sizes on purpose: the level measures policy behaviour, not
    # scale, and must separate the policies in smoke AND full runs.
    SLO_DOOMED, SLO_FEASIBLE = 2, 6

    def slo_workload():
        reqs = [Request(rid=i, prompt=list(range(1, SERVE_PROMPT + 1)),
                        max_gen=8, arrival_step=0, deadline_steps=6)
                for i in range(SLO_DOOMED)]
        reqs += [Request(rid=10 + i, prompt=[1, 2, 3, 4], max_gen=3,
                         arrival_step=0, deadline_steps=14)
                 for i in range(SLO_FEASIBLE)]
        return reqs

    slo_goodput = {}
    for admission in ("fcfs", "slo"):
        m = engine.serve(slo_workload(), policy=ServePolicy(
            max_slots=2, clock="virtual",
            admission=admission))["metrics"]
        slo_goodput[admission] = m["goodput"]
        records.append({
            "level": "serving_slo", "admission": admission,
            "arch": "stablelm-1.6b", "smoke": SMOKE,
            "n_requests": m["n_requests"], "n_slots": m["n_slots"],
            "clock": m["clock"], "goodput": m["goodput"],
            "ttft_p50": m["ttft"]["p50"], "ttft_p99": m["ttft"]["p99"],
            "status_counts": m["status_counts"],
        })
        rows.append((f"decode.serving.slo.{admission}",
                     round(m["wall_s"] * 1e6, 1),
                     f"goodput{m['goodput']}_ttft_p99_"
                     f"{m['ttft']['p99']}"))

    # ---- serving level: paged KV cache, shared-prefix chat --------------

    paged = ServeEngine(spec, prompt_len=PAGED_SYS + PAGED_TURN,
                        gen=PAGED_GEN, paged=True, kv_block_size=PAGED_BS,
                        verbose=False)
    rng = np.random.default_rng(0)
    system = rng.integers(0, paged.cfg.vocab_size,
                          PAGED_SYS).astype(np.int32)

    def turns(seed):
        r = np.random.default_rng(seed)
        return [Request(rid=i, arrival_step=0, max_gen=PAGED_GEN,
                        prompt=np.concatenate([system, r.integers(
                            0, paged.cfg.vocab_size,
                            PAGED_TURN).astype(np.int32)]))
                for i in range(PAGED_REQS)]

    # cold serve registers the system prompt's blocks; the warm serve is
    # fresh user turns against the now-cached prefix — the steady state a
    # chat deployment actually runs in
    for phase, seed in (("cold", 1), ("warm", 2)):
        m = paged.serve(turns(seed), policy=ServePolicy(
            max_slots=PAGED_SLOTS))["metrics"]
        pg = m["paging"]
        records.append({
            "level": "serving_paged", "phase": phase,
            "arch": "stablelm-1.6b", "smoke": SMOKE,
            "n_requests": m["n_requests"], "n_slots": m["n_slots"],
            "system_tokens": PAGED_SYS, "turn_tokens": PAGED_TURN,
            "pool_blocks": pg["pool_blocks"],
            "block_size": pg["block_size"],
            "blocks_in_use_peak": pg["blocks_in_use_peak"],
            "prefix_hit_rate": pg["prefix_hit_rate"],
            "prefill_tokens_requested": pg["prefill_tokens_requested"],
            "marginal_prefill_tokens": pg["marginal_prefill_tokens"],
            "preemptions": pg["preemptions"],
            "decode_tok_s": m["decode_tok_s"],
        })
        rows.append((f"decode.serving.paged.{phase}",
                     round(m["wall_s"] * 1e6, 1),
                     f"hit{pg['prefix_hit_rate']}_"
                     f"{pg['marginal_prefill_tokens']}of"
                     f"{pg['prefill_tokens_requested']}tok"))

    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, "decode_bench.json")
    with open(path, "w") as f:
        json.dump(records, f, indent=1)
    rows.append(("decode.artifact", 0.0, path))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
