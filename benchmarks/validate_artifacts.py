"""Schema validator for the benchmark artifacts — the CI benchmark-smoke
gate. No perf numbers are gated (interpret-mode CPU timings are noise);
what IS enforced is that every record a future PR will aggregate or plot
still carries the fields the tooling reads:

  * repo-root ``BENCH_kernels.json`` — the cross-PR kernel-speedup
    trajectory appended by ``benchmarks/run.py`` (commit / when /
    interpret / pallas_speedup_vs_jnp);
  * ``benchmarks/artifacts/decode_bench.json`` — per-level required keys,
    including the serving-level continuous-vs-static throughput + p50/p99
    latency records and the paged shared-prefix records (cold + warm
    phases; pool blocks, peak occupancy, prefix hit rate, marginal
    prefill tokens — with range sanity checks, since a hit rate > 1 or
    occupancy > pool size means the allocator's accounting broke);
  * ``benchmarks/artifacts/rollout_bench.json`` (when present) — the RL
    rollout loop records: per-plan phase timings (all four phases
    present), generation tok/s, and a reward curve that must RISE —
    a flat or falling curve means the policy-gradient step broke;
  * ``benchmarks/artifacts/elastic_bench.json`` (when present) — the
    rank-death recovery records for dp AND zero_cdp: steps lost bounded
    by the snapshot interval, positive recovery wall-clock, finite
    post-recovery loss, and a restore source the engine actually has.

    PYTHONPATH=src python -m benchmarks.validate_artifacts

Exits non-zero listing every violation (never just the first).
"""
from __future__ import annotations

import json
import math
import numbers
import os
import sys

_ART = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts")
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

TRAJECTORY_KEYS = {"commit": str, "when": str, "interpret": bool,
                   "pallas_speedup_vs_jnp": dict}
DECODE_LEVEL_KEYS = {
    "kernel": {"backend": str, "shape": str, "interpret": bool,
               "us_per_call": numbers.Real, "decode_tok_s": numbers.Real},
    "model": {"backend": str, "arch": str, "interpret": bool,
              "prefill_us": numbers.Real, "decode_tok_s": numbers.Real},
    "serving": {"policy": str, "n_requests": int, "n_slots": int,
                "total_generated": int, "decode_steps": int,
                "admitted_mid_decode": int, "decode_tok_s": numbers.Real,
                "p50_latency_s": numbers.Real, "p99_latency_s": numbers.Real,
                "p50_latency_steps": numbers.Real,
                "p99_latency_steps": numbers.Real},
    # paged KV-cache shared-prefix records (cold registry-fill serve +
    # warm reuse serve) — what a future PR plots as the prefix-reuse
    # trajectory, so the memory-accounting keys are all required
    "serving_paged": {"phase": str, "n_requests": int, "n_slots": int,
                      "pool_blocks": int, "block_size": int,
                      "blocks_in_use_peak": int,
                      "prefix_hit_rate": numbers.Real,
                      "prefill_tokens_requested": int,
                      "marginal_prefill_tokens": int, "preemptions": int,
                      "decode_tok_s": numbers.Real},
    # SLO-aware admission vs FCFS on the same virtual-clock workload; the
    # semantic gates below require finite TTFT tails and that slo's
    # goodput is at least fcfs's (the policy's entire reason to exist)
    "serving_slo": {"admission": str, "n_requests": int, "n_slots": int,
                    "clock": str, "goodput": numbers.Real,
                    "ttft_p50": numbers.Real, "ttft_p99": numbers.Real,
                    "status_counts": dict},
}

# RL rollout loop records (``rollout_bench.json``, one per plan). Beyond
# the keys, two SEMANTIC gates: the reward curve must be monotone-capable
# evidence of learning (strictly higher at the end than the start, not
# flat), and the four phase timings must all be present and positive —
# a refactor that silently drops a phase or breaks the policy-gradient
# step fails the benchmark smoke here.
ROLLOUT_KEYS = {"arch": str, "plan": str, "iters": int, "groups": int,
                "group_size": int, "gen_tok_s": numbers.Real,
                "phase_s": dict, "compile_iter_s": numbers.Real,
                "reward_curve": list, "final_loss": numbers.Real}
ROLLOUT_PHASES = ("generate", "score", "train", "push")

# Elastic recovery records (``elastic_bench.json``, one per plan scenario).
# Semantic gates beyond the keys: steps_lost must sit inside
# [0, snapshot_every] (more means the buddy snapshot was not the restore
# point it claims to be), recovery_s must be positive wall-clock, the
# post-recovery final loss must be finite, and both the dp and zero_cdp
# scenarios must be present — a regression that breaks recovery on the
# ring but not on dp still fails here.
ELASTIC_KEYS = {"arch": str, "plan": str, "n_ranks": int, "dead_rank": int,
                "fail_step": int, "recover_step": int, "steps_lost": int,
                "recovery_s": numbers.Real, "snapshot_s_mean": numbers.Real,
                "snapshot_bytes": int, "snapshot_every": int,
                "source": str, "final_loss": numbers.Real}


def _check_keys(rec, schema, where, errors):
    for key, typ in schema.items():
        if key not in rec:
            errors.append(f"{where}: missing key {key!r}")
        elif not isinstance(rec[key], typ):
            errors.append(f"{where}: {key!r} is {type(rec[key]).__name__}, "
                          f"expected {getattr(typ, '__name__', typ)}")


def validate(errors=None):
    errors = [] if errors is None else errors

    traj_path = os.path.join(_ROOT, "BENCH_kernels.json")
    if not os.path.exists(traj_path):
        errors.append(f"missing trajectory {traj_path}")
    else:
        with open(traj_path) as f:
            traj = json.load(f)
        if not isinstance(traj, list) or not traj:
            errors.append("BENCH_kernels.json: expected a non-empty list")
        else:
            for i, rec in enumerate(traj):
                _check_keys(rec, TRAJECTORY_KEYS,
                            f"BENCH_kernels.json[{i}]", errors)
                for op, v in rec.get("pallas_speedup_vs_jnp", {}).items():
                    if not isinstance(v, numbers.Real) or v <= 0:
                        errors.append(f"BENCH_kernels.json[{i}]: speedup "
                                      f"{op}={v!r} is not a positive number")

    dec_path = os.path.join(_ART, "decode_bench.json")
    if not os.path.exists(dec_path):
        errors.append(f"missing artifact {dec_path} (run benchmarks first)")
    else:
        with open(dec_path) as f:
            records = json.load(f)
        levels = {r.get("level") for r in records}
        for need in ("kernel", "model", "serving"):
            if need not in levels:
                errors.append(f"decode_bench.json: no {need!r}-level records")
        for i, rec in enumerate(records):
            schema = DECODE_LEVEL_KEYS.get(rec.get("level"))
            if schema is None:
                errors.append(f"decode_bench.json[{i}]: unknown level "
                              f"{rec.get('level')!r}")
            else:
                _check_keys(rec, schema, f"decode_bench.json[{i}]", errors)
        policies = {r.get("policy") for r in records
                    if r.get("level") == "serving"}
        if policies >= {"continuous", "static"}:
            pass
        elif "serving" in levels:
            errors.append("decode_bench.json: serving records must cover "
                          "both 'continuous' and 'static' policies")
        paged = [r for r in records if r.get("level") == "serving_paged"]
        if paged:
            phases = {r.get("phase") for r in paged}
            if not phases >= {"cold", "warm"}:
                errors.append("decode_bench.json: serving_paged records "
                              "must cover both 'cold' and 'warm' phases")
            for i, rec in enumerate(paged):
                hr = rec.get("prefix_hit_rate")
                if isinstance(hr, numbers.Real) and not 0.0 <= hr <= 1.0:
                    errors.append(f"decode_bench.json serving_paged[{i}]: "
                                  f"prefix_hit_rate {hr!r} outside [0, 1]")
                marg, req = (rec.get("marginal_prefill_tokens"),
                             rec.get("prefill_tokens_requested"))
                if isinstance(marg, int) and isinstance(req, int) \
                        and marg > req:
                    errors.append(f"decode_bench.json serving_paged[{i}]: "
                                  f"marginal prefill {marg} exceeds "
                                  f"requested {req}")
                peak, total = (rec.get("blocks_in_use_peak"),
                               rec.get("pool_blocks"))
                if isinstance(peak, int) and isinstance(total, int) \
                        and peak > total:
                    errors.append(f"decode_bench.json serving_paged[{i}]: "
                                  f"peak occupancy {peak} exceeds pool "
                                  f"size {total}")
        slo = {r.get("admission"): r for r in records
               if r.get("level") == "serving_slo"}
        if slo:
            if not set(slo) >= {"fcfs", "slo"}:
                errors.append("decode_bench.json: serving_slo records "
                              "must cover both 'fcfs' and 'slo' admission")
            for name, rec in slo.items():
                for k in ("ttft_p50", "ttft_p99", "goodput"):
                    v = rec.get(k)
                    if isinstance(v, numbers.Real) and not math.isfinite(v):
                        errors.append(f"decode_bench.json serving_slo"
                                      f"[{name}]: {k} {v!r} not finite")
                g = rec.get("goodput")
                if isinstance(g, numbers.Real) and not 0.0 <= g <= 1.0:
                    errors.append(f"decode_bench.json serving_slo[{name}]: "
                                  f"goodput {g!r} outside [0, 1]")
            gf, gs = (slo.get("fcfs", {}).get("goodput"),
                      slo.get("slo", {}).get("goodput"))
            if isinstance(gf, numbers.Real) and \
                    isinstance(gs, numbers.Real) and gs < gf:
                errors.append(f"decode_bench.json: slo admission goodput "
                              f"{gs} below fcfs {gf} — the deadline-aware "
                              "policy regressed on its own workload")

    roll_path = os.path.join(_ART, "rollout_bench.json")
    if os.path.exists(roll_path):        # conditional: landed with the
        with open(roll_path) as f:       # rollout subsystem, absent before
            rolls = json.load(f)
        if not isinstance(rolls, list) or not rolls:
            errors.append("rollout_bench.json: expected a non-empty list")
            rolls = []
        for i, rec in enumerate(rolls):
            where = f"rollout_bench.json[{i}]"
            _check_keys(rec, ROLLOUT_KEYS, where, errors)
            phases = rec.get("phase_s", {})
            for p in ROLLOUT_PHASES:
                v = phases.get(p)
                if not isinstance(v, numbers.Real) or v < 0:
                    errors.append(f"{where}: phase_s[{p!r}]={v!r} missing "
                                  f"or negative")
            curve = rec.get("reward_curve", [])
            if not all(isinstance(r, numbers.Real) for r in curve):
                errors.append(f"{where}: non-numeric reward_curve {curve!r}")
            elif len(curve) < 2 or curve[-1] <= curve[0]:
                errors.append(f"{where}: reward curve must RISE over the "
                              f"run (plan {rec.get('plan')!r} got {curve!r}"
                              f" — the policy-gradient step is not "
                              f"learning)")
    el_path = os.path.join(_ART, "elastic_bench.json")
    if os.path.exists(el_path):          # conditional: landed with the
        with open(el_path) as f:         # elastic subsystem, absent before
            els = json.load(f)
        if not isinstance(els, list) or not els:
            errors.append("elastic_bench.json: expected a non-empty list")
            els = []
        for i, rec in enumerate(els):
            where = f"elastic_bench.json[{i}]"
            _check_keys(rec, ELASTIC_KEYS, where, errors)
            lost, every = rec.get("steps_lost"), rec.get("snapshot_every")
            if isinstance(lost, int) and isinstance(every, int) \
                    and not 0 <= lost <= every:
                errors.append(f"{where}: steps_lost {lost} outside "
                              f"[0, snapshot_every={every}] — the restore "
                              f"point was not the newest snapshot")
            rs = rec.get("recovery_s")
            if isinstance(rs, numbers.Real) and rs <= 0:
                errors.append(f"{where}: recovery_s {rs!r} must be positive")
            fl = rec.get("final_loss")
            if isinstance(fl, numbers.Real) and not math.isfinite(fl):
                errors.append(f"{where}: post-recovery final_loss {fl!r} "
                              f"is not finite")
            if rec.get("source") not in ("snapshot", "checkpoint"):
                errors.append(f"{where}: source {rec.get('source')!r} is "
                              f"neither 'snapshot' nor 'checkpoint'")
        plans = {r.get("plan") for r in els}
        if els and not plans >= {"dp", "zero_cdp"}:
            errors.append("elastic_bench.json: records must cover both the "
                          f"'dp' and 'zero_cdp' scenarios (got {plans})")
    return errors


def main() -> int:
    errors = validate()
    if errors:
        for e in errors:
            print(f"SCHEMA ERROR: {e}", file=sys.stderr)
        return 1
    extra = "".join(f" + {name}" for name in
                    ("rollout_bench.json", "elastic_bench.json")
                    if os.path.exists(os.path.join(_ART, name)))
    print("benchmark artifact schemas OK "
          f"(BENCH_kernels.json + decode_bench.json{extra})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
