"""Attention micro-benchmark: fwd and fwd+bwd wall-clock + achieved FLOPs
for both attention backends ("jnp" blockwise reference and the Pallas
kernel suite behind ``train_attn="pallas"``), plus the block-sparse
pruning ledger.

Configs cover the two causal training shapes AND a sliding-window sweep
(window in {256, 1024, 4096} at S=8k) where grid pruning matters most.
Every Pallas record carries:

  * ``blocks_visited`` / ``blocks_total`` — tiles the pruned grid walks vs
    the dense (nq x nk) rectangle (from ``flash_grid_plan``), the auditable
    pruning win (causal ~ half, window ~ (window + bq)/S);
  * ``dq_us`` / ``dkv_us`` — the backward split, timed per kernel.

Writes a JSON artifact to ``benchmarks/artifacts/attn_bench.json`` (one
record per backend x config x pass) so the perf trajectory accumulates
attention datapoints across PRs, and yields the same rows in the
``name,us_per_call,derived`` CSV convention of ``benchmarks/run.py``.

Off-TPU the Pallas rows run in interpreter mode (``interpret=True``) —
correct but slow; they are tagged ``"interpret": true`` in the artifact so
trajectory tooling never mistakes them for kernel timings. Interpreter
wall-clock still scales with blocks_visited (each visited tile is one grid
step), so the pruning ratio shows up even in CPU-measured numbers.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from benchmarks._util import ARTIFACTS, time_us

# B, S, H, KV, dh, window — causal self-attention training shapes
CONFIGS = [
    (2, 512, 8, 2, 64, 0),
    (1, 1024, 8, 4, 64, 0),
    # sliding-window sweep at long context: pruning visits ~(window/bk)+2
    # kv blocks per q block instead of the whole lower triangle
    (1, 8192, 1, 1, 64, 256),
    (1, 8192, 1, 1, 64, 1024),
    (1, 8192, 1, 1, 64, 4096),
]
BQ = BK = 128


def _unmasked_frac(S, window):
    """EXACT unmasked fraction of the causal (+ sliding-window) [S, S]
    score matrix: row i attends min(i+1, window) keys. Element-exact — not
    the coarser block-granular visited/total ratio, which counts boundary
    tiles as fully unmasked."""
    w = min(window, S) if window else S
    unmasked = w * (w + 1) // 2 + max(S - w, 0) * w
    return unmasked / (S * S)


def _attn_flops(B, S, H, dh, frac, *, bwd=False):
    """Matmul FLOPs of attention: QK^T and PV are 2*S*S*dh MACs per head;
    ``frac`` is the exact unmasked fraction of the score matrix; the flash
    backward re-does QK^T plus the four gradient matmuls -> 2.5x the
    forward."""
    f = 2 * 2 * B * H * S * S * dh * frac
    return int(f * 2.5) if bwd else int(f)


def _fold(x):
    B, S, H, d = x.shape
    return jnp.moveaxis(x, 2, 1).reshape(B * H, S, d)


def run():
    from repro.kernels import ops
    from repro.kernels.flash_attention import (flash_attention_bwd_dkv,
                                               flash_attention_bwd_dq,
                                               flash_attention_kernel,
                                               flash_grid_plan)
    from repro.models.attention import blockwise_attention

    interpret = ops.default_interpret()
    records = []
    rows = []
    for B, S, H, KV, dh, window in CONFIGS:
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        q = jax.random.normal(ks[0], (B, S, H, dh), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, KV, dh), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, KV, dh), jnp.float32)
        do = jax.random.normal(ks[3], (B, S, H, dh), jnp.float32)
        tag = f"b{B}s{S}h{H}kv{KV}d{dh}" + (f"w{window}" if window else "")

        bq, bk = min(BQ, S), min(BK, S)
        plan = flash_grid_plan(S, S, bq, bk, True, window, 0, S)
        frac = _unmasked_frac(S, window)

        backends = {
            "jnp": jax.jit(lambda q, k, v, w=window: blockwise_attention(
                q, k, v, causal=True, window=w, backend="jnp")),
            "pallas": jax.jit(lambda q, k, v, w=window: ops.flash_attention(
                q, k, v, causal=True, window=w, bq=bq, bk=bk,
                interpret=interpret)),
        }
        for name, fwd in backends.items():
            fwd_us = time_us(fwd, q, k, v)
            grad = jax.jit(jax.grad(
                lambda q, k, v: jnp.sum(fwd(q, k, v) * do),
                argnums=(0, 1, 2)))
            fwdbwd_us = time_us(grad, q, k, v)
            rec = {
                "backend": name, "shape": tag,
                "B": B, "S": S, "H": H, "KV": KV, "dh": dh,
                "causal": True, "window": window, "bq": bq, "bk": bk,
                "interpret": bool(name == "pallas" and interpret),
                "fwd_us": round(fwd_us, 1),
                "fwdbwd_us": round(fwdbwd_us, 1),
                "fwd_achieved_gflops": round(
                    _attn_flops(B, S, H, dh, frac) / fwd_us * 1e-3, 2),
                "fwdbwd_achieved_gflops": round(
                    _attn_flops(B, S, H, dh, frac, bwd=True)
                    / fwdbwd_us * 1e-3, 2),
            }
            if name == "pallas":
                # pruning ledger + per-kernel backward split
                rec["blocks_visited"] = plan["visited"]
                rec["blocks_visited_dkv"] = plan["visited_dkv"]
                rec["blocks_total"] = plan["total"]
                group = H // KV
                qh, kh, vh, doh = _fold(q), _fold(k), _fold(v), _fold(do)
                kw = dict(causal=True, window=window, bq=bq, bk=bk,
                          group=group, sk_valid=S, interpret=interpret)
                fwd_k = jax.jit(lambda qh, kh, vh: flash_attention_kernel(
                    qh, kh, vh, **kw))
                out, lse = fwd_k(qh, kh, vh)
                delta = jnp.sum(doh * out, axis=-1)
                dq_us = time_us(jax.jit(
                    lambda *a: flash_attention_bwd_dq(*a, **kw)),
                    qh, kh, vh, doh, lse, delta)
                dkv_us = time_us(jax.jit(
                    lambda *a: flash_attention_bwd_dkv(*a, **kw)),
                    qh, kh, vh, doh, lse, delta)
                rec["dq_us"] = round(dq_us, 1)
                rec["dkv_us"] = round(dkv_us, 1)
                rows.append((f"attn.pallas.{tag}.bwd_dq", rec["dq_us"],
                             f"{plan['visited']}/{plan['total']}blocks"))
                rows.append((f"attn.pallas.{tag}.bwd_dkv", rec["dkv_us"],
                             f"{plan['visited_dkv']}/{plan['total']}blocks"))
            records.append(rec)
            rows.append((f"attn.{name}.{tag}.fwd", rec["fwd_us"],
                         f"{rec['fwd_achieved_gflops']}GFLOP/s"))
            rows.append((f"attn.{name}.{tag}.fwdbwd", rec["fwdbwd_us"],
                         f"{rec['fwdbwd_achieved_gflops']}GFLOP/s"))

    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, "attn_bench.json")
    with open(path, "w") as f:
        json.dump(records, f, indent=1)
    rows.append(("attn.artifact", 0.0, path))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
