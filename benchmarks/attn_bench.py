"""Attention micro-benchmark: fwd and fwd+bwd wall-clock + achieved FLOPs
for both attention backends ("jnp" blockwise reference and the Pallas
kernel pair behind ``attn_backend="pallas"``).

Writes a JSON artifact to ``benchmarks/artifacts/attn_bench.json`` (one
record per backend x shape x pass) so the perf trajectory accumulates
attention datapoints across PRs, and yields the same rows in the
``name,us_per_call,derived`` CSV convention of ``benchmarks/run.py``.

Off-TPU the Pallas rows run in interpreter mode (``interpret=True``) —
correct but slow; they are tagged ``"interpret": true`` in the artifact so
trajectory tooling never mistakes them for kernel timings.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

ARTIFACTS = os.path.join(os.path.dirname(__file__), "artifacts")

# B, S, H, KV, dh — two training-ish shapes (causal self-attention)
SHAPES = [
    (2, 512, 8, 2, 64),
    (1, 1024, 8, 4, 64),
]
ITERS = 5


def _attn_flops(B, S, H, dh, *, causal=True, bwd=False):
    """Matmul FLOPs of attention: QK^T and PV are 2*S*S*dh MACs per head;
    causal halves the useful area; the flash backward re-does QK^T plus the
    three gradient matmuls (dP, dV, dQ, dK) -> 2.5x the forward."""
    f = 2 * 2 * B * H * S * S * dh
    if causal:
        f //= 2
    return int(f * 2.5) if bwd else f


def _time(fn, *args):
    out = fn(*args)                                    # compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(ITERS):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) * 1e6 / ITERS    # us/call


def run():
    from repro.kernels import ops
    from repro.models.attention import blockwise_attention

    interpret = ops.default_interpret()
    records = []
    rows = []
    for B, S, H, KV, dh in SHAPES:
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        q = jax.random.normal(ks[0], (B, S, H, dh), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, KV, dh), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, KV, dh), jnp.float32)
        do = jax.random.normal(ks[3], (B, S, H, dh), jnp.float32)
        shape_tag = f"b{B}s{S}h{H}kv{KV}d{dh}"

        backends = {
            "jnp": jax.jit(lambda q, k, v: blockwise_attention(
                q, k, v, causal=True, backend="jnp")),
            "pallas": jax.jit(lambda q, k, v: ops.flash_attention(
                q, k, v, causal=True, interpret=interpret)),
        }
        for name, fwd in backends.items():
            fwd_us = _time(fwd, q, k, v)
            grad = jax.jit(jax.grad(
                lambda q, k, v: jnp.sum(fwd(q, k, v) * do),
                argnums=(0, 1, 2)))
            fwdbwd_us = _time(grad, q, k, v)
            fwd_gflops = _attn_flops(B, S, H, dh) / fwd_us * 1e-3
            fwdbwd_gflops = (_attn_flops(B, S, H, dh, bwd=True)
                             / fwdbwd_us * 1e-3)
            records.append({
                "backend": name, "shape": shape_tag,
                "B": B, "S": S, "H": H, "KV": KV, "dh": dh,
                "interpret": bool(name == "pallas" and interpret),
                "fwd_us": round(fwd_us, 1),
                "fwdbwd_us": round(fwdbwd_us, 1),
                "fwd_achieved_gflops": round(fwd_gflops, 2),
                "fwdbwd_achieved_gflops": round(fwdbwd_gflops, 2),
            })
            rows.append((f"attn.{name}.{shape_tag}.fwd", round(fwd_us, 1),
                         f"{fwd_gflops:.2f}GFLOP/s"))
            rows.append((f"attn.{name}.{shape_tag}.fwdbwd",
                         round(fwdbwd_us, 1),
                         f"{fwdbwd_gflops:.2f}GFLOP/s"))

    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, "attn_bench.json")
    with open(path, "w") as f:
        json.dump(records, f, indent=1)
    rows.append(("attn.artifact", 0.0, path))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
