"""Paper Table 2 analogue: test accuracy of DP vs CDP-v1 vs CDP-v2.

The paper trains ResNet-18/50 on CIFAR-10/ImageNet with the delays
*simulated* (Sec. 5). CPU-scale reproduction: a conv-ish MLP classifier on a
Gaussian-cluster dataset (CIFAR-10-like optimisation character), trained with
the exact three update rules via repro.core.delay_sim, SGD momentum 0.9 — the
paper's claim is that the three rules reach the same accuracy.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.delay_sim import init_sim_state, make_sim_step
from repro.core.schedule import RULES
from repro.data.synthetic import make_classification_data
from repro.optim import sgd_momentum, step_drops

N_STAGES = 4


def init_mlp(key, dims=(64, 128, 128, 128, 10)):
    ks = jax.random.split(key, len(dims) - 1)
    return {f"layer{i}": {
        "w": jax.random.normal(ks[i], (dims[i], dims[i + 1])) /
             np.sqrt(dims[i]),
        "b": jnp.zeros((dims[i + 1],))}
        for i in range(len(dims) - 1)}


def stage_ids_for(params, n):
    L = len(params)
    return {k: jax.tree.map(lambda _: jnp.int32(min(n - 1, i * n // L)),
                            params[k])
            for i, k in enumerate(sorted(params))}


def apply_mlp(params, x):
    ks = sorted(params)
    for k in ks[:-1]:
        x = jax.nn.relu(x @ params[k]["w"] + params[k]["b"])
    k = ks[-1]
    return x @ params[k]["w"] + params[k]["b"]


def loss_fn(params, mb):
    x, y = mb
    logits = apply_mlp(params, x)
    return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(y.shape[0]), y])


def accuracy(params, x, y):
    pred = jnp.argmax(apply_mlp(params, x), -1)
    return float(jnp.mean((pred == y).astype(jnp.float32)))


def run(steps: int = 400, seed: int = 0):
    # one dataset (one set of class clusters), split train/test
    x, y = make_classification_data(5120, dim=64, classes=10, seed=seed)
    xtr, ytr = jnp.asarray(x[:4096]), jnp.asarray(y[:4096])
    xte, yte = jnp.asarray(x[4096:]), jnp.asarray(y[4096:])
    rng = np.random.default_rng(seed)
    rows = []
    for rule in RULES:
        t0 = time.time()
        params = init_mlp(jax.random.PRNGKey(seed))
        ids = stage_ids_for(params, N_STAGES)
        opt = sgd_momentum(0.9, weight_decay=5e-4)
        lr = step_drops(0.05, [int(steps * 0.6), int(steps * 0.85)], 0.2)
        step = make_sim_step(loss_fn, ids, rule, N_STAGES, opt, lr)
        state = init_sim_state(params, rule, opt)
        bsz = 32 * N_STAGES
        for t in range(steps):
            idx = rng.integers(0, xtr.shape[0], bsz)
            mb = (xtr[idx].reshape(N_STAGES, 32, -1),
                  ytr[idx].reshape(N_STAGES, 32))
            state, _ = step(state, mb)
        acc = accuracy(state["params"], xte, yte)
        us = (time.time() - t0) * 1e6 / steps
        rows.append((f"table2.{rule}.test_acc", us, round(acc, 4)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
