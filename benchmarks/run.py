"""Benchmark runner — one module per paper table/figure + roofline summary.

Prints ``name,us_per_call,derived`` CSV (one line per metric).

    PYTHONPATH=src python -m benchmarks.run [--only table2,fig4] [--steps N]

After the modules run, the kernel-vs-jnp speedup ratios measured by the
attn/ssm/decode benches are aggregated into the repo-root
``BENCH_kernels.json`` trajectory (one record per run, keyed by git
commit) so the kernel-perf trend is auditable across PRs. Interpret-mode
(off-TPU) records are tagged — their ratios measure the Pallas
*interpreter*, not the kernels.
"""
from __future__ import annotations

import argparse
import datetime
import json
import math
import os
import subprocess
import sys
import traceback

_ART = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts")
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TRAJECTORY = os.path.join(_ROOT, "BENCH_kernels.json")


def _load_artifact(name):
    path = os.path.join(_ART, name)
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def _geomean(xs):
    xs = [x for x in xs if x and x > 0]
    if not xs:
        return None
    return round(math.exp(sum(math.log(x) for x in xs) / len(xs)), 3)


def _pair_ratios(records, us_key, match_keys=("shape",)):
    """jnp_us / pallas_us per matching config (>1 means the kernel wins)."""
    by = {}
    for r in records:
        if us_key in r:
            by[(tuple(r.get(k) for k in match_keys), r["backend"])] = r
    ratios, interpret = [], False
    for (cfg, backend), r in by.items():
        if backend != "pallas":
            continue
        j = by.get((cfg, "jnp"))
        if j and r.get(us_key):
            ratios.append(j[us_key] / r[us_key])
            interpret |= bool(r.get("interpret"))
    return ratios, interpret


def update_trajectory(ran):
    """Append this run's kernel-vs-jnp speedups to BENCH_kernels.json.

    ``ran``: the bench modules that completed THIS invocation — only their
    artifacts are aggregated, so a stale file from an older commit (or from
    a module that just failed) is never recorded under the current one."""
    attn = _load_artifact("attn_bench.json") if "attn" in ran else []
    ssm = _load_artifact("ssm_bench.json") if "ssm" in ran else []
    decode = [r for r in _load_artifact("decode_bench.json")
              if r.get("level") == "kernel"] if "decode" in ran else []
    speedup, interpret = {}, False
    for key, recs, us_key in (
            ("train_attn_fwd", attn, "fwd_us"),
            ("train_attn_fwdbwd", attn, "fwdbwd_us"),
            ("ssm_scan_fwd", ssm, "fwd_us"),
            ("ssm_scan_fwdbwd", ssm, "fwdbwd_us"),
            ("decode_attn", decode, "us_per_call")):
        ratios, interp = _pair_ratios(recs, us_key)
        gm = _geomean(ratios)
        if gm is not None:
            speedup[key] = gm
            interpret |= interp
    if not speedup:
        return None
    blocks = {r["shape"]: f"{r['blocks_visited']}/{r['blocks_total']}"
              for r in attn if "blocks_visited" in r}
    try:
        commit = subprocess.check_output(
            ["git", "rev-parse", "--short", "HEAD"], cwd=_ROOT,
            stderr=subprocess.DEVNULL).decode().strip()
        status = subprocess.check_output(
            ["git", "status", "--porcelain"], cwd=_ROOT,
            stderr=subprocess.DEVNULL).decode()
        # the benches rewrite their own tracked artifacts every run — only
        # OTHER modifications mean the measured code differs from HEAD
        dirty = [ln for ln in status.splitlines()
                 if not ln[3:].startswith(("benchmarks/artifacts/",
                                           "BENCH_kernels.json"))]
        if dirty:
            commit += "+"        # measured on an uncommitted working tree
    except Exception:
        commit = "unknown"
    record = {
        "commit": commit,
        "when": datetime.datetime.now().isoformat(timespec="seconds"),
        "interpret": interpret,
        "pallas_speedup_vs_jnp": speedup,
        "blocks_visited_over_total": blocks,
    }
    trajectory = []
    if os.path.exists(TRAJECTORY):
        with open(TRAJECTORY) as f:
            trajectory = json.load(f)
    trajectory.append(record)
    with open(TRAJECTORY, "w") as f:
        json.dump(trajectory, f, indent=1)
    return record


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: table1,table2,fig3,fig4,roofline,attn,"
                         "decode,ssm,rollout,elastic")
    args = ap.parse_args(argv)

    from benchmarks import (attn_bench, decode_bench, elastic_bench,
                            fig3_loss, fig4_memory, roofline_bench,
                            rollout_bench, ssm_bench, table1_comm,
                            table2_convergence)
    mods = {"table1": table1_comm, "table2": table2_convergence,
            "fig3": fig3_loss, "fig4": fig4_memory,
            "roofline": roofline_bench, "attn": attn_bench,
            "decode": decode_bench, "ssm": ssm_bench,
            "rollout": rollout_bench, "elastic": elastic_bench}
    only = args.only.split(",") if args.only else list(mods)

    print("name,us_per_call,derived")
    failed = []
    for name in only:
        try:
            for row in mods[name].run():
                print(",".join(str(x) for x in row), flush=True)
        except Exception as e:
            failed.append(name)
            traceback.print_exc()
            print(f"{name}.ERROR,0,{type(e).__name__}")
    ran = {"attn", "ssm", "decode"} & (set(only) - set(failed))
    if ran:
        try:
            rec = update_trajectory(ran)
            if rec:
                print(f"trajectory.BENCH_kernels,0.0,{TRAJECTORY}")
        except Exception:
            traceback.print_exc()
            print("trajectory.ERROR,0,")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
