"""Benchmark runner — one module per paper table/figure + roofline summary.

Prints ``name,us_per_call,derived`` CSV (one line per metric).

    PYTHONPATH=src python -m benchmarks.run [--only table2,fig4] [--steps N]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: table1,table2,fig3,fig4,roofline,attn,"
                         "decode")
    args = ap.parse_args(argv)

    from benchmarks import (attn_bench, decode_bench, fig3_loss, fig4_memory,
                            roofline_bench, table1_comm, table2_convergence)
    mods = {"table1": table1_comm, "table2": table2_convergence,
            "fig3": fig3_loss, "fig4": fig4_memory,
            "roofline": roofline_bench, "attn": attn_bench,
            "decode": decode_bench}
    only = args.only.split(",") if args.only else list(mods)

    print("name,us_per_call,derived")
    failed = []
    for name in only:
        try:
            for row in mods[name].run():
                print(",".join(str(x) for x in row), flush=True)
        except Exception as e:
            failed.append(name)
            traceback.print_exc()
            print(f"{name}.ERROR,0,{type(e).__name__}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
