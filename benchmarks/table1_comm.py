"""Paper Table 1: theoretical memory / communication costs of DP vs CDP
across the four implementation settings, instantiated with the measured
parameter/activation sizes of a real config, plus the schedule-level
communication balance (comm events per tick)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import schedule as S
from repro.configs.paper_models import (resnet50_param_bytes,
                                        resnet50_profile)


def run():
    rows = []
    t0 = time.time()
    prof = resnet50_profile()
    Pa = float(sum(a for (_, a, _) in prof))          # activations, 1 sample
    Pp = float(resnet50_param_bytes())
    n, B = 8, 32
    t = S.table1(n, B, Pp, Pa, Pa * 0.02)
    for name, r in t.items():
        rows.append((f"table1.{name}.act_mem_MB", r["act_mem"] / 2**20))
        rows.append((f"table1.{name}.gpus", r["gpus"]))
    # communication balance: events per tick for CDP vs one burst for DP
    ev = S.comm_events(n)
    per_tick = {}
    for e in ev:
        per_tick[e["tau"]] = per_tick.get(e["tau"], 0) + 1
    rows.append(("table1.cdp_p2p_sends_per_tick_max", max(per_tick.values())))
    rows.append(("table1.cdp_p2p_sends_per_tick_min", min(per_tick.values())))
    rows.append(("table1.dp_burst_msgs_at_step_end", n))
    dt = (time.time() - t0) * 1e6
    return [(name, dt / max(len(rows), 1), val) for name, val in rows]


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
