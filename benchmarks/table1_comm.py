"""Paper Table 1: theoretical memory / communication costs of DP vs CDP
across the four implementation settings, instantiated with the measured
parameter/activation sizes of a real config, plus the schedule-level
communication balance (comm events per tick).

Also records the *measured* HLO collective mix per parallel plan: each
registered strategy's reduced-model train step is compiled on a 4-rank
host mesh (in a subprocess so the benchmark runner keeps its single
device) and ``roofline.parse_collectives`` reads the collective op counts
and bytes off the optimized HLO — the communication signature Table 1
predicts (all-reduce burst for dp, collective-permute chains for the ring
plans, permute-only streaming with zero all-gathers for zero_cdp).
Artifact: ``benchmarks/artifacts/table1_comm.json``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

from repro.core import schedule as S
from repro.configs.paper_models import (resnet50_param_bytes,
                                        resnet50_profile)

ARTIFACTS = os.path.join(os.path.dirname(__file__), "artifacts")

MEASURED_PLANS = ("dp", "cdp_v1", "cdp_v2", "zero1_ring", "zero_cdp")

_MEASURE_SNIPPET = """
import json
from repro.engine import RunSpec, TrainEngine
from repro.launch.roofline import parse_collectives

out = {}
for plan in %r:
    spec = RunSpec(arch="stablelm-1.6b", reduced=True, plan=plan,
                   mesh_data=4, mesh_model=1)
    engine = TrainEngine(spec, steps=1, batch=8, seq=32, verbose=False)
    engine.build()
    stats = parse_collectives(engine.hlo_text())
    out[plan] = {"op_counts": stats.op_counts,
                 "total_bytes": int(stats.total_bytes),
                 "max_single_op_bytes": int(stats.max_single_op_bytes),
                 "max_grad_merge_bytes": int(stats.max_grad_merge_bytes())}
    engine.close()
print("MEASURED " + json.dumps(out))
"""


def measure_plan_collectives(plans=MEASURED_PLANS, timeout=1200):
    """Compile one reduced train step per plan in a 4-host-device
    subprocess; returns {plan: collective stats dict}."""
    env = dict(os.environ)
    flag = "--xla_force_host_platform_device_count=4"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + flag).strip()
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run(
        [sys.executable, "-c", _MEASURE_SNIPPET % (tuple(plans),)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if res.returncode != 0:
        raise RuntimeError(f"plan measurement subprocess failed:\n"
                           f"{res.stdout}\n{res.stderr}")
    for line in res.stdout.splitlines():
        if line.startswith("MEASURED "):
            return json.loads(line[len("MEASURED "):])
    raise RuntimeError(f"no MEASURED line in output:\n{res.stdout}")


def run():
    rows = []
    t0 = time.time()
    prof = resnet50_profile()
    Pa = float(sum(a for (_, a, _) in prof))          # activations, 1 sample
    Pp = float(resnet50_param_bytes())
    n, B = 8, 32
    t = S.table1(n, B, Pp, Pa, Pa * 0.02)
    for name, r in t.items():
        rows.append((f"table1.{name}.act_mem_MB", r["act_mem"] / 2**20))
        rows.append((f"table1.{name}.gpus", r["gpus"]))
    # communication balance: events per tick for CDP vs one burst for DP
    ev = S.comm_events(n)
    per_tick = {}
    for e in ev:
        per_tick[e["tau"]] = per_tick.get(e["tau"], 0) + 1
    rows.append(("table1.cdp_p2p_sends_per_tick_max", max(per_tick.values())))
    rows.append(("table1.cdp_p2p_sends_per_tick_min", min(per_tick.values())))
    rows.append(("table1.dp_burst_msgs_at_step_end", n))
    # stamp the schedule-math rows with their own (microsecond-scale)
    # timing BEFORE the compile subprocess below; measured rows carry the
    # subprocess wall-clock amortised over the plans they cover
    dt = (time.time() - t0) * 1e6
    out = [(name, dt / max(len(rows), 1), val) for name, val in rows]

    # measured HLO collective mix per parallel plan (reduced model, 4 ranks)
    t1 = time.time()
    measured = measure_plan_collectives()
    us_per_plan = (time.time() - t1) * 1e6 / max(len(measured), 1)
    for plan, st in measured.items():
        for op, count in st["op_counts"].items():
            if count:
                out.append((f"table1.measured.{plan}."
                            f"{op.replace('-', '_')}_count",
                            us_per_plan, count))
        out.append((f"table1.measured.{plan}.collective_bytes",
                    us_per_plan, st["total_bytes"]))
        out.append((f"table1.measured.{plan}.max_grad_merge_bytes",
                    us_per_plan, st["max_grad_merge_bytes"]))

    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, "table1_comm.json")
    with open(path, "w") as f:
        json.dump({"mesh": {"data": 4, "model": 1},
                   "arch": "stablelm-1.6b-reduced",
                   "plans": measured}, f, indent=2)
    out.append(("table1.artifact", 0.0, path))
    return out


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
