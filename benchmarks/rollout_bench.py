"""RL rollout loop benchmark: phase timings, generation throughput, and
the reward curve for ``RolloutEngine`` (generate -> score -> train -> push
on one device).

What the artifact captures per plan (``dp`` always; ``zero_cdp`` when the
process has >= 2 devices):

  * ``phase_s`` — mean seconds per phase over the WARM iterations (the
    first iteration compiles everything and is reported separately as
    ``compile_iter_s``); the generate/train split is the time-sharing
    story, the push entry is the device-side weight hand-off;
  * ``gen_tok_s`` — sampled tokens per second through the paged serve
    engine during the generate phase (warm mean);
  * ``reward_curve`` — mean group reward per iteration on the steerable
    synthetic task. The curve RISING is the subsystem's correctness
    signal and ``validate_artifacts`` gates on it, so a perf refactor
    that silently breaks the policy-gradient step fails the benchmark
    smoke, not just the test suite.

Writes ``benchmarks/artifacts/rollout_bench.json`` and yields rows in the
``name,us_per_call,derived`` CSV convention of ``benchmarks/run.py``.
"""
from __future__ import annotations

import json
import os

import jax

from benchmarks._util import ARTIFACTS, SMOKE

ARCH = "stablelm-1.6b"
ITERS = 3 if SMOKE else 5
GROUPS, GROUP_SIZE = (2, 4) if SMOKE else (4, 4)
PROMPT_LEN, GEN = (8, 8) if SMOKE else (8, 16)


def _one_plan(plan: str, mesh_data: int):
    from repro.engine import RolloutEngine, RunSpec

    spec = RunSpec(arch=ARCH, reduced=True, plan=plan,
                   mesh_data=mesh_data, mesh_model=1)
    eng = RolloutEngine(spec, plan=plan, groups=GROUPS,
                        group_size=GROUP_SIZE, prompt_len=PROMPT_LEN,
                        gen=GEN, iters=ITERS, verbose=False)
    hist = eng.run()
    warm = hist[1:] if len(hist) > 1 else hist
    phases = ("generate", "score", "train", "push")
    phase_s = {p: sum(h["phase_s"][p] for h in warm) / len(warm)
               for p in phases}
    return {
        "arch": ARCH,
        "plan": plan,
        "reduced": True,
        "iters": len(hist),
        "groups": GROUPS,
        "group_size": GROUP_SIZE,
        "prompt_len": PROMPT_LEN,
        "gen": GEN,
        "gen_tok_s": round(sum(h["gen_tok_s"] for h in warm) / len(warm), 2),
        "phase_s": {k: round(v, 4) for k, v in phase_s.items()},
        "compile_iter_s": round(sum(hist[0]["phase_s"].values()), 4),
        "reward_curve": [round(h["mean_reward"], 4) for h in hist],
        "final_loss": round(hist[-1]["loss"], 6),
    }


def run():
    records = [_one_plan("dp", mesh_data=1)]
    if jax.device_count() >= 2:
        records.append(_one_plan("zero_cdp", mesh_data=2))

    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, "rollout_bench.json")
    with open(path, "w") as f:
        json.dump(records, f, indent=1)

    rows = []
    for rec in records:
        total = sum(rec["phase_s"].values())
        rows.append((f"rollout.{rec['plan']}.iter", round(total * 1e6, 1),
                     f"{rec['gen_tok_s']}tok_s"))
        rows.append((f"rollout.{rec['plan']}.reward", 0.0,
                     "->".join(str(r) for r in rec["reward_curve"])))
    rows.append(("rollout.artifact", 0.0, path))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
