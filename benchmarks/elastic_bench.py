"""Elastic recovery benchmark: what a rank death actually costs.

One run per plan (``dp`` on a 2-rank mesh, ``zero_cdp`` on a 3-rank
ring), each in a forced-host-device subprocess (like ``table1_comm``'s
plan measurement, so the runner keeps its single device): inject
``rank_down@k``, let the engine re-form the ring on the survivors from
the buddy snapshot, and record the price —

  * ``recovery_s``      — wall-clock of the shrink (restore point + mesh
    rebuild + state re-cut + re-jit + stream fast-forward);
  * ``steps_lost``      — work discarded (failed step - snapshot step),
    bounded by ``snapshot_every``;
  * ``snapshot_s_mean`` / ``snapshot_bytes`` — the steady-state overhead
    paid per snapshot interval for that recovery to exist;
  * ``source``          — where the restore point came from (``snapshot``
    unless the store was unusable and disk served).

Writes ``benchmarks/artifacts/elastic_bench.json`` and yields rows in
the ``name,us_per_call,derived`` CSV convention of ``benchmarks/run.py``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from benchmarks._util import ARTIFACTS, SMOKE

ARCH = "stablelm-1.6b"
STEPS = 6 if SMOKE else 10
FAIL_STEP = 3 if SMOKE else 5
SNAPSHOT_EVERY = 2

# (plan, n_ranks, dead_rank, global_batch) — batch divides both N and N-1
SCENARIOS = (("dp", 2, 1, 4), ("zero_cdp", 3, 1, 6))

_MEASURE_SNIPPET = """
import json
from repro.engine import RunSpec, TrainEngine

plan, n, dead, batch, steps, every, spec_str = {scenario!r}
spec = RunSpec(arch={arch!r}, reduced=True, plan=plan, mesh_data=n,
               mesh_model=1)
eng = TrainEngine(spec, steps=steps, batch=batch, seq=16, log_every=1,
                  elastic=True, snapshot_every=every,
                  resilience=spec_str, verbose=False)
eng.run()
rec = eng.recoveries[0]
snaps = eng.events.of("snapshot")
out = {{
    "plan": plan,
    "n_ranks": n,
    "dead_rank": dead,
    "fail_step": rec["failed_at"],
    "recover_step": rec["step"],
    "steps_lost": rec["steps_lost"],
    "recovery_s": round(rec["duration_s"], 4),
    "snapshot_s_mean": round(sum(s["dur_s"] for s in snaps)
                             / max(len(snaps), 1), 4),
    "snapshot_bytes": max(s["bytes"] for s in snaps),
    "snapshot_every": every,
    "source": rec["source"],
    "final_loss": round(eng.history[-1]["loss"], 6),
}}
print("ELASTIC " + json.dumps(out))
"""


def _one_scenario(plan, n, dead, batch, timeout=1200):
    env = dict(os.environ)
    flag = f"--xla_force_host_platform_device_count={n}"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + flag).strip()
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    snippet = _MEASURE_SNIPPET.format(
        scenario=(plan, n, dead, batch, STEPS, SNAPSHOT_EVERY,
                  f"rank_down@{FAIL_STEP}:{dead}"),
        arch=ARCH)
    res = subprocess.run([sys.executable, "-c", snippet],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    if res.returncode != 0:
        raise RuntimeError(f"elastic scenario {plan}@{n} failed:\n"
                           f"{res.stdout}\n{res.stderr}")
    for line in res.stdout.splitlines():
        if line.startswith("ELASTIC "):
            rec = json.loads(line[len("ELASTIC "):])
            rec["arch"] = ARCH
            rec["reduced"] = True
            return rec
    raise RuntimeError(f"no ELASTIC line in output:\n{res.stdout}")


def run():
    records, rows = [], []
    for plan, n, dead, batch in SCENARIOS:
        t0 = time.time()
        rec = _one_scenario(plan, n, dead, batch)
        us = (time.time() - t0) * 1e6
        records.append(rec)
        rows.append((f"elastic.{plan}.recovery_s", us, rec["recovery_s"]))
        rows.append((f"elastic.{plan}.steps_lost", 0.0, rec["steps_lost"]))
        rows.append((f"elastic.{plan}.snapshot_s", 0.0,
                     rec["snapshot_s_mean"]))
        rows.append((f"elastic.{plan}.snapshot_MB", 0.0,
                     round(rec["snapshot_bytes"] / 2**20, 2)))

    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, "elastic_bench.json")
    with open(path, "w") as f:
        json.dump(records, f, indent=1)
    rows.append(("elastic.artifact", 0.0, path))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
