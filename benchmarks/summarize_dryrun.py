"""Convert dryrun_grid.json records into the EXPERIMENTS.md markdown tables.

    PYTHONPATH=src python -m benchmarks.summarize_dryrun \
        benchmarks/artifacts/dryrun_grid.json
"""
from __future__ import annotations

import json
import sys

GIB = 2**30


def fmt_table(recs):
    lines = [
        "| arch | shape | mesh | compute | memory | collective | bottleneck "
        "| peak/dev (corr.) | useful FLOPs | max burst |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"FAIL: {r.get('error','?')[:60]} |" + " |" * 6)
            continue
        rl = r["roofline"]
        bpd = r["bytes_per_device"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rl['compute_s']*1e3:.1f} ms | {rl['memory_s']*1e3:.1f} ms "
            f"| {rl['collective_s']*1e3:.1f} ms | {rl['bottleneck']} "
            f"| {bpd['peak_est']/GIB:.1f} ({bpd.get('peak_tpu_corrected', bpd['peak_est'])/GIB:.1f}) GiB "
            f"| {rl['useful_ratio']*100:.0f}% "
            f"| {rl['coll_max_burst']/2**20:.0f} MiB |")
    return "\n".join(lines)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else \
        "benchmarks/artifacts/dryrun_grid.json"
    with open(path) as f:
        recs = json.load(f)
    ok = sum(1 for r in recs if r.get("ok"))
    print(f"## Dry-run grid: {ok}/{len(recs)} pairs lower + compile\n")
    print(fmt_table(recs))
    # bottleneck histogram
    from collections import Counter
    c = Counter(r["roofline"]["bottleneck"] for r in recs if r.get("ok"))
    print(f"\nbottlenecks: {dict(c)}")


if __name__ == "__main__":
    main()
