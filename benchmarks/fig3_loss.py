"""Paper Fig. 3 analogue: training-loss trajectories of the three rules on a
small LM — the delay must not change the optimisation path materially, with
CDP-v1 slightly behind early (larger delay) and all rules converging."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core.delay_sim import init_sim_state, make_sim_step
from repro.core.schedule import RULES
from repro.data import lm_batch_iterator, make_lm_data
from repro.models import init_params, loss_fn as model_loss
from repro.models.model import param_stage_ids
from repro.optim import sgd_momentum

N_STAGES = 4


def run(steps: int = 250, seed: int = 0):
    cfg = get_reduced("stablelm-1.6b").with_(vocab_size=256)
    params0 = init_params(cfg, jax.random.PRNGKey(seed))
    toks = make_lm_data(cfg.vocab_size, 100_000, seed=seed)
    rows = []
    curves = {}
    for rule in RULES:
        t0 = time.time()
        it = lm_batch_iterator(toks, 2 * N_STAGES, 32, seed=seed)
        ids = param_stage_ids(cfg, params0, N_STAGES)
        opt = sgd_momentum(0.9)
        step = make_sim_step(lambda p, mb: model_loss(cfg, p, mb)[0], ids,
                             rule, N_STAGES, opt, lambda s: 0.05)
        state = init_sim_state(params0, rule, opt)
        losses = []
        for t in range(steps):
            hb = next(it)
            mb = {k: jnp.asarray(v).reshape(N_STAGES, 2, 32)
                  for k, v in hb.items()}
            state, loss = step(state, mb)
            losses.append(float(loss))
        curves[rule] = losses
        us = (time.time() - t0) * 1e6 / steps
        rows.append((f"fig3.{rule}.loss_first10", us,
                     round(float(np.mean(losses[:10])), 4)))
        rows.append((f"fig3.{rule}.loss_last10", us,
                     round(float(np.mean(losses[-10:])), 4)))
    # paper claim: final losses agree across rules
    finals = [np.mean(curves[r][-10:]) for r in RULES]
    rows.append(("fig3.max_final_loss_gap", 0.0,
                 round(float(max(finals) - min(finals)), 4)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
