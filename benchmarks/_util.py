"""Shared benchmark plumbing: the compile-then-average timing loop and the
artifact directory, so every bench module measures the same way (a change
here — warmup, donation — moves all of them in lockstep, keeping the
cross-bench ratios in BENCH_kernels.json comparable)."""
from __future__ import annotations

import os
import time

import jax

ARTIFACTS = os.path.join(os.path.dirname(__file__), "artifacts")

# BENCH_SMOKE=1 (CI benchmark-smoke job): every module shrinks its shapes /
# iteration counts so the whole suite runs in minutes on a CPU runner. The
# artifacts keep their schema (that IS what the job validates) but the
# numbers are smoke-tagged, never perf-gated.
SMOKE = os.environ.get("BENCH_SMOKE", "") not in ("", "0")


def time_us(fn, *args, iters: int = 3):
    """us/call of ``fn(*args)``: one untimed call to compile, then the mean
    of ``iters`` blocked calls."""
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) * 1e6 / iters
