"""Delay-simulator semantics (paper Sec. 5 protocol) on a quadratic model:
the three update rules must match hand-rolled reference iterations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.delay_sim import init_sim_state, make_sim_step
from repro.core.schedule import RULE_CDP_V1, RULE_CDP_V2, RULE_DP
from repro.optim import sgd_momentum


def quad_loss(params, mb):
    # per-microbatch quadratic: 0.5 * ||w - mb||^2 summed over stage blocks
    return sum(0.5 * jnp.sum((params[k] - mb) ** 2) for k in params)


def setup(n=4):
    params = {"s0": jnp.ones((3,)), "s1": 2.0 * jnp.ones((3,))}
    stage_ids = {"s0": jnp.int32(0), "s1": jnp.int32(n - 1)}
    return params, stage_ids


def run(rule, steps=5, n=4, lr=0.1):
    params, stage_ids = setup(n)
    opt = sgd_momentum(0.0)
    step = make_sim_step(quad_loss, stage_ids, rule, n, opt, lambda s: lr)
    state = init_sim_state(params, rule, opt)
    data = jnp.zeros((steps, n))     # micro-batch targets all zero
    traj = []
    for t in range(steps):
        state, loss = step(state, data[t])
        traj.append({k: np.asarray(v) for k, v in state["params"].items()})
    return traj


def test_dp_equals_plain_gd():
    lr, steps = 0.1, 5
    traj = run(RULE_DP, steps=steps, lr=lr)
    w = np.array([1.0, 1.0, 1.0])
    for t in range(steps):
        w = w - lr * w              # grad of 0.5||w||^2 = w, same each mb
        np.testing.assert_allclose(traj[t]["s0"], w, rtol=1e-6)


def test_cdp_v1_is_one_step_delayed_gd():
    lr, steps = 0.1, 6
    traj = run(RULE_CDP_V1, steps=steps, lr=lr)
    # w_{t+1} = w_t - lr * grad(w_{t-1}) with w_{-1} = w_0
    w_prev = np.ones(3)
    w = np.ones(3)
    for t in range(steps):
        w, w_prev = w - lr * w_prev, w
        np.testing.assert_allclose(traj[t]["s0"], w, rtol=1e-6)


def test_cdp_v2_mixes_stages():
    """Stage 0 (threshold n-1-i) is fresh only for the last micro-batch; the
    last stage is fresh for every micro-batch."""
    lr, n, steps = 0.1, 4, 4
    traj = run(RULE_CDP_V2, steps=steps, n=n, lr=lr)
    # stage n-1: all micro-batches fresh -> plain GD on s1
    w = 2.0 * np.ones(3)
    for t in range(steps):
        w = w - lr * w
        np.testing.assert_allclose(traj[t]["s1"], w, rtol=1e-6)
    # stage 0: (n-1)/n of micro-batches use the stale params
    w_prev = np.ones(3)
    w = np.ones(3)
    for t in range(steps):
        g = ((n - 1) * w_prev + 1 * w) / n
        w, w_prev = w - lr * g, w
        np.testing.assert_allclose(traj[t]["s0"], w, rtol=1e-6)


@pytest.mark.parametrize("rule", [RULE_DP, RULE_CDP_V1, RULE_CDP_V2])
def test_all_rules_converge_on_quadratic(rule):
    traj = run(rule, steps=60, lr=0.3)
    assert np.abs(traj[-1]["s0"]).max() < 1e-3
    assert np.abs(traj[-1]["s1"]).max() < 1e-3
