"""Engine API: RunSpec resolution, TrainEngine checkpoint/resume equality,
ServeEngine fused prefill vs the old launcher's teacher-forcing decode path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.engine import RunSpec, ServeEngine, TrainEngine
from repro.kernels.registry import KernelSpec
from repro.models import decode_step, init_cache, init_params

SPEC = RunSpec(arch="stablelm-1.6b", reduced=True, mesh_data=1, mesh_model=1)


# ---------------------------------------------------------------------------
# RunSpec / kernel registry resolution
# ---------------------------------------------------------------------------

def test_runspec_resolves_arch_and_kernels():
    cfg = SPEC.resolve_config()
    assert cfg.name == "stablelm-1.6b-reduced"
    cfg = SPEC.with_(kernels="decode_attn=pallas").resolve_config()
    assert cfg.kernels == KernelSpec(decode_attn="pallas")
    cfg = SPEC.with_(kernels="pallas").resolve_config()
    assert cfg.kernels == KernelSpec.all("pallas")


def test_runspec_attn_backend_alias_populates_registry():
    from repro.kernels import registry
    with pytest.warns(DeprecationWarning):
        cfg = SPEC.with_(attn_backend="pallas").resolve_config()
    spec = registry.resolve(cfg)
    assert spec.train_attn == "pallas" and spec.prefill_attn == "pallas"
    assert spec.decode_attn == "jnp" and spec.ssm_scan == "jnp"
    # an explicitly named op wins over the alias; ops the --kernels value
    # did not name are still filled from the alias (never silently dropped)
    with pytest.warns(DeprecationWarning):
        cfg = SPEC.with_(attn_backend="pallas",
                         kernels="train_attn=jnp").resolve_config()
    spec = registry.resolve(cfg)
    assert spec.train_attn == "jnp"
    assert spec.prefill_attn == "pallas"


def test_runspec_rejects_bad_backend():
    with pytest.raises(ValueError):
        SPEC.with_(kernels="decode_attn=cuda").resolve_config()
    with pytest.raises(ValueError):
        SPEC.with_(kernels="not_an_op=pallas").resolve_config()
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError):
            SPEC.with_(attn_backend="typo").resolve_config()


def test_trainer_validates_registry_not_alias_string():
    """make_train_step fails fast on a bad backend through the registry."""
    from repro.core.trainer import TrainerConfig, make_train_step
    from repro.compat import make_mesh
    from repro.optim import sgd_momentum
    cfg = get_reduced("stablelm-1.6b").with_(attn_backend="bogus")
    mesh = make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError):
        make_train_step(cfg, TrainerConfig(rule="dp"), mesh, sgd_momentum())


# ---------------------------------------------------------------------------
# TrainEngine: interrupted + resumed == uninterrupted
# ---------------------------------------------------------------------------

def test_train_engine_resume_matches_uninterrupted(tmp_path):
    kw = dict(rule="cdp_v2", steps=4, batch=2, seq=16, log_every=2,
              verbose=False)
    full = TrainEngine(SPEC, **kw)
    s_full = full.run()

    ckpt = str(tmp_path / "ck")
    part = TrainEngine(SPEC, ckpt_dir=ckpt, ckpt_every=2, **kw)
    part.run(steps=2)                       # interrupted after 2 steps
    resumed = TrainEngine(SPEC, ckpt_dir=ckpt, ckpt_every=2, **kw)
    resumed.build()
    assert resumed.start_step == 2
    s_res = resumed.run()

    for a, b in zip(jax.tree.leaves(s_full["params"]),
                    jax.tree.leaves(s_res["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(s_res["step"]) == 4


def test_train_engine_in_process_continuation_matches():
    """run(steps=2); run() on ONE engine == an uninterrupted run: the
    persistent loader hands prefetched batches to the next call instead of
    dropping them."""
    kw = dict(rule="cdp_v2", steps=4, batch=2, seq=16, log_every=2,
              verbose=False)
    s_full = TrainEngine(SPEC, **kw).run()
    parts = TrainEngine(SPEC, **kw)
    parts.run(steps=2)
    s_parts = parts.run()
    for a, b in zip(jax.tree.leaves(s_full["params"]),
                    jax.tree.leaves(s_parts["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# ServeEngine: fused prefill == old launcher teacher-forcing path
# ---------------------------------------------------------------------------

def _teacher_forced_reference(cfg, params, prompts, cache_len, gen,
                              memory=None):
    """The pre-engine launch/serve.py path: prefill by teacher-forcing the
    prompt through decode_step, then greedy decode."""
    B, S = prompts.shape
    cache = init_cache(cfg, B, cache_len)
    if memory is not None:
        cache["memory"] = memory            # EXACT memory (no zeros splice)
    step = jax.jit(lambda p, b, c: decode_step(cfg, p, b, c))
    logits = None
    for i in range(S):
        logits, cache = step(params, {"token": prompts[:, i]}, cache)
    toks = []
    tok = jnp.argmax(logits, -1)
    for _ in range(gen):
        toks.append(np.asarray(tok))
        logits, cache = step(params, {"token": tok}, cache)
        tok = jnp.argmax(logits, -1)
    return np.stack(toks, 1)


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "zamba2-7b",
                                  "xlstm-350m"])
def test_serve_engine_matches_launcher_decode_path(arch):
    spec = SPEC.with_(arch=arch)
    B, S, gen = 2, 8, 4
    engine = ServeEngine(spec, batch=B, prompt_len=S, gen=gen, verbose=False)
    engine.build()
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                 engine.cfg.vocab_size)
    result = engine.generate(prompts)
    ref = _teacher_forced_reference(engine.cfg, engine.params, prompts,
                                    engine.cache_len, gen)
    np.testing.assert_array_equal(result["tokens"], ref)
    assert result["prefill_tok_s"] > 0 and result["decode_tok_s"] > 0


def test_serve_engine_encdec_public_encode():
    """Enc-dec serving goes through the public encode() and keeps the EXACT
    encoder memory (the zeros-padded splice of the old launcher attended
    zero rows in cross-attention)."""
    spec = SPEC.with_(arch="seamless-m4t-large-v2")
    B, S, gen = 2, 8, 3
    engine = ServeEngine(spec, batch=B, prompt_len=S, gen=gen, verbose=False)
    engine.build()
    cfg = engine.cfg
    prompts = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                 cfg.vocab_size)
    frames = 0.01 * jnp.ones(
        (B, max(1, S // cfg.encdec.frame_rate_divisor), cfg.encdec.frontend_dim),
        jnp.dtype(cfg.dtype))
    memory = engine.encode(frames)
    assert memory.shape == (B, frames.shape[1], cfg.d_model)
    result = engine.generate(prompts, extras={"frames": frames})
    # after prefill the cached memory is the exact encoder output — no pad
    assert engine.cache["memory"].shape[1] == frames.shape[1]
    ref = _teacher_forced_reference(cfg, engine.params, prompts,
                                    engine.cache_len, gen, memory=memory)
    np.testing.assert_array_equal(result["tokens"], ref)


# ---------------------------------------------------------------------------
# Optimizer slot sharding is derived, not hardcoded
# ---------------------------------------------------------------------------

def test_optimizer_slot_keys_derived_from_structure():
    from repro.core.trainer import optimizer_slot_keys
    from repro.optim import adamw, sgd_momentum
    cfg = get_reduced("stablelm-1.6b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    assert optimizer_slot_keys(sgd_momentum().init(params), params) == {"mom"}
    assert optimizer_slot_keys(adamw().init(params), params) == {"m", "v"}

    # a custom optimizer with an unusual slot name is detected structurally
    custom = {"exp_avg": jax.tree.map(jnp.zeros_like, params),
              "count": jnp.zeros((), jnp.int32)}
    assert optimizer_slot_keys(custom, params) == {"exp_avg"}


def test_state_shardings_shard_custom_slots():
    from repro.compat import make_mesh
    from repro.sharding import specs as sh
    cfg = get_reduced("stablelm-1.6b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    mesh = make_mesh((1, 1), ("data", "model"))
    psh = sh.param_shardings(params, mesh)
    state = {"exp_avg": jax.tree.map(jnp.zeros_like, params),
             "count": jnp.zeros((), jnp.int32)}
    out = sh.state_shardings(state, psh)
    assert out["exp_avg"] is psh                # mirrors params
    assert out["count"].spec == jax.sharding.PartitionSpec()
