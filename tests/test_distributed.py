"""Multi-device SPMD tests (run in subprocesses with 8 forced host devices so
the main pytest process keeps a single CPU device)."""
import pytest


def test_ring_all_reduce_equals_pmean(subproc):
    subproc("""
from repro.compat import make_mesh as compat_make_mesh, shard_map as compat_shard_map
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.grad_sync import ring_all_reduce, ring_all_reduce_vec, psum_all_reduce, reduce_scatter_ring
mesh = compat_make_mesh((4, 2), ("data", "model"))
n = 4
tree = {"a": jnp.arange(24.0).reshape(4, 6), "b": jnp.ones((5,)), "w": jnp.arange(32.0).reshape(8, 4)}
pspecs = {"a": P(), "b": P(), "w": P(None, "model")}

def f(x):
    i = jax.lax.axis_index("data")
    local = jax.tree.map(lambda t: t * (i + 1).astype(t.dtype), x)
    ring = ring_all_reduce(local, "data", n, pspecs)
    ps = psum_all_reduce(local, "data")
    return ring, ps

g = compat_shard_map(f, mesh=mesh, in_specs=(jax.tree.map(lambda _: P(), tree),),
                  out_specs=(jax.tree.map(lambda _: P(), tree),)*2,
                  axis_names={"data"}, check_vma=False)
ring, ps = jax.jit(g)(tree)
for k in tree:
    np.testing.assert_allclose(np.asarray(ring[k]), np.asarray(ps[k]), rtol=1e-6)
# vec version
def fv(v):
    i = jax.lax.axis_index("data")
    return ring_all_reduce_vec(v * (i + 1).astype(v.dtype), "data", n)
gv = compat_shard_map(fv, mesh=mesh, in_specs=(P(),), out_specs=P(), axis_names={"data"}, check_vma=False)
v = jnp.arange(37.0)
np.testing.assert_allclose(np.asarray(jax.jit(gv)(v)), np.asarray(v) * 10, rtol=1e-6)
print("RING OK")
""")


def test_trainer_rules_semantics_on_mesh(subproc):
    """CDP-v1 must equal manual delayed-SGD; DP must equal plain SGD; v2 must
    sit between. Verified against the single-process delay simulator."""
    subproc("""
from repro.compat import make_mesh as compat_make_mesh, shard_map as compat_shard_map
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_reduced
from repro.core.trainer import TrainerConfig, init_state, jit_train_step
from repro.core.delay_sim import make_sim_step, init_sim_state
from repro.models import init_params, loss_fn as model_loss
from repro.models.model import param_stage_ids
from repro.optim import sgd_momentum
mesh = compat_make_mesh((4, 2), ("data", "model"))
cfg = get_reduced("stablelm-1.6b")
key = jax.random.PRNGKey(0)
params = init_params(cfg, key)
opt = sgd_momentum(0.9)
B, S = 8, 16
batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
         "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
steps = 3
for rule in ("dp", "cdp_v1", "cdp_v2"):
    tr = TrainerConfig(rule=rule, lr_schedule=lambda s: 0.05, donate=False)
    state = init_state(cfg, tr, params, opt)
    jitted, ssh, bsh = jit_train_step(cfg, tr, mesh, opt, state, batch)
    for _ in range(steps):
        state, met = jitted(state, batch)
    # reference: delay simulator with the same stage partition (n = 4 = data axis)
    ids = param_stage_ids(cfg, params, 4)
    sim = make_sim_step(lambda p, mb: model_loss(cfg, p, mb)[0], ids, rule, 4, opt, lambda s: 0.05)
    sstate = init_sim_state(params, rule, opt)
    mb = {k: v.reshape(4, 2, S) for k, v in batch.items()}
    for _ in range(steps):
        sstate, _ = sim(sstate, mb)
    for a, b in zip(jax.tree.leaves(state["params"]), jax.tree.leaves(sstate["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32), atol=5e-4, rtol=5e-3)
    print(rule, "MATCHES SIMULATOR")
""", timeout=1200)


def test_cdp_loss_decreases_all_rules(subproc):
    subproc("""
from repro.compat import make_mesh as compat_make_mesh, shard_map as compat_shard_map
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_reduced
from repro.core.trainer import TrainerConfig, init_state, jit_train_step
from repro.data import make_lm_data, lm_batch_iterator
from repro.models import init_params
from repro.optim import sgd_momentum
mesh = compat_make_mesh((4, 2), ("data", "model"))
cfg = get_reduced("qwen2.5-14b")
params = init_params(cfg, jax.random.PRNGKey(0))
opt = sgd_momentum(0.9)
toks = make_lm_data(cfg.vocab_size, 50_000)
it = lm_batch_iterator(toks, 8, 32)
b0 = {k: jnp.asarray(v) for k, v in next(it).items()}
for rule in ("dp", "cdp_v1", "cdp_v2"):
    # lr 0.05 + clip: at 0.1 the fully-delayed cdp_v1 gradients + momentum
    # 0.9 diverge after ~15 steps (delayed SGD needs the smaller step; the
    # rule itself is verified exactly against the delay simulator above)
    tr = TrainerConfig(rule=rule, lr_schedule=lambda s: 0.05, grad_clip=1.0, donate=False)
    state = init_state(cfg, tr, params, opt)
    jitted, _, _ = jit_train_step(cfg, tr, mesh, opt, state, b0)
    losses = []
    for i in range(25):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, met = jitted(state, batch)
        losses.append(float(met["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, (rule, losses)
    print(rule, f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
""", timeout=1200)


def test_zero_cdp_streaming_equals_baseline(subproc):
    """ZeRO-CDP parameter streaming (ppermute ring) == ZeRO-DP all-gather ==
    local sequential execution."""
    subproc("""
from repro.compat import make_mesh as compat_make_mesh, shard_map as compat_shard_map
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.zero import zero_cdp_apply, zero_dp_apply, roll_stage_params
n = 8
mesh = compat_make_mesh((8,), ("data",))
key = jax.random.PRNGKey(0)
d = 16
stages = {"w": 0.3 * jax.random.normal(key, (n, d, d)),
          "b": 0.1 * jax.random.normal(jax.random.PRNGKey(1), (n, d))}

def stage_fn(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])

x = jax.random.normal(jax.random.PRNGKey(2), (n, 4, d))  # one microbatch/rank

# local reference
def local_ref(x1):
    for j in range(n):
        x1 = stage_fn({"w": stages["w"][j], "b": stages["b"][j]}, x1)
    return x1
ref = jax.vmap(local_ref)(x)

rolled = roll_stage_params(stages, n)
def run_cdp(rolled_shard, xs):
    my_params = jax.tree.map(lambda t: t[0], rolled_shard)  # drop shard dim
    return zero_cdp_apply(stage_fn, my_params, xs[0], "data", n)[None]
f = compat_shard_map(run_cdp, mesh=mesh,
                  in_specs=(jax.tree.map(lambda _: P("data"), stages), P("data")),
                  out_specs=P("data"), axis_names={"data"}, check_vma=False)
out_cdp = jax.jit(f)(rolled, x)
np.testing.assert_allclose(np.asarray(out_cdp), np.asarray(ref), rtol=2e-5, atol=2e-5)

def run_dp(rolled_shard, xs):
    return zero_dp_apply(stage_fn, jax.tree.map(lambda t: t[0], rolled_shard), xs[0], "data", n)[None]
fd = compat_shard_map(run_dp, mesh=mesh,
                  in_specs=(jax.tree.map(lambda _: P("data"), stages), P("data")),
                  out_specs=P("data"), axis_names={"data"}, check_vma=False)
out_dp = jax.jit(fd)(rolled, x)
np.testing.assert_allclose(np.asarray(out_dp), np.asarray(ref), rtol=2e-5, atol=2e-5)

# grads flow through the ppermute chain
def loss_cdp(rolled, x):
    y = jax.jit(f)(rolled, x)
    return jnp.sum(y ** 2)
g = jax.grad(loss_cdp)(rolled, x)
assert all(np.isfinite(np.asarray(t)).all() for t in jax.tree.leaves(g))
assert float(jnp.abs(g["w"]).max()) > 0
print("ZERO-CDP OK")
""", timeout=900)


def test_collectives_in_hlo_match_paper_claims(subproc):
    """CDP ring lowers to collective-permute (point-to-point), DP lowers to a
    single all-reduce burst — the paper's Table 1 communication claim, read
    off the compiled HLO."""
    subproc("""
from repro.compat import make_mesh as compat_make_mesh, shard_map as compat_shard_map
import jax, jax.numpy as jnp, re
from repro.configs import get_reduced
from repro.core.trainer import TrainerConfig, init_state, jit_train_step
from repro.models import init_params
from repro.optim import sgd_momentum
from repro.launch.roofline import parse_collectives
mesh = compat_make_mesh((4, 2), ("data", "model"))
cfg = get_reduced("stablelm-1.6b")
params = init_params(cfg, jax.random.PRNGKey(0))
opt = sgd_momentum(0.9)
batch = {"tokens": jnp.zeros((8, 16), jnp.int32), "targets": jnp.zeros((8, 16), jnp.int32)}
stats = {}
for rule in ("dp", "cdp_v2"):
    tr = TrainerConfig(rule=rule, lr_schedule=lambda s: 0.05, donate=False)
    state = init_state(cfg, tr, params, opt)
    jitted, ssh, bsh = jit_train_step(cfg, tr, mesh, opt, state, batch)
    comp = jitted.lower(state, batch).compile()
    stats[rule] = parse_collectives(comp.as_text())
print({k: (v.op_counts, v.max_grad_merge_bytes()) for k, v in stats.items()})
assert stats["cdp_v2"].op_counts["collective-permute"] > 0
# the ring breaks the big gradient burst into chunks: the largest
# gradient-merge collective (all-reduce / permute / reduce-scatter) shrinks.
# (Compared per-type, not on the global max: a compat-mode param all-gather
# outside the step can dominate both programs identically.)
assert stats["cdp_v2"].max_grad_merge_bytes() < stats["dp"].max_grad_merge_bytes()
print("HLO CLAIMS OK")
""", timeout=1200)


def test_zero1_ring_matches_baseline(subproc):
    """ZeRO-1-on-the-ring (reduce-scatter + data-sharded optimizer state +
    param all-gather) must be numerically identical to the full ring."""
    subproc("""
from repro.compat import make_mesh as compat_make_mesh, shard_map as compat_shard_map
import jax, jax.numpy as jnp, numpy as np
mesh = compat_make_mesh((4,2), ("data","model"))
from repro.configs import get_reduced
from repro.core.trainer import TrainerConfig, init_state, jit_train_step
from repro.optim import sgd_momentum
import repro.models as M
cfg = get_reduced("qwen2.5-14b")
key = jax.random.PRNGKey(0)
params = M.init_params(cfg, key)
opt = sgd_momentum(0.9)
batch = {"tokens": jax.random.randint(key,(8,32),0,cfg.vocab_size),
         "targets": jax.random.randint(key,(8,32),0,cfg.vocab_size)}
res = {}
for tag, kw in [("base", {}), ("zero1", dict(zero1_ring=True)),
                ("seqpar", dict(seq_parallel=True))]:
    tr = TrainerConfig(rule="cdp_v2", lr_schedule=lambda s: 0.1, donate=False, **kw)
    state = init_state(cfg, tr, params, opt)
    jt, ssh, bsh = jit_train_step(cfg, tr, mesh, opt, state, batch)
    for _ in range(3):
        state, met = jt(state, batch)
    res[tag] = np.concatenate([np.asarray(l).ravel()[:50]
                               for l in jax.tree.leaves(state["params"])][:5])
for tag in ("zero1", "seqpar"):
    assert float(np.max(np.abs(res[tag]-res["base"]))) < 5e-4, tag
print("ZERO1/SEQPAR OK")
""", timeout=1200)


def test_cdp_random_rule_trains(subproc):
    """Beyond-paper randomized u_{i,j} (the paper's stated future work)
    trains on par with cdp_v2 and keeps delay <= 1."""
    subproc("""
from repro.compat import make_mesh as compat_make_mesh, shard_map as compat_shard_map
import jax, jax.numpy as jnp, numpy as np
mesh = compat_make_mesh((4,2), ("data","model"))
from repro.configs import get_reduced
from repro.core.trainer import TrainerConfig, init_state, jit_train_step
from repro.data import make_lm_data, lm_batch_iterator
from repro.optim import sgd_momentum
import repro.models as M
cfg = get_reduced("qwen2.5-14b")
params = M.init_params(cfg, jax.random.PRNGKey(0))
opt = sgd_momentum(0.9)
it = lm_batch_iterator(make_lm_data(cfg.vocab_size, 50000), 8, 32)
b0 = {k: jnp.asarray(v) for k, v in next(it).items()}
tr = TrainerConfig(rule="cdp_random", lr_schedule=lambda s: 0.1, donate=False,
                   grad_clip=1.0)
state = init_state(cfg, tr, params, opt)
jt, _, _ = jit_train_step(cfg, tr, mesh, opt, state, b0)
losses = []
for i in range(25):
    b = {k: jnp.asarray(v) for k, v in next(it).items()}
    state, met = jt(state, b)
    losses.append(float(met["loss"]))
assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses
print("cdp_random", losses[0], "->", losses[-1])
""", timeout=1200)
