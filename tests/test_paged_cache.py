"""Paged KV-cache subsystem: allocator invariants, paged-vs-dense bitwise
parity, copy-on-write prefix sharing, and offload/wake round-trips.

The load-bearing properties:

  * paged decode is BITWISE identical to the dense per-slot cache — the
    block pool is a memory-layout change, never a numerics change;
  * a shared prefix is shared by reference only: a request diverging from
    it (or being preempted off it) must never perturb a co-resident;
  * every terminal status releases its blocks — the pool drains to empty
    after any serve, whatever mix of ok/timeout/failed the workload hit.

Allocator invariants are checked twice: structurally (``BlockPool.audit``)
and by replaying the ``page_*`` event stream a serve left behind — the
event log alone must prove no block was double-freed or handed out while
still referenced.
"""
import numpy as np
import pytest

from repro.engine import BlockPool, PoolExhausted, Request, RunSpec
from repro.engine.serve import ServeEngine

SPEC = RunSpec(arch="stablelm-1.6b", reduced=True, mesh_data=1, mesh_model=1)


def _prompt(rng, n, vocab=500):
    return rng.integers(0, vocab, size=n).astype(np.int32)


# ---------------------------------------------------------------------------
# BlockPool (host-side, no jax)
# ---------------------------------------------------------------------------

def test_blockpool_refcounts_drain_and_blocks_return():
    pool = BlockPool(8, 4, prefix_cache=False)
    rng = np.random.default_rng(0)
    hist, cow = pool.admit(0, _prompt(rng, 10))    # 3 blocks
    assert hist == 0 and cow is None               # no prefix cache
    pool.admit(1, _prompt(rng, 7))                 # 2 blocks
    assert pool.blocks_in_use() == 5
    pool.audit()
    pool.release_slot(0)
    pool.release_slot(1)
    pool.release_slot(1)                           # idempotent
    assert pool.blocks_in_use() == 0
    assert (pool.ref == 0).all()
    assert sorted(pool.free) == list(range(8))     # all blocks came back
    pool.audit()


def test_blockpool_prefix_sharing_and_full_match_cow():
    pool = BlockPool(16, 4)
    p = np.arange(12, dtype=np.int32)
    h0, c0 = pool.admit(0, p)
    assert h0 == 0 and c0 is None                  # cold: nothing cached
    # identical prompt: full match -> hist = plen-1, last block CoW'd
    h1, c1 = pool.admit(1, p)
    assert h1 == 11 and c1 is not None
    src, dst, logical = c1
    assert logical == 2 and pool.slot_blocks[1][2] == dst
    # the two leading blocks are aliased by reference, not copied
    assert pool.slot_blocks[0][:2] == pool.slot_blocks[1][:2]
    assert all(pool.ref[b] == 2 for b in pool.slot_blocks[0][:2])
    # a PARTIAL match shares only the matched whole blocks, no CoW
    q = np.concatenate([p[:8], np.array([90, 91, 92, 93], np.int32)])
    h2, c2 = pool.admit(2, q)
    assert h2 == 8 and c2 is None
    assert pool.slot_blocks[2][:2] == pool.slot_blocks[0][:2]
    pool.audit()
    for s in (0, 1, 2):
        pool.release_slot(s)
    assert (pool.ref == 0).all()
    # registered prefix blocks stay cached (reclaimable), not free
    assert set(pool.lru) == set(pool.registered)
    pool.audit()


def test_blockpool_exhaustion_rolls_back_and_reclaims_lru():
    pool = BlockPool(3, 4)
    rng = np.random.default_rng(1)
    pool.admit(0, _prompt(rng, 12))                # all 3 blocks
    with pytest.raises(PoolExhausted):
        pool.admit(1, _prompt(rng, 4))
    assert 1 not in pool.slot_blocks               # rolled back cleanly
    assert pool.blocks_in_use() == 3
    pool.audit()
    pool.release_slot(0)                           # blocks -> prefix LRU
    assert pool.blocks_in_use() == 0 and not pool.free
    pool.admit(1, _prompt(rng, 12))                # reclaims all 3 via LRU
    assert pool.blocks_in_use() == 3
    pool.audit()


def test_blockpool_exhaustion_rollback_deregisters_unwritten():
    """A rolled-back admission must not leave its never-prefilled blocks
    registered: a retry (the engine's normal exhaustion path) would get
    prefix hits on blocks whose content was never written and decode over
    zero/garbage KV."""
    pool = BlockPool(3, 4)
    p = np.arange(16, dtype=np.int32)              # needs 4 blocks > pool
    with pytest.raises(PoolExhausted):
        pool.admit(0, p)
    assert not pool.registered and not pool.by_hash and not pool.lru
    h, cow = pool.admit(1, p[:12])                 # same leading blocks fit
    assert h == 0 and cow is None, \
        "phantom prefix hit on blocks that were never prefilled"
    pool.audit()


def test_blockpool_pending_tail_not_matchable_until_written():
    """A block registered by a shared-tail admission holds no content until
    the engine's round executes its prefill: matching it — or using it as a
    CoW source — before mark_written() would read unwritten KV."""
    pool = BlockPool(16, 4)
    base = np.arange(8, dtype=np.int32)
    p = np.concatenate([base, np.array([50, 51, 52, 53], np.int32)])
    pool.admit(0, base)                    # fresh plan: matchable at once
    pool.mark_written()
    h1, c1 = pool.admit(1, p)              # partial hit -> tail is PENDING
    assert h1 == 8 and c1 is None
    h2, c2 = pool.admit(2, p)              # same round, identical prompt
    assert h2 == 8 and c2 is None, \
        "matched a tail block whose prefill has not run yet"
    pool.mark_written()
    h3, c3 = pool.admit(3, p)              # next round: fully matchable
    assert h3 == 11 and c3 is not None
    pool.audit()


def test_blockpool_audit_catches_aliased_writable_block():
    pool = BlockPool(4, 4, prefix_cache=False)
    rng = np.random.default_rng(2)
    pool.admit(0, _prompt(rng, 4))
    b = pool.slot_blocks[0][0]
    # alias the block into a second slot WITHOUT registering it
    pool.ref[b] += 1
    pool.slot_blocks[1] = [b]
    with pytest.raises(AssertionError, match="aliased"):
        pool.audit()


# ---------------------------------------------------------------------------
# Engine level
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dense_engine():
    eng = ServeEngine(SPEC, prompt_len=16, gen=8, verbose=False)
    eng.build()
    return eng


@pytest.fixture(scope="module")
def paged_engine():
    eng = ServeEngine(SPEC, prompt_len=16, gen=8, paged=True,
                      kv_block_size=4, verbose=False)
    eng.build()
    return eng


@pytest.fixture(scope="module")
def paged_nopfx():
    eng = ServeEngine(SPEC, prompt_len=16, gen=8, paged=True,
                      kv_block_size=4, prefix_cache=False, verbose=False)
    eng.build()
    return eng


def _staggered(n=5, seed=3):
    rng = np.random.default_rng(seed)
    arrivals = [0, 1, 3, 5, 8, 11, 13][:n]
    gens = [8, 3, 6, 2, 8, 4, 7][:n]
    return [Request(rid=i, prompt=_prompt(rng, int(rng.integers(5, 17))),
                    max_gen=gens[i], arrival_step=arrivals[i])
            for i in range(n)]


def _tokens(res):
    return {r.rid: r.tokens.tolist() for r in res["requests"]}


def test_paged_matches_dense_staggered(paged_nopfx, dense_engine):
    """Paged decode through the block table is bitwise identical to the
    dense per-slot cache under staggered admission — same prompts, same
    arrival steps, same slots, greedy decode."""
    res_p = paged_nopfx.serve(_staggered(), max_slots=2)
    res_d = dense_engine.serve(_staggered(), max_slots=2)
    assert res_p["metrics"]["admitted_mid_decode"] > 0
    assert res_p["metrics"]["status_counts"] == {"ok": 5}
    assert _tokens(res_p) == _tokens(res_d)


def test_poisoned_pool_never_leaks_unwritten_lanes(monkeypatch,
                                                   dense_engine):
    """Leak canary: PAGED_POISON=1 fills the pool (trash block included)
    with NaN at init, so any read of a never-written lane that escapes the
    masks becomes NaN logits -> token 0 instead of a silent zero-read.
    Parity with dense under poison proves every kept token was computed
    from lanes the engine actually wrote (this caught a real race: the
    async host->device table upload reading an in-place-mutated table)."""
    monkeypatch.setenv("PAGED_POISON", "1")
    eng = ServeEngine(SPEC, prompt_len=16, gen=8, paged=True,
                      kv_block_size=4, prefix_cache=False, verbose=False)
    res_p = eng.serve(_staggered(), max_slots=2)
    res_d = dense_engine.serve(_staggered(), max_slots=2)
    assert res_p["metrics"]["status_counts"] == {"ok": 5}
    assert _tokens(res_p) == _tokens(res_d)


def test_prefix_sharing_warm_hit_rate_and_parity():
    """Re-serving the same prompts hits the prefix cache for all but the
    last token of each prompt (> 0.9 of prefill work skipped) and the
    tokens are bitwise identical to the cold serve. The pool must be large
    enough to RETAIN the registered prefixes — a pool sized below the
    working set thrashes the LRU and the hit rate collapses to 0."""
    eng = ServeEngine(SPEC, prompt_len=16, gen=8, paged=True,
                      kv_block_size=4, kv_pool_blocks=40, verbose=False)
    rng = np.random.default_rng(9)
    prompts = [_prompt(rng, 16) for _ in range(4)]

    def reqs(base):
        return [Request(rid=base + i, prompt=p, max_gen=8)
                for i, p in enumerate(prompts)]

    cold = eng.serve(reqs(0), max_slots=4)
    warm = eng.serve(reqs(100), max_slots=4)
    pg = warm["metrics"]["paging"]
    assert pg["prefix_hit_rate"] > 0.9, pg
    assert pg["marginal_prefill_tokens"] < pg["prefill_tokens_requested"]
    cold_t = {r.rid % 100: r.tokens.tolist() for r in cold["requests"]}
    warm_t = {r.rid % 100: r.tokens.tolist() for r in warm["requests"]}
    assert warm_t == cold_t


def test_cow_divergence_never_perturbs_co_residents(paged_engine,
                                                    paged_nopfx):
    """Requests sharing a 12-token prefix then diverging: every stream must
    equal its unshared solo serve — writes into a shared block go through
    copy-on-write, never in place."""
    rng = np.random.default_rng(21)
    prefix = _prompt(rng, 12)
    reqs = [Request(rid=i,
                    prompt=np.concatenate([prefix, _prompt(rng, 4)]),
                    max_gen=8)
            for i in range(3)]
    shared = paged_engine.serve(
        [Request(rid=r.rid, prompt=r.prompt, max_gen=8) for r in reqs],
        max_slots=3)
    assert shared["metrics"]["paging"]["prefix_hit_rate"] > 0
    for r in reqs:
        solo = paged_nopfx.serve(
            [Request(rid=r.rid, prompt=r.prompt, max_gen=8)], max_slots=3)
        assert _tokens(shared)[r.rid] == _tokens(solo)[r.rid], \
            f"request {r.rid} perturbed by its shared prefix"


def test_identical_prompts_in_one_batch_share_and_match(paged_engine):
    """Identical prompts admitted TOGETHER share within the batch (blocks
    are registered at allocation time); full-match CoW keeps each row's
    final block private and the streams identical."""
    rng = np.random.default_rng(5)
    p = _prompt(rng, 16)
    res = paged_engine.serve(
        [Request(rid=i, prompt=p, max_gen=6) for i in range(3)],
        max_slots=3)
    assert res["metrics"]["paging"]["cow_copies"] >= 2
    toks = _tokens(res)
    assert toks[0] == toks[1] == toks[2]


def test_same_round_shared_tail_cow_parity():
    """Request A extends a cached prefix with a prompt ending on a block
    boundary (its tail block is registered at allocation time); request B
    carries the identical prompt in the SAME admission round. B must not
    CoW-copy or read A's tail block before A's shared-tail prefill writes
    it — both streams must match an unshared solo serve bitwise."""
    eng = ServeEngine(SPEC, prompt_len=24, gen=6, paged=True,
                      kv_block_size=4, kv_pool_blocks=48, verbose=False)
    rng = np.random.default_rng(31)
    base = _prompt(rng, 16)
    ext = np.concatenate([base, _prompt(rng, 8)])   # 24 tokens, % 4 == 0
    eng.serve([Request(rid=0, prompt=base, max_gen=4)], max_slots=2)
    res = eng.serve([Request(rid=1, prompt=ext.copy(), max_gen=6),
                     Request(rid=2, prompt=ext.copy(), max_gen=6)],
                    max_slots=2)
    assert res["metrics"]["paging"]["prefix_hit_rate"] > 0
    solo_eng = ServeEngine(SPEC, prompt_len=24, gen=6, paged=True,
                           kv_block_size=4, prefix_cache=False,
                           verbose=False)
    solo = solo_eng.serve([Request(rid=9, prompt=ext.copy(), max_gen=6)],
                          max_slots=1)
    toks = _tokens(res)
    assert toks[1] == toks[2] == _tokens(solo)[9], \
        "same-round shared-tail admission read/copied unwritten blocks"
    pool = eng._paged_state["pool"]
    assert pool.blocks_in_use() == 0 and not pool.pending
    pool.audit()


def test_poison_quarantine_spares_shared_prefix_and_registry():
    """A poison_request fault on one request of a shared-prefix trio must
    quarantine ONLY that request: co-residents sharing its prefix blocks
    finish bitwise intact, and the prefix registry never serves a NaN block
    to a later request."""
    eng = ServeEngine(SPEC, prompt_len=16, gen=8, paged=True,
                      kv_block_size=4, resilience="poison_request@1",
                      verbose=False)
    rng = np.random.default_rng(41)
    p = _prompt(rng, 16)
    res = eng.serve([Request(rid=i, prompt=p.copy(), max_gen=8)
                     for i in range(3)], max_slots=3)
    statuses = {r.rid: r.status for r in res["requests"]}
    assert statuses == {0: "ok", 1: "failed", 2: "ok"}, statuses
    clean = ServeEngine(SPEC, prompt_len=16, gen=8, paged=True,
                        kv_block_size=4, prefix_cache=False, verbose=False)
    ref = clean.serve([Request(rid=0, prompt=p.copy(), max_gen=8)],
                      max_slots=3)
    assert _tokens(res)[0] == _tokens(res)[2] == _tokens(ref)[0], \
        "poisoned row leaked NaN into co-residents sharing its prefix"
    # warm re-serve: the registry must hit the (un-poisoned) prefix blocks
    res2 = eng.serve([Request(rid=10, prompt=p.copy(), max_gen=8)],
                     max_slots=3)
    assert {r.status for r in res2["requests"]} == {"ok"}
    assert res2["metrics"]["paging"]["prefix_hit_rate"] > 0.9
    assert _tokens(res2)[10] == _tokens(ref)[0], \
        "prefix registry served a block poisoned by the quarantined row"
    pool = eng._paged_state["pool"]
    assert pool.blocks_in_use() == 0
    pool.audit()


@pytest.mark.parametrize("level", [1, 2])
def test_pool_exhaustion_preemption_roundtrip(level):
    """A pool too small for the workload forces preemption; sleep level 1
    (host offload, bitwise restore) and level 2 (discard + re-prefill) must
    both finish every request with tokens identical to an unpressured
    pool."""
    def reqs():
        rng = np.random.default_rng(7)
        return [Request(rid=i, prompt=_prompt(rng, 16), max_gen=12)
                for i in range(4)]

    tiny = ServeEngine(SPEC, prompt_len=16, gen=12, paged=True,
                       kv_block_size=4, kv_pool_blocks=16,
                       prefix_cache=False, sleep_level=level, verbose=False)
    res = tiny.serve(reqs(), max_slots=4, max_steps=500)
    pg = res["metrics"]["paging"]
    assert res["metrics"]["status_counts"] == {"ok": 4}
    assert pg["preemptions"] > 0, "workload too tame: no pool pressure"
    if level == 1:
        assert pg["offloads"] > 0 and pg["wakes"] > 0
    else:
        assert pg["offloads"] == 0 and pg["wakes"] > 0

    big = ServeEngine(SPEC, prompt_len=16, gen=12, paged=True,
                      kv_block_size=4, prefix_cache=False, verbose=False)
    ref = big.serve(reqs(), max_slots=4)
    assert ref["metrics"]["paging"]["preemptions"] == 0
    assert _tokens(res) == _tokens(ref), \
        f"sleep level {level} round-trip diverged"

    # allocator invariant replay from the event stream alone: a block is
    # only handed out while unreferenced, never double-freed, and every
    # reference is eventually dropped
    ref_replay = {}
    for ev in res["events"]:
        kind = ev[0]
        if not kind.startswith("page_"):
            continue
        _, _, slot, block = ev
        if kind == "page_alloc":
            assert ref_replay.get(block, 0) == 0, \
                f"block {block} allocated while still referenced"
            ref_replay[block] = 1
        elif kind == "page_share":
            ref_replay[block] = ref_replay.get(block, 0) + 1
        elif kind == "page_cow":
            src, dst = block
            assert ref_replay.get(dst, 0) == 0
            ref_replay[dst] = 1
            ref_replay[src] -= 1
            assert ref_replay[src] >= 0, f"block {src} double-freed (cow)"
        elif kind == "page_free":
            ref_replay[block] = ref_replay.get(block, 0) - 1
            assert ref_replay[block] >= 0, f"block {block} double-freed"
    assert all(v == 0 for v in ref_replay.values()), \
        f"leaked references at end of serve: {ref_replay}"

    # structural audit of the live pool agrees: fully drained
    pool = tiny._paged_state["pool"]
    assert pool.blocks_in_use() == 0
    pool.audit()


def test_terminal_statuses_release_blocks():
    """Satellite 1: every terminal path — completion, deadline timeout,
    poison quarantine — returns its blocks; the pool is empty after serve
    whatever the status mix."""
    eng = ServeEngine(SPEC, prompt_len=16, gen=8, paged=True,
                      kv_block_size=4, resilience="poison_request@1",
                      verbose=False)
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i, prompt=_prompt(rng, 16), max_gen=8,
                    deadline_steps=3 if i == 2 else None)
            for i in range(4)]
    res = eng.serve(reqs, max_slots=4, max_steps=200)
    statuses = {r.rid: r.status for r in res["requests"]}
    assert statuses[1] == "failed" and statuses[2] == "timeout"
    assert statuses[0] == "ok" and statuses[3] == "ok"
    pool = eng._paged_state["pool"]
    assert pool.blocks_in_use() == 0, \
        f"terminal statuses leaked blocks: {statuses}"
    pool.audit()
    # survivors were not perturbed by the quarantined row's NaN blocks
    for r in res["requests"]:
        if r.status == "ok":
            assert np.isfinite(r.tokens).all() and len(r.tokens) == 8


def test_peak_occupancy_independent_of_max_len():
    """The acceptance property: with a fixed pool, peak block occupancy
    tracks the tokens actually resident, NOT the engine's max cache length
    — growing ``gen`` (hence cache_len) must not move the peak."""
    def reqs():
        rng = np.random.default_rng(13)
        return [Request(rid=i, prompt=_prompt(rng, 16), max_gen=6)
                for i in range(4)]

    peaks = []
    for gen in (8, 32):
        eng = ServeEngine(SPEC, prompt_len=16, gen=gen, paged=True,
                          kv_block_size=4, kv_pool_blocks=32,
                          prefix_cache=False, verbose=False)
        res = eng.serve(reqs(), max_slots=2)
        peaks.append(res["metrics"]["paging"]["blocks_in_use_peak"])
    assert peaks[0] == peaks[1], \
        f"peak occupancy scaled with max_len: {peaks}"


def test_pallas_paged_backend_matches_jnp(paged_engine):
    """The Pallas block-table kernels (paged_attn=pallas) produce the same
    tokens as the jnp gather reference."""
    spec = RunSpec(arch="stablelm-1.6b", reduced=True, mesh_data=1,
                   mesh_model=1, kernels="paged_attn=pallas")
    eng = ServeEngine(spec, prompt_len=16, gen=8, paged=True,
                      kv_block_size=4, verbose=False)
    res_p = eng.serve(_staggered(seed=17), max_slots=2)
    res_j = paged_engine.serve(_staggered(seed=17), max_slots=2)
    assert _tokens(res_p) == _tokens(res_j)


def test_batch_axes_discovered_once_per_engine(dense_engine, monkeypatch):
    """Satellite 2: ``cache_batch_axes`` eval_shape discovery runs once per
    engine build and is reused from ``_cache_axes`` afterwards."""
    from repro.engine import batching

    calls = {"n": 0}
    real = batching.cache_batch_axes

    def counting(init_fn):
        calls["n"] += 1
        return real(init_fn)

    monkeypatch.setattr(batching, "cache_batch_axes", counting)
    dense_engine._cache_axes = None                # force re-discovery
    from repro.models import init_cache
    init = lambda b: init_cache(dense_engine.cfg, b, 24)
    a1 = dense_engine._batch_axes(init)
    a2 = dense_engine._batch_axes(init)
    assert calls["n"] == 1 and a1 is a2


def test_sleep2_wake_on_shared_prefix_under_preemption_storm():
    """Satellite: a preemption storm at sleep level 2 (discard +
    re-prefill) hitting rows whose PREFIX BLOCKS ARE SHARED. A preempted
    row drops its references and its wake re-admits through the prefix
    registry — the round-trip must neither corrupt the shared blocks nor
    leak a reference: refcounts drain to zero and every co-resident's
    stream is bitwise identical to an unpressured pool's."""
    rng = np.random.default_rng(23)
    prefix = _prompt(rng, 12)
    suffixes = [_prompt(rng, 4) for _ in range(4)]

    def reqs():
        return [Request(rid=i,
                        prompt=np.concatenate([prefix, suffixes[i]]),
                        max_gen=12)
                for i in range(4)]

    tiny = ServeEngine(SPEC, prompt_len=16, gen=12, paged=True,
                       kv_block_size=4, kv_pool_blocks=14,
                       sleep_level=2, verbose=False)
    res = tiny.serve(reqs(), max_slots=4, max_steps=800)
    pg = res["metrics"]["paging"]
    assert res["metrics"]["status_counts"] == {"ok": 4}
    assert pg["preemptions"] > 0, "workload too tame: no pool pressure"
    assert pg["offloads"] == 0 and pg["wakes"] > 0    # level 2: discard only
    assert pg["prefix_hit_rate"] > 0, "prefixes were never shared"

    big = ServeEngine(SPEC, prompt_len=16, gen=12, paged=True,
                      kv_block_size=4, verbose=False)
    ref = big.serve(reqs(), max_slots=4)
    assert ref["metrics"]["paging"]["preemptions"] == 0
    assert _tokens(res) == _tokens(ref), \
        "level-2 wake on shared prefixes diverged"

    pool = tiny._paged_state["pool"]
    assert pool.blocks_in_use() == 0 and (pool.ref == 0).all()
    assert not pool.pending
    pool.audit()
