"""select_params / thresholds — Eq. (CDP) semantics on parameter pytrees."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import schedule as S
from repro.core.update_rules import (fresh_threshold_traced, select_params)
from repro.configs import get_reduced
from repro.models import init_params
from repro.models.model import param_stage_ids


def toy_tree(n_layers=6):
    return {"embed": jnp.zeros((4, 2)),
            "blocks": {"dense": {"w": jnp.zeros((n_layers, 3, 3))}},
            "final_norm": {"scale": jnp.zeros((3,))}}


def test_thresholds_match_schedule():
    for rule in S.RULES:
        for n in (2, 4, 16):
            for i in range(n):
                a = S.fresh_threshold(rule, i, n)
                b = int(fresh_threshold_traced(rule, jnp.int32(i), n))
                assert a == b, (rule, i, n)


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "deepseek-v3-671b",
                                  "xlstm-350m", "zamba2-7b",
                                  "seamless-m4t-large-v2"])
def test_stage_ids_cover_all_stages(arch):
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    n = 2
    ids = param_stage_ids(cfg, params, n)
    vals = set()
    for leaf in jax.tree.leaves(ids):
        vals.update(np.unique(np.asarray(leaf)).tolist())
    assert vals <= set(range(n))
    assert 0 in vals and (n - 1) in vals


def test_select_params_mixes_by_stage():
    cfg = get_reduced("qwen2.5-14b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    prev = jax.tree.map(lambda x: x - 1000.0, params)
    n = 2
    ids = param_stage_ids(cfg, params, n)

    # threshold n -> all stale
    sel = select_params(params, prev, ids, jnp.int32(n))
    assert all(np.allclose(a, b) for a, b in
               zip(jax.tree.leaves(sel), jax.tree.leaves(prev)))
    # threshold 0 -> all fresh
    sel = select_params(params, prev, ids, jnp.int32(0))
    assert all(np.allclose(a, b) for a, b in
               zip(jax.tree.leaves(sel), jax.tree.leaves(params)))
    # threshold 1 with 2 stages: embedding stale, head fresh
    sel = select_params(params, prev, ids, jnp.int32(1))
    assert np.allclose(sel["embed"], prev["embed"])
    assert np.allclose(sel["lm_head"], params["lm_head"])
    # layer stacking: first layer stale, last fresh
    w_sel = sel["blocks"]["dense"]["ln1"]["scale"]
    w_new = params["blocks"]["dense"]["ln1"]["scale"]
    w_old = prev["blocks"]["dense"]["ln1"]["scale"]
    assert np.allclose(w_sel[0], w_old[0])
    assert np.allclose(w_sel[-1], w_new[-1])


def test_cdp_random_threshold_bounds():
    """Beyond-paper random rule: threshold always in [thr_v2, n] — never
    fresher than the cyclic execution permits, delay always <= 1."""
    import jax
    from repro.core.update_rules import fresh_threshold_traced
    n = 8
    for i in range(n):
        lo = S.fresh_threshold(S.RULE_CDP_V2, i, n)
        for step in range(5):
            t = int(fresh_threshold_traced("cdp_random", jnp.int32(i), n,
                                           jnp.int32(step)))
            assert lo <= t <= n, (i, step, t)
    # deterministic in (step, i)
    a = int(fresh_threshold_traced("cdp_random", jnp.int32(2), n, jnp.int32(3)))
    b = int(fresh_threshold_traced("cdp_random", jnp.int32(2), n, jnp.int32(3)))
    assert a == b


def test_ascii_timeline_properties():
    from repro.core.schedule import ascii_timeline
    out = ascii_timeline(4)
    lines = [l for l in out.splitlines() if l.startswith("w")]
    assert len(lines) == 4
    # every tick column contains each stage exactly once (F or B)
    cols = list(zip(*[l.split()[1:] for l in lines]))
    for col in cols:
        stages = sorted(c[1] for c in col)
        assert stages == ["0", "1", "2", "3"]
