import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600):
    """Run a python snippet in a subprocess with N forced host devices.

    Multi-device tests use this so the main pytest process keeps the default
    single CPU device (the dry-run flag must never be set globally).
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    if res.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}")
    return res.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_with_devices
