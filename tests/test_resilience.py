"""Chaos suite: deterministic fault injection and the survival machinery.

Every test here injects a fault through ``repro.engine.resilience`` and
asserts the engine's RECOVERY, not just the failure: crash-consistent
checkpoints fall back to the newest intact step with bitwise trajectory
parity, the health guard skips poisoned updates deterministically, loader
crashes retry on a bit-identical rebuilt stream, and serve() degrades
per-request (timeout / rejected / failed) without ever raising."""
import os

import numpy as np
import pytest

from repro.engine import (EventLog, Fault, FaultInjector, HealthGuard,
                          Request, RunSpec, parse_faults)
from repro.engine import resilience as rsl

SPEC = RunSpec(arch="stablelm-1.6b", reduced=True, mesh_data=1, mesh_model=1)
TRAIN_KW = dict(rule="cdp_v2", batch=2, seq=16, log_every=100, verbose=False)


def _params_equal(a, b, msg=""):
    import jax
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


# ---------------------------------------------------------------------------
# FaultInjector: parsing + deterministic replay
# ---------------------------------------------------------------------------

def test_parse_faults_clauses():
    faults = parse_faults("nan_loss@3,loader%0.25:1.5,ckpt_io@4:2")
    assert faults[0] == Fault(site="nan_loss", step=3)
    assert faults[1].site == "loader" and faults[1].prob == 0.25 \
        and faults[1].arg == 1.5
    assert faults[2].site == "ckpt_io" and faults[2].step == 4 \
        and faults[2].count == 2
    assert parse_faults("on") == [] and parse_faults("") == []
    with pytest.raises(ValueError):
        parse_faults("nan_loss")            # no @step / %prob
    with pytest.raises(ValueError):
        parse_faults("not_a_site@3")


def test_injector_exact_step_fires_once():
    inj = FaultInjector("nan_loss@3")
    assert inj.fires("nan_loss", 2) is None
    assert inj.fires("loader", 3) is None    # wrong site
    assert inj.fires("nan_loss", 3) is not None
    assert inj.fires("nan_loss", 3) is None  # count charge burnt
    assert inj.log == [("nan_loss", 3)]


def test_injector_probabilistic_replay_is_seeded():
    def trace(seed):
        inj = FaultInjector("loader%0.3", seed=seed)
        return [inj.fires("loader", s) is not None for s in range(200)]

    a, b = trace(1), trace(1)
    assert a == b, "same seed must replay the same fire pattern"
    assert sum(a) == 1, "count=1: even a probabilistic fault fires once"


def test_injector_from_spec_passthrough():
    assert FaultInjector.from_spec(None) is None
    assert FaultInjector.from_spec("off") is None
    inj = FaultInjector("nan_loss@1")
    assert FaultInjector.from_spec(inj) is inj
    assert FaultInjector.from_spec("on").faults == []


def test_health_guard_nonfinite_spike_and_warmup():
    g = HealthGuard(spike_factor=10.0, warmup=2)
    assert g.check(float("nan")) == "nonfinite"
    assert g.check(100.0) == "ok"            # warmup: spikes are legal
    assert g.check(1.0) == "ok"
    assert g.check(1.0) == "ok"
    assert g.check(1e6) == "spike"
    ema = g.ema
    assert g.check(1e6) == "spike" and g.ema == ema, \
        "a spike must not fold into the EMA baseline"
    g.reset()
    assert g.ema is None and g.check(1e6) == "ok"


def test_event_log_query():
    log = EventLog()
    log.append("skip", 3, reason="nonfinite")
    log.append("rollback", 5, to_step=4)
    log.append("skip", 7, reason="spike")
    assert len(log) == 3
    assert [r["step"] for r in log.of("skip")] == [3, 7]
    assert log.of("rollback")[0]["to_step"] == 4


# ---------------------------------------------------------------------------
# Checkpoint: commit manifests, fallback, GC, tmp sweep, IO retry
# ---------------------------------------------------------------------------

def _tree(v):
    return {"w": np.full((4, 3), v, np.float32),
            "b": np.arange(3).astype(np.int32) + v}


def test_checkpoint_manifest_detects_truncation(tmp_path):
    from repro import checkpoint as ckpt
    d = str(tmp_path)
    ckpt.save(d, 1, _tree(1))
    assert ckpt.verify_step(d, 1) == (True, "ok")
    path = os.path.join(d, "step_00000001.npz")
    with open(path, "r+b") as fh:
        fh.truncate(os.path.getsize(path) // 2)
    intact, reason = ckpt.verify_step(d, 1)
    assert not intact and "mismatch" in reason


def test_checkpoint_restore_falls_back_to_newest_intact(tmp_path):
    from repro import checkpoint as ckpt
    d = str(tmp_path)
    for s in (1, 2, 3):
        ckpt.save(d, s, _tree(s))
    path = os.path.join(d, "step_00000003.npz")
    with open(path, "r+b") as fh:
        fh.truncate(os.path.getsize(path) // 2)

    fallbacks = []
    with pytest.warns(RuntimeWarning):
        tree, step = ckpt.restore(d, _tree(0),
                                  on_fallback=lambda s, r: fallbacks.append(s))
    assert step == 2 and fallbacks == [3]
    np.testing.assert_array_equal(tree["w"], _tree(2)["w"])

    # an EXPLICIT step is strict: the caller asked for that exact state
    with pytest.raises(ValueError):
        ckpt.restore(d, _tree(0), step=3)
    # every step broken -> FileNotFoundError, not a silent bad restore
    for s in (1, 2):
        p = os.path.join(d, f"step_{s:08d}.npz")
        with open(p, "r+b") as fh:
            fh.truncate(1)
    with pytest.warns(RuntimeWarning):
        with pytest.raises(FileNotFoundError):
            ckpt.restore(d, _tree(0))


def test_checkpoint_keep_last_gc_and_tmp_sweep(tmp_path):
    from repro import checkpoint as ckpt
    d = str(tmp_path)
    junk = os.path.join(d, "step_00000009.npz.tmp.npz")
    open(junk, "wb").write(b"killed mid-save")
    for s in (1, 2, 3, 4):
        ckpt.save(d, s, _tree(s), keep_last=2)
    assert not os.path.exists(junk), "stale tmp junk must be swept on save"
    assert ckpt.list_steps(d) == [3, 4]
    assert ckpt.latest_step(d) == 4
    manifests = [f for f in os.listdir(d) if f.endswith(".manifest.json")]
    assert len(manifests) == 2, "GC must drop the manifest with the npz"
    with pytest.raises(ValueError):
        ckpt.gc_old_steps(d, 0)


def test_checkpoint_save_retries_transient_io(tmp_path):
    from repro import checkpoint as ckpt
    d = str(tmp_path)
    # two failing attempts, then success (retries=3 covers it)
    inj = FaultInjector([Fault(site="ckpt_io", step=5, count=2)])
    ckpt.save(d, 5, _tree(5), injector=inj, backoff_s=0.001)
    assert ckpt.verify_step(d, 5) == (True, "ok")
    assert inj.log == [("ckpt_io", 5), ("ckpt_io", 5)]
    # a persistent failure exhausts the retries and surfaces
    inj = FaultInjector([Fault(site="ckpt_io", step=6, count=99)])
    with pytest.raises(OSError):
        ckpt.save(d, 6, _tree(6), injector=inj, retries=1, backoff_s=0.001)


# ---------------------------------------------------------------------------
# ShardedLoader: a crashed worker surfaces, never hangs
# ---------------------------------------------------------------------------

def _crashing_iter(good):
    for i in range(good):
        yield {"x": np.full((2,), i, np.float32)}
    raise ValueError("worker blew up")


def test_loader_reraises_worker_exception(tmp_path):
    from repro.data import ShardedLoader
    loader = ShardedLoader(_crashing_iter(2), shardings=None, depth=2)
    got = [np.asarray(next(loader)["x"])[0] for _ in range(2)]
    assert got == [0.0, 1.0], "prefetched good batches drain first"
    with pytest.raises(ValueError, match="worker blew up"):
        next(loader)
    # a consumer retry loop must keep failing fast, not block forever
    with pytest.raises(ValueError, match="worker blew up"):
        next(loader)
    loader.close()                          # clean join after the crash
    assert not loader._thread.is_alive()


def test_loader_clean_exhaustion_raises_stopiteration():
    from repro.data import ShardedLoader
    loader = ShardedLoader(iter([{"x": np.zeros(2, np.float32)}]),
                           shardings=None)
    assert next(loader) is not None
    with pytest.raises(StopIteration):
        next(loader)
    with pytest.raises(StopIteration):
        next(loader)
    loader.close()


def test_train_engine_survives_loader_crash():
    """An injected loader-worker crash at step 2 is retried on a rebuilt
    stream; the retried batch is bit-identical, so the run matches a
    fault-free baseline bitwise."""
    from repro.engine import TrainEngine
    base = TrainEngine(SPEC, steps=4, donate=False, **TRAIN_KW).run()
    eng = TrainEngine(SPEC, steps=4, resilience="loader@2", **TRAIN_KW)
    state = eng.run()
    errs = eng.events.of("loader_error")
    assert len(errs) == 1 and errs[0]["step"] == 2
    assert not eng.events.of("skip")
    _params_equal(base["params"], state["params"],
                  "loader-crash retry must not perturb the trajectory")


# ---------------------------------------------------------------------------
# TrainEngine: NaN guard, rollback, crash-resume parity
# ---------------------------------------------------------------------------

def test_nan_injection_skips_once_and_is_deterministic():
    """Acceptance: NaN at step k -> finite final loss with exactly one
    skip event; same seed -> same skip steps -> same final params."""
    from repro.engine import TrainEngine

    def run():
        eng = TrainEngine(SPEC, steps=6, resilience="nan_loss@3",
                          **TRAIN_KW)
        state = eng.run()
        return eng, state

    eng_a, state_a = run()
    skips = eng_a.events.of("skip")
    assert len(skips) == 1 and skips[0]["step"] == 3 \
        and skips[0]["reason"] == "nonfinite"
    assert eng_a.events.of("inject")[0]["site"] == "nan_loss"
    final_loss = eng_a.history[-1]["loss"]
    assert np.isfinite(final_loss), "guarded run must end finite"
    import jax
    assert all(np.all(np.isfinite(np.asarray(p)))
               for p in jax.tree.leaves(state_a["params"])
               if np.issubdtype(np.asarray(p).dtype, np.floating)), \
        "the skipped NaN update must not leak into the params"
    assert int(state_a["step"]) == 6, \
        "a skipped update still advances the step counter"

    eng_b, state_b = run()
    assert [r["step"] for r in eng_b.events.of("skip")] == [3]
    _params_equal(state_a["params"], state_b["params"],
                  "same seed + same faults must replay bitwise")


def test_spike_injection_skips_update():
    from repro.engine import TrainEngine
    eng = TrainEngine(SPEC, steps=8, resilience="loss_spike@6:1e4",
                      **TRAIN_KW)
    eng.run()
    skips = eng.events.of("skip")
    assert len(skips) == 1 and skips[0]["step"] == 6 \
        and skips[0]["reason"] == "spike"
    assert np.isfinite(eng.history[-1]["loss"])


def test_rollback_after_consecutive_bad_steps(tmp_path):
    """guard_max_bad consecutive bad steps roll back to the newest intact
    checkpoint and the run still finishes finite."""
    from repro.engine import TrainEngine
    eng = TrainEngine(SPEC, steps=8, ckpt_dir=str(tmp_path / "ck"),
                      ckpt_every=2, guard_max_bad=2,
                      resilience="nan_loss@4,nan_loss@5", **TRAIN_KW)
    state = eng.run()
    rb = eng.events.of("rollback")
    assert len(rb) == 1 and rb[0]["step"] == 5 and rb[0]["to_step"] == 4
    assert [r["step"] for r in eng.events.of("skip")] == [4, 5]
    assert np.isfinite(eng.history[-1]["loss"])
    assert int(state["step"]) == 8


def test_rollback_without_checkpoint_raises():
    from repro.engine import TrainEngine
    eng = TrainEngine(SPEC, steps=6, guard_max_bad=1,
                      resilience="nan_loss@2", **TRAIN_KW)
    with pytest.raises(RuntimeError, match="no intact checkpoint"):
        eng.run()
    assert eng.events.of("rollback_failed")


def test_crash_resume_parity_after_truncated_checkpoint(tmp_path):
    """Acceptance: truncate the NEWEST checkpoint mid-run; the next engine
    resumes from the previous intact step and the resumed trajectory is
    bitwise identical to an uninterrupted run."""
    from repro import checkpoint as ckpt
    from repro.engine import TrainEngine
    full = TrainEngine(SPEC, steps=6, donate=False, **TRAIN_KW).run()

    d = str(tmp_path / "ck")
    TrainEngine(SPEC, steps=6, ckpt_dir=d, ckpt_every=2, **TRAIN_KW).run()
    assert ckpt.list_steps(d) == [2, 4, 6]
    path = os.path.join(d, "step_00000006.npz")
    with open(path, "r+b") as fh:          # kill -9 / disk corruption
        fh.truncate(os.path.getsize(path) // 2)

    resumed = TrainEngine(SPEC, steps=6, ckpt_dir=d, ckpt_every=2,
                          **TRAIN_KW)
    with pytest.warns(RuntimeWarning):
        resumed.build()
    assert resumed.start_step == 4, "must fall back to the intact step"
    fb = resumed.events.of("ckpt_fallback")
    assert len(fb) == 1 and fb[0]["step"] == 6
    state = resumed.run()
    _params_equal(full["params"], state["params"],
                  "resume-from-fallback must replay the lost steps bitwise")
    assert int(state["step"]) == 6


def test_ckpt_truncate_injection_forces_fallback(tmp_path):
    """The ckpt_truncate fault corrupts the file AFTER the commit — the
    next restore must detect it via the manifest and fall back."""
    from repro import checkpoint as ckpt
    from repro.engine import TrainEngine
    d = str(tmp_path / "ck")
    eng = TrainEngine(SPEC, steps=4, ckpt_dir=d, ckpt_every=2,
                      resilience="ckpt_truncate@4", **TRAIN_KW)
    eng.run()
    assert ckpt.list_steps(d) == [2, 4]
    assert ckpt.latest_intact_step(d) == 2
    resumed = TrainEngine(SPEC, steps=4, ckpt_dir=d, ckpt_every=2,
                          **TRAIN_KW)
    with pytest.warns(RuntimeWarning):
        resumed.build()
    assert resumed.start_step == 2


def test_zero_cdp_guard_skip_and_rollback_bitwise(subproc):
    """Guard-skip and rollback on stage-sharded f32 masters (--plan
    zero_cdp): a NaN-skip replays bitwise against a same-seed clean run,
    and a guard_max_bad rollback restores the [N, chunk] stages + momentum
    bitwise — the recovery moves tested on dp hold on the ring too."""
    subproc("""
import tempfile
import numpy as np
from repro.engine import RunSpec, TrainEngine

SPEC = RunSpec(arch="stablelm-1.6b", reduced=True, plan="zero_cdp",
               mesh_data=2, mesh_model=1)
KW = dict(batch=4, seq=16, log_every=100, verbose=False)

def stages_equal(a, b, msg):
    np.testing.assert_array_equal(np.asarray(a["params"]["stages"]),
                                  np.asarray(b["params"]["stages"]),
                                  err_msg=msg)
    np.testing.assert_array_equal(np.asarray(a["opt"]["mom"]["stages"]),
                                  np.asarray(b["opt"]["mom"]["stages"]),
                                  err_msg=msg)

# NaN-skip: the poisoned update is dropped, the trajectory stays on the
# clean run's rail EXCEPT the skipped step, and same seed replays bitwise
eng = TrainEngine(SPEC, steps=6, resilience="nan_loss@3", **KW)
state = eng.run()
skips = eng.events.of("skip")
assert len(skips) == 1 and skips[0]["step"] == 3 \\
    and skips[0]["reason"] == "nonfinite"
assert np.all(np.isfinite(np.asarray(state["params"]["stages"])))
assert int(state["step"]) == 6
rep = TrainEngine(SPEC, steps=6, resilience="nan_loss@3", **KW).run()
stages_equal(state, rep, "same seed + same fault must replay bitwise")

# Rollback: two consecutive NaNs trip guard_max_bad=2 -> restore the
# newest intact checkpoint (step 2) into the stage-sharded layout and
# replay; the finish must equal a same-seed CLEAN run bitwise (the
# replayed stream is bit-identical, the bad updates never landed)
d = tempfile.mkdtemp()
clean = TrainEngine(SPEC, steps=6, donate=False, **KW).run()
eng = TrainEngine(SPEC, steps=6, ckpt_dir=d, ckpt_every=2,
                  guard_max_bad=2,
                  resilience="nan_loss@3,nan_loss@4", **KW)
state = eng.run()
rb = eng.events.of("rollback")
assert len(rb) == 1 and rb[0]["step"] == 4 and rb[0]["to_step"] == 2
assert [r["step"] for r in eng.events.of("skip")] == [3, 4]
stages_equal(clean, state,
             "rollback + bit-identical replay must match the clean run")
assert int(state["step"]) == 6
print("OK")
""", n_devices=2, timeout=900)


# ---------------------------------------------------------------------------
# ServeEngine: graceful degradation
# ---------------------------------------------------------------------------

def _prompt(rng, vocab, n):
    return rng.integers(0, vocab, size=n).astype(np.int32)


@pytest.fixture(scope="module")
def serve_engine():
    from repro.engine import ServeEngine
    eng = ServeEngine(SPEC, batch=2, prompt_len=12, gen=8, verbose=False)
    eng.build()
    return eng


def _reqs(vocab, n=3, seed=9, max_gen=6):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=_prompt(rng, vocab, 6), max_gen=max_gen)
            for i in range(n)]


def test_serve_poison_quarantine_isolates_coresidents(serve_engine):
    """Acceptance: one poison request -> status='failed' for it, co-resident
    requests complete status='ok' with BITWISE-identical tokens, serve()
    never raises."""
    vocab = serve_engine.cfg.vocab_size
    clean = serve_engine.serve(_reqs(vocab), max_slots=2)
    assert all(r.status == "ok" for r in clean["requests"])

    serve_engine.injector = FaultInjector("poison_request@1",
                                          seed=SPEC.seed)
    try:
        res = serve_engine.serve(_reqs(vocab), max_slots=2)
    finally:
        serve_engine.injector = None
    by_rid = {r.rid: r for r in res["requests"]}
    assert by_rid[1].status == "failed"
    assert "non-finite" in by_rid[1].error
    assert len(by_rid[1].tokens) == 0, \
        "a quarantined request must not serve garbage tokens"
    assert res["metrics"]["status_counts"]["failed"] == 1
    assert res["engine_events"].of("quarantine")[0]["rid"] == 1
    for rid in (0, 2):
        assert by_rid[rid].status == "ok"
        np.testing.assert_array_equal(
            by_rid[rid].tokens, {r.rid: r for r in clean["requests"]}[rid].tokens,
            err_msg=f"co-resident {rid} perturbed by the quarantined row")


def test_serve_deadline_times_out_in_queue(serve_engine):
    """max_slots=1: the request stuck behind a long generation expires in
    the queue with status='timeout' and no tokens; the long one is 'ok'."""
    vocab = serve_engine.cfg.vocab_size
    rng = np.random.default_rng(2)
    long_r = Request(rid=0, prompt=_prompt(rng, vocab, 6), max_gen=8)
    stuck = Request(rid=1, prompt=_prompt(rng, vocab, 6), max_gen=2,
                    deadline_steps=3)
    res = serve_engine.serve([long_r, stuck], max_slots=1)
    by_rid = {r.rid: r for r in res["requests"]}
    assert by_rid[0].status == "ok" and len(by_rid[0].tokens) == 8
    assert by_rid[1].status == "timeout"
    assert "queue" in by_rid[1].error and len(by_rid[1].tokens) == 0


def test_serve_deadline_evicts_live_with_partial_tokens(serve_engine):
    vocab = serve_engine.cfg.vocab_size
    rng = np.random.default_rng(4)
    prompt = _prompt(rng, vocab, 6)
    base = serve_engine.serve([Request(rid=0, prompt=prompt, max_gen=8)],
                              max_slots=2)["requests"][0]
    cut = serve_engine.serve([Request(rid=0, prompt=prompt, max_gen=8)],
                             max_slots=2, deadline_steps=4)["requests"][0]
    assert cut.status == "timeout"
    assert 0 < len(cut.tokens) < 8
    np.testing.assert_array_equal(
        cut.tokens, base.tokens[:len(cut.tokens)],
        err_msg="partial tokens must be a prefix of the full generation")


def test_serve_bounded_admission_queue(serve_engine):
    vocab = serve_engine.cfg.vocab_size
    reqs = _reqs(vocab, n=3, max_gen=4)
    res = serve_engine.serve(reqs, max_slots=1, queue_limit=1)
    by_rid = {r.rid: r for r in res["requests"]}
    assert by_rid[0].status == "ok"
    rejected = [r for r in res["requests"] if r.status == "rejected"]
    assert len(rejected) == 2
    assert all("queue full" in r.error for r in rejected)


def test_serve_max_steps_truncates_gracefully(serve_engine):
    vocab = serve_engine.cfg.vocab_size
    res = serve_engine.serve(_reqs(vocab, n=2, max_gen=8), max_slots=1,
                             max_steps=3)
    assert res["metrics"]["truncated"] is True
    by_rid = {r.rid: r for r in res["requests"]}
    assert by_rid[0].status == "timeout" and 0 < len(by_rid[0].tokens) <= 3
    assert by_rid[1].status == "timeout" and len(by_rid[1].tokens) == 0
