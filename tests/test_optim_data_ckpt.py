"""Substrate tests: optimizers, schedules, data pipeline, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.data import ShardedLoader, lm_batch_iterator, make_lm_data
from repro.data.synthetic import make_classification_data
from repro.optim import adamw, cosine_warmup, sgd_momentum, step_drops


def test_sgd_momentum_matches_reference():
    opt = sgd_momentum(momentum=0.9, weight_decay=0.0)
    p = {"w": jnp.array([1.0, 2.0])}
    s = opt.init(p)
    g = {"w": jnp.array([0.5, -1.0])}
    p1, s1 = opt.update(g, s, p, 0.1)
    np.testing.assert_allclose(p1["w"], [1.0 - 0.05, 2.0 + 0.1])
    p2, s2 = opt.update(g, s1, p1, 0.1)
    # momentum: m2 = 0.9*0.5+0.5 = 0.95
    np.testing.assert_allclose(p2["w"][0], p1["w"][0] - 0.1 * 0.95, rtol=1e-6)


def test_adamw_decreases_quadratic():
    opt = adamw(weight_decay=0.0)
    p = {"w": jnp.ones((8,))}
    s = opt.init(p)
    for _ in range(50):
        g = {"w": p["w"]}
        p, s = opt.update(g, s, p, 0.1)
    assert float(jnp.abs(p["w"]).max()) < 0.2


def test_step_drops_schedule():
    f = step_drops(1.0, [10, 20], 0.1)
    assert float(f(jnp.int32(0))) == pytest.approx(1.0)
    assert float(f(jnp.int32(10))) == pytest.approx(0.1)
    assert float(f(jnp.int32(25))) == pytest.approx(0.01)


def test_cosine_warmup():
    f = cosine_warmup(1.0, 10, 100)
    assert float(f(jnp.int32(0))) == pytest.approx(0.0)
    assert float(f(jnp.int32(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(f(jnp.int32(100))) == pytest.approx(0.1, abs=1e-2)


def test_lm_data_deterministic_and_learnable():
    t1 = make_lm_data(100, 5000, seed=3)
    t2 = make_lm_data(100, 5000, seed=3)
    np.testing.assert_array_equal(t1, t2)
    assert t1.min() >= 0 and t1.max() < 100
    # Markov structure: conditional entropy << marginal entropy
    joint = np.zeros((100, 100))
    for a, b in zip(t1[:-1], t1[1:]):
        joint[a, b] += 1


def test_batch_iterator_shapes():
    toks = make_lm_data(50, 10_000)
    it = lm_batch_iterator(toks, batch=4, seq=16)
    b = next(it)
    assert b["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_sharded_loader_prefetch():
    def gen():
        for i in range(5):
            yield {"x": np.full((2, 2), i)}
    loader = ShardedLoader(gen(), shardings=None, depth=2)
    vals = [int(next(loader)["x"][0, 0]) for _ in range(5)]
    assert vals == list(range(5))
    loader.close()


def test_checkpoint_roundtrip(tmp_path):
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "b": jnp.zeros((3,), jnp.bfloat16)},
            "step": jnp.int32(7)}
    ckpt.save(str(tmp_path), 7, tree)
    ckpt.save(str(tmp_path), 12, tree)
    assert ckpt.latest_step(str(tmp_path)) == 12
    restored, step = ckpt.restore(str(tmp_path), tree)
    assert step == 12
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))
    assert restored["params"]["b"].dtype == jnp.bfloat16


def test_classification_data_separable():
    x, y = make_classification_data(500, dim=32, classes=5)
    assert x.shape == (500, 32) and set(np.unique(y)) <= set(range(5))
