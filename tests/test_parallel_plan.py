"""ParallelPlan API: registry resolution/validation, legacy-flag aliasing
(with DeprecationWarnings), and the ZeRO-CDP execution path on a real
reduced model — DP-trajectory parity and the paper's HLO communication
claim (collective-permute stage movement, no per-stage all-gather)."""
import warnings

import pytest

from repro.core.trainer import TrainerConfig
from repro.engine import RunSpec
from repro.parallel import (ParallelPlan, available_plans, get_plan,
                            plan_from_legacy_flags, resolve_plan)


# ---------------------------------------------------------------------------
# Registry / resolution (jax-free)
# ---------------------------------------------------------------------------

def test_registry_has_all_paper_strategies():
    assert set(available_plans()) >= {"dp", "cdp_v1", "cdp_v2", "cdp_random",
                                      "zero1_ring", "zero_cdp"}


def test_resolve_plan_names_and_objects():
    assert resolve_plan("dp").sync == "psum"
    assert resolve_plan(None).name == "cdp_v2"          # engine default
    p = resolve_plan(ParallelPlan(name="custom", rule="cdp_v1", sync="psum"))
    assert p.name == "custom"
    zc = get_plan("zero_cdp")
    assert (zc.rule, zc.sync, zc.placement) == \
        ("cdp_v1", "stream", "stage_sharded")


def test_bad_plan_names_fail_fast():
    with pytest.raises(ValueError, match="unknown parallel plan"):
        resolve_plan("zero_cdp_typo")
    with pytest.raises(ValueError, match="unknown parallel plan"):
        RunSpec(arch="stablelm-1.6b", plan="nope").resolve_plan()
    with pytest.raises(ValueError, match="unknown parallel plan"):
        TrainerConfig(plan="nope")
    # invalid field combos are rejected at validate()
    with pytest.raises(ValueError, match="unknown rule"):
        ParallelPlan(name="x", rule="sgd").validate()
    with pytest.raises(ValueError, match="imply each other"):
        ParallelPlan(name="x", sync="stream").validate()
    with pytest.raises(ValueError, match="streaming supports"):
        get_plan("zero_cdp").with_(rule="cdp_v2")
    with pytest.raises(ValueError, match="zero_axis"):
        get_plan("zero_cdp").with_(zero_axis="model")


def test_engine_rejects_bad_plan_before_jax_work():
    from repro.engine import TrainEngine
    spec = RunSpec(arch="stablelm-1.6b", reduced=True)
    with pytest.raises(ValueError, match="unknown parallel plan"):
        TrainEngine(spec, plan="not_a_plan")
    with pytest.raises(ValueError, match="not both"):
        TrainEngine(spec, plan="dp", rule="cdp_v2")
    # a trainer= override carries its own plan; a conflicting plan=/rule=
    # must not be silently ignored
    with pytest.raises(ValueError, match="carries its own plan"):
        TrainEngine(spec, plan="zero_cdp", trainer=TrainerConfig(plan="dp"))


def test_zero_cdp_mesh_validation():
    from repro.compat import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="needs a 'data' axis"):
        get_plan("zero_cdp").validate_mesh(mesh)
    with pytest.raises(ValueError, match="pod axis"):
        get_plan("zero_cdp").with_(min_data=1).validate_mesh(
            mesh, pod_axis="pod")


# ---------------------------------------------------------------------------
# Legacy TrainerConfig flags -> plan aliasing (deprecated)
# ---------------------------------------------------------------------------

def test_legacy_rule_flag_warns_and_maps():
    with pytest.warns(DeprecationWarning, match="rule="):
        tc = TrainerConfig(rule="dp")
    assert tc.resolved_plan().name == "dp"
    assert tc.resolved_plan().sync == "psum"
    with pytest.warns(DeprecationWarning):
        tc = TrainerConfig(rule="cdp_v1")
    assert tc.resolved_plan().sync == "ring"


def test_legacy_zero1_ring_flag_warns_and_maps():
    with pytest.warns(DeprecationWarning, match="zero1_ring="):
        tc = TrainerConfig(rule="cdp_v2", zero1_ring=True)
    plan = tc.resolved_plan()
    assert plan.sync == "zero1_ring" and plan.placement == "zero1"
    assert plan.rule == "cdp_v2"


def test_legacy_ring_grads_flag_warns_and_maps():
    with pytest.warns(DeprecationWarning, match="ring_grads="):
        tc = TrainerConfig(rule="cdp_v2", ring_grads=False)
    assert tc.resolved_plan().sync == "psum"
    assert tc.resolved_plan().rule == "cdp_v2"


def test_legacy_zero_axis_flag_maps_onto_plan():
    with pytest.warns(DeprecationWarning, match="zero_axis="):
        tc = TrainerConfig(rule="dp", zero_axis="data")
    assert tc.resolved_plan().zero_axis == "data"


def test_plan_plus_legacy_flags_rejected():
    with pytest.raises(ValueError, match="not both"):
        TrainerConfig(plan="dp", rule="cdp_v2")


def test_plain_trainer_config_neither_warns_nor_fails():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        tc = TrainerConfig(plan="cdp_v2")
        tc2 = TrainerConfig()
    assert tc.resolved_plan().name == tc2.resolved_plan().name == "cdp_v2"
    assert plan_from_legacy_flags() == tc.resolved_plan()


# ---------------------------------------------------------------------------
# ZeRO-CDP on a real reduced model (multi-device subprocesses)
# ---------------------------------------------------------------------------

def test_zero_cdp_matches_dp_trajectory(subproc):
    """Parity on a real reduced model: with rule='dp' the streamed path is
    numerically DP (same params); with the default cdp_v1 staleness the loss
    trajectory matches DP within the 1-step-delay tolerance."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh
from repro.configs import get_reduced
from repro.core.trainer import TrainerConfig, init_state, jit_train_step
from repro.data import make_lm_data, lm_batch_iterator
from repro.models import init_params
from repro.optim import sgd_momentum
from repro.parallel import get_plan
from repro.parallel.zero_cdp import params_from_state

n = 4
mesh = make_mesh((n, 2), ("data", "model"))
cfg = get_reduced("stablelm-1.6b")
params = init_params(cfg, jax.random.PRNGKey(0))
opt = sgd_momentum(0.9)
it = lm_batch_iterator(make_lm_data(cfg.vocab_size, 50_000), 8, 16)
batches = [{k: jnp.asarray(v) for k, v in next(it).items()} for _ in range(8)]

losses = {}
states = {}
for plan in ("dp", get_plan("zero_cdp").with_(rule="dp"), "zero_cdp"):
    tr = TrainerConfig(plan=plan, lr_schedule=lambda s: 0.05, donate=False)
    state = init_state(cfg, tr, params, opt, mesh=mesh)
    jt, _, _ = jit_train_step(cfg, tr, mesh, opt, state, batches[0])
    name = tr.resolved_plan().name + "/" + tr.resolved_plan().rule
    ls = []
    for b in batches:
        state, met = jt(state, b)
        ls.append(float(met["loss"]))
    losses[name] = ls
    states[name] = state

# rule='dp' through the streamed stage ring == plain DP, param-for-param
pz = params_from_state(cfg, states["zero_cdp/dp"], n)
for a, b in zip(jax.tree.leaves(states["dp/dp"]["params"]), jax.tree.leaves(pz)):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=2e-5, rtol=1e-5)

# default zero_cdp (cdp_v1): reported loss lags ONE step behind DP (the
# cyclic delay); shifted trajectories agree closely and it trains
dp, zc = losses["dp/dp"], losses["zero_cdp/cdp_v1"]
shifted = np.abs(np.asarray(zc[1:]) - np.asarray(dp[:-1]))
assert shifted.max() < 0.15, (dp, zc)
assert np.mean(zc[-4:]) < np.mean(zc[:4]) - 0.02, zc

# grad_comm_dtype: chunks ride the ring in bf16 (both directions through
# the cast transpose) and stay within bf16 rounding of the f32 stream
tr16 = TrainerConfig(plan="zero_cdp", lr_schedule=lambda s: 0.05,
                     donate=False, grad_comm_dtype="bfloat16")
st16 = init_state(cfg, tr16, params, opt, mesh=mesh)
jt16, _, _ = jit_train_step(cfg, tr16, mesh, opt, st16, batches[0])
l16 = []
for b in batches[:4]:
    st16, m16 = jt16(st16, b)
    l16.append(float(m16["loss"]))
assert np.abs(np.asarray(l16) - np.asarray(zc[:4])).max() < 0.02, (l16, zc)
print("ZERO-CDP PARITY OK", dp[-1], zc[-1], "bf16 ring", l16[-1])
""", n_devices=8, timeout=1200)


def test_zero_cdp_hlo_streams_without_all_gather(subproc):
    """Acceptance: the compiled zero_cdp step contains collective-permute
    for stage movement and NO all-gather broadcast — and no gradient
    all-reduce burst either (scalar loss/metric pmeans are the only
    all-reduces, orders of magnitude below the parameter bytes)."""
    subproc("""
import jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.configs import get_reduced
from repro.core.trainer import TrainerConfig, init_state, jit_train_step
from repro.launch.roofline import parse_collectives

n = 4
mesh = make_mesh((n, 1), ("data", "model"))
cfg = get_reduced("stablelm-1.6b")
from repro.models import init_params
from repro.optim import sgd_momentum
params = init_params(cfg, jax.random.PRNGKey(0))
opt = sgd_momentum(0.9)
batch = {"tokens": jnp.zeros((8, 16), jnp.int32),
         "targets": jnp.zeros((8, 16), jnp.int32)}
tr = TrainerConfig(plan="zero_cdp", lr_schedule=lambda s: 0.05, donate=False)
state = init_state(cfg, tr, params, opt, mesh=mesh)
jt, _, _ = jit_train_step(cfg, tr, mesh, opt, state, batch)
stats = parse_collectives(jt.lower(state, batch).compile().as_text())
print("zero_cdp collectives:", stats.op_counts)

# unsupported knobs fail fast instead of silently dropping the lever
from repro.core.trainer import make_train_step
try:
    make_train_step(cfg, TrainerConfig(plan="zero_cdp", seq_parallel=True),
                    mesh, opt)
    raise SystemExit("seq_parallel + zero_cdp should have raised")
except ValueError as e:
    assert "seq_parallel" in str(e)
# stage movement: >= n-1 permute hops forward + the transposed ring back
assert stats.op_counts["collective-permute"] >= 2 * (n - 1)
# the ZeRO-DP broadcast the paper removes:
assert stats.op_counts["all-gather"] == 0
# no gradient merge collective: only scalar loss/metric pmeans all-reduce
chunk_bytes = 4 * state["params"]["stages"].shape[1]
assert stats.max_by_type["all-reduce"] < chunk_bytes // 100
print("HLO STREAMING CLAIMS OK")
""", n_devices=4, timeout=1200)


def test_zero_cdp_through_train_engine(subproc):
    """--plan zero_cdp drives RunSpec -> TrainEngine -> launch end-to-end,
    and checkpoint resume works on the stage-sharded state."""
    subproc("""
import numpy as np, tempfile, jax
from repro.engine import RunSpec, TrainEngine

spec = RunSpec(arch="stablelm-1.6b", reduced=True, plan="zero_cdp",
               mesh_data=4, mesh_model=1)
kw = dict(steps=4, batch=4, seq=16, log_every=1, verbose=False)
full = TrainEngine(spec, **kw)
s_full = full.run()
assert set(s_full["params"]) == {"stages"}
assert s_full["params"]["stages"].shape[0] == 4

with tempfile.TemporaryDirectory() as d:
    part = TrainEngine(spec, ckpt_dir=d, ckpt_every=2, **kw)
    part.run(steps=2)
    resumed = TrainEngine(spec, ckpt_dir=d, ckpt_every=2, **kw)
    resumed.build()
    assert resumed.start_step == 2
    s_res = resumed.run()
for a, b in zip(jax.tree.leaves(s_full["params"]),
                jax.tree.leaves(s_res["params"])):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("ENGINE ZERO-CDP OK")
""", n_devices=4, timeout=1200)
