"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU): shape/dtype
sweeps + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyp_compat import given, settings, st

from repro.kernels import ops, ref
from repro.models.attention import blockwise_attention


def _mk(key, shape, dt):
    return jax.random.normal(key, shape, dt)


FLASH_CASES = [
    # B, Sq, Sk, H, KV, dh, causal, window, bq, bk, dtype
    (2, 128, 128, 4, 2, 32, True, 0, 64, 64, jnp.float32),
    (1, 100, 100, 4, 4, 16, True, 0, 32, 32, jnp.float32),
    (2, 64, 64, 8, 2, 64, True, 30, 32, 32, jnp.bfloat16),
    (1, 128, 128, 2, 1, 32, False, 0, 64, 64, jnp.float32),
    (1, 96, 160, 4, 1, 16, False, 0, 32, 64, jnp.float32),
    (2, 128, 128, 4, 2, 32, True, 64, 128, 128, jnp.float32),
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_vs_ref(case):
    B, Sq, Sk, H, KV, dh, causal, window, bq, bk, dt = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _mk(ks[0], (B, Sq, H, dh), dt)
    k = _mk(ks[1], (B, Sk, KV, dh), dt)
    v = _mk(ks[2], (B, Sk, KV, dh), dt)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              bq=bq, bk=bk, interpret=True)
    qh = jnp.moveaxis(q, 2, 1).reshape(B * H, Sq, dh)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * KV, Sk, dh)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * KV, Sk, dh)
    r = ref.flash_attention_ref(qh, kh, vh, causal=causal, window=window,
                                group=H // KV)
    r = jnp.moveaxis(r.reshape(B, H, Sq, dh), 1, 2)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# Gradient sweeps: jax.grad through the Pallas custom_vjp (dq + dk/dv
# kernels, interpret=True) vs the jnp blockwise VJP vs naive full-matrix
# autodiff — over GQA groups, causal + sliding window, non-block-divisible
# lengths, and bf16.
# ---------------------------------------------------------------------------

def _naive_attention(q, k, v, causal, window):
    """Full-matrix oracle in [B,S,H,dh] layout (plain autodiff reference)."""
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    qh = jnp.moveaxis(q, 2, 1).reshape(B * H, Sq, dh)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * KV, Sk, dh)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * KV, Sk, v.shape[-1])
    r = ref.flash_attention_ref(qh, kh, vh, causal=causal, window=window,
                                group=H // KV)
    return jnp.moveaxis(r.reshape(B, H, Sq, -1), 1, 2)


GRAD_CASES = [
    # B, Sq, Sk, H, KV, dh, causal, window, bq, bk, dtype
    (2, 128, 128, 4, 2, 32, True, 0, 64, 64, jnp.float32),   # GQA, causal
    (1, 100, 100, 4, 4, 16, True, 0, 32, 32, jnp.float32),   # non-divisible
    (1, 96, 160, 4, 1, 16, False, 0, 32, 64, jnp.float32),   # Sq!=Sk, MQA
    (2, 64, 64, 8, 2, 32, True, 30, 32, 32, jnp.float32),    # sliding window
    (2, 128, 128, 4, 2, 32, True, 64, 128, 128, jnp.float32),  # window=block
    (2, 64, 64, 8, 4, 32, True, 0, 32, 32, jnp.bfloat16),    # bf16
]


@pytest.mark.parametrize("case", GRAD_CASES)
def test_flash_attention_grad_vs_references(case):
    B, Sq, Sk, H, KV, dh, causal, window, bq, bk, dt = case
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    q = _mk(ks[0], (B, Sq, H, dh), dt)
    k = _mk(ks[1], (B, Sk, KV, dh), dt)
    v = _mk(ks[2], (B, Sk, KV, dh), dt)
    do = _mk(ks[3], (B, Sq, H, dh), dt)

    def scal(attn_fn):
        return lambda q, k, v: jnp.sum(
            attn_fn(q, k, v).astype(jnp.float32) * do.astype(jnp.float32))

    g_pallas = jax.grad(scal(lambda q, k, v: ops.flash_attention(
        q, k, v, causal=causal, window=window, bq=bq, bk=bk,
        interpret=True)), argnums=(0, 1, 2))(q, k, v)
    g_block = jax.grad(scal(lambda q, k, v: blockwise_attention(
        q, k, v, causal=causal, window=window, block=bk)),
        argnums=(0, 1, 2))(q, k, v)
    g_naive = jax.grad(scal(lambda q, k, v: _naive_attention(
        q, k, v, causal, window)), argnums=(0, 1, 2))(q, k, v)

    tol = 5e-2 if dt == jnp.bfloat16 else 1e-3
    for name, gp, gb, gn in zip("qkv", g_pallas, g_block, g_naive):
        gp, gb, gn = (np.asarray(g, np.float32) for g in (gp, gb, gn))
        np.testing.assert_allclose(gp, gn, atol=tol, rtol=tol,
                                   err_msg=f"pallas vs naive d{name}")
        np.testing.assert_allclose(gb, gn, atol=tol, rtol=tol,
                                   err_msg=f"blockwise vs naive d{name}")


def test_pallas_backend_train_step_all_rules():
    """One CPU training step through the fused-kernel attention path
    (attn_backend='pallas', interpret) under every update rule."""
    import jax.numpy as jnp
    from repro.compat import make_mesh
    from repro.configs import get_reduced
    from repro.core.trainer import TrainerConfig, init_state, jit_train_step
    from repro.models import init_params
    from repro.optim import sgd_momentum

    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_reduced("stablelm-1.6b").with_(attn_backend="pallas")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = sgd_momentum(0.9)
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab_size),
             "targets": jax.random.randint(key, (2, 32), 0, cfg.vocab_size)}
    for rule in ("dp", "cdp_v1", "cdp_v2"):
        tr = TrainerConfig(rule=rule, lr_schedule=lambda s: 0.05,
                           donate=False)
        state = init_state(cfg, tr, params, opt)
        jitted, _, _ = jit_train_step(cfg, tr, mesh, opt, state, batch)
        state, met = jitted(state, batch)
        assert np.isfinite(float(met["loss"])), rule


DECODE_CASES = [
    (2, 256, 4, 2, 32, 128, jnp.float32),
    (1, 100, 8, 8, 16, 64, jnp.float32),
    (4, 512, 8, 4, 64, 256, jnp.bfloat16),
]


@pytest.mark.parametrize("case", DECODE_CASES)
def test_decode_attention_vs_ref(case):
    B, T, H, KV, dh, bk, dt = case
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _mk(ks[0], (B, 1, H, dh), dt)
    k = _mk(ks[1], (B, T, KV, dh), dt)
    v = _mk(ks[2], (B, T, KV, dh), dt)
    cl = jnp.asarray(np.random.default_rng(0).integers(1, T, B), jnp.int32)
    out = ops.decode_attention(q, k, v, cl, bk=bk, interpret=True)
    qh = q[:, 0].reshape(B * H, dh)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * KV, T, dh)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * KV, T, dh)
    r = ref.decode_attention_ref(qh, kh, vh, jnp.repeat(cl, KV),
                                 group=H // KV).reshape(B, 1, H, dh)
    tol = 3e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# decode_attn backend parity: the registry's "pallas" op (flash-decode
# kernel) vs the jnp decode path, through the MODEL layer (gqa_decode /
# mla_decode) across GQA, sliding-window, ring-buffer, and MLA cache cases.
# ---------------------------------------------------------------------------

def test_decode_attention_kernel_window_vs_jnp():
    from repro.models.attention import decode_attention
    B, T, H, KV, dh = 2, 96, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = _mk(ks[0], (B, 1, H, dh), jnp.float32)
    k = _mk(ks[1], (B, T, KV, dh), jnp.float32)
    v = _mk(ks[2], (B, T, KV, dh), jnp.float32)
    cl = jnp.asarray([40, 90], jnp.int32)
    for window in (0, 16, 48):
        o_jnp = decode_attention(q, k, v, cl, window=window, backend="jnp")
        o_pal = ops.decode_attention(q, k, v, cl, window=window, bk=32,
                                     interpret=True)
        np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_jnp),
                                   atol=2e-5, rtol=2e-5,
                                   err_msg=f"window={window}")


def _decode_parity_cfgs(arch):
    from repro.configs import get_reduced
    from repro.kernels.registry import KernelSpec
    cfg = get_reduced(arch)
    return cfg, cfg.with_(kernels=KernelSpec(decode_attn="pallas"))


@pytest.mark.parametrize("case", [
    # (arch, cache_len, n_tokens) — mixtral reduced has window=64, so its
    # cache is always the window-sized ring (gqa_cache_init clamps T to the
    # window); 20 tokens leave it partially filled, 70 wrap it. The linear
    # windowed cache (window < T) is covered at the kernel level above.
    ("stablelm-1.6b", 24, 10),       # plain GQA
    ("deepseek-v3-671b", 24, 10),    # MLA latent cache
    ("mixtral-8x22b", 96, 20),       # windowed ring, partially filled
    ("mixtral-8x22b", 64, 70),       # windowed ring, wraps
])
def test_decode_backend_parity_model_level(case):
    from repro.models import decode_step, init_cache, init_params
    arch, cache_len, n = case
    cfg_jnp, cfg_pal = _decode_parity_cfgs(arch)
    params = init_params(cfg_jnp, jax.random.PRNGKey(0))
    B = 2
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, n), 0,
                              cfg_jnp.vocab_size)

    def run(cfg):
        cache = init_cache(cfg, B, cache_len)
        step = jax.jit(lambda p, b, c: decode_step(cfg, p, b, c))
        outs = []
        for i in range(n):
            logits, cache = step(params, {"token": toks[:, i]}, cache)
            outs.append(np.asarray(logits, np.float32))
        return np.stack(outs)

    np.testing.assert_allclose(run(cfg_pal), run(cfg_jnp), atol=3e-3,
                               rtol=1e-3)


def test_gla_pallas_forward_dispatch_and_grad():
    """ssm_scan="pallas" forward == jnp chunked scan, and jax.grad through
    the dispatch (kernel fwd + jnp-recompute bwd) == plain jnp grad."""
    from repro.configs import get_reduced
    from repro.kernels.registry import KernelSpec
    from repro.models.ssm import _gla_forward, chunked_gla
    cfg_j = get_reduced("xlstm-350m")
    cfg_p = cfg_j.with_(kernels=KernelSpec(ssm_scan="pallas"))
    B, S, H, dk, dv = 1, 48, 2, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    q = _mk(ks[0], (B, S, H, dk), jnp.float32)
    k = _mk(ks[1], (B, S, H, dk), jnp.float32) * 0.3
    v = _mk(ks[2], (B, S, H, dv), jnp.float32)
    g = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))

    y_p = _gla_forward(cfg_p, q, k, v, g, chunk=16)
    y_j = _gla_forward(cfg_j, q, k, v, g, chunk=16)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_j), atol=5e-5,
                               rtol=5e-5)

    def loss(cfg):
        return lambda q, k, v, g: jnp.sum(
            _gla_forward(cfg, q, k, v, g, chunk=16) ** 2)
    g_p = jax.grad(loss(cfg_p), argnums=(0, 1, 2, 3))(q, k, v, g)
    g_j = jax.grad(loss(cfg_j), argnums=(0, 1, 2, 3))(q, k, v, g)
    for name, gp, gj in zip("qkvg", g_p, g_j):
        np.testing.assert_allclose(np.asarray(gp), np.asarray(gj), atol=1e-4,
                                   rtol=1e-4, err_msg=f"d{name}")


# ---------------------------------------------------------------------------
# Block-sparse grid pruning: the Pallas kernels walk flash_grid_plan's tile
# list instead of the dense rectangle. Parity exactly at block boundaries
# (window % bk == 0, window < bk, q_offset != 0) against the dense jnp
# references, plus the plan's own pruning ledger.
# ---------------------------------------------------------------------------

PRUNED_CASES = [
    # B, Sq, Sk, H, KV, dh, causal, window, q_offset, bq, bk
    (2, 128, 128, 4, 2, 32, True, 64, 0, 32, 32),    # window % bk == 0
    (2, 128, 128, 4, 2, 32, True, 16, 0, 32, 32),    # window < bk
    (1, 32, 128, 4, 2, 16, True, 0, 96, 32, 32),     # q_offset != 0
    (1, 32, 128, 4, 2, 16, True, 48, 96, 32, 32),    # offset + window
    (1, 17, 128, 2, 1, 16, True, 0, 50, 16, 32),     # ragged q + offset
    (2, 128, 128, 4, 2, 32, False, 48, 0, 32, 32),   # non-causal window
]


@pytest.mark.parametrize("case", PRUNED_CASES)
def test_flash_pruned_grid_parity_at_block_boundaries(case):
    B, Sq, Sk, H, KV, dh, causal, window, q_offset, bq, bk = case
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    q = _mk(ks[0], (B, Sq, H, dh), jnp.float32)
    k = _mk(ks[1], (B, Sk, KV, dh), jnp.float32)
    v = _mk(ks[2], (B, Sk, KV, dh), jnp.float32)
    do = _mk(ks[3], (B, Sq, H, dh), jnp.float32)

    def pallas(q, k, v):
        return ops.flash_attention(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset, bq=bq, bk=bk,
                                   interpret=True)

    def dense(q, k, v):
        return blockwise_attention(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset, block=bk)

    np.testing.assert_allclose(np.asarray(pallas(q, k, v)),
                               np.asarray(dense(q, k, v)),
                               atol=2e-5, rtol=2e-5)

    def scal(fn):
        return lambda q, k, v: jnp.sum(fn(q, k, v) * do)

    g_p = jax.grad(scal(pallas), argnums=(0, 1, 2))(q, k, v)
    g_d = jax.grad(scal(dense), argnums=(0, 1, 2))(q, k, v)
    for name, gp, gd in zip("qkv", g_p, g_d):
        np.testing.assert_allclose(np.asarray(gp), np.asarray(gd), atol=1e-3,
                                   rtol=1e-3, err_msg=f"pruned d{name}")


def test_flash_grid_plan_prunes_masked_tiles():
    from repro.kernels.flash_attention import flash_grid_plan
    # causal square triangle: nq*(nq+1)/2 of nq^2
    plan = flash_grid_plan(512, 512, 64, 64, True, 0, 0, 512)
    assert plan["total"] == 64
    assert plan["visited"] == 8 * 9 // 2
    # sliding window: constant ceil(window/bk)+1 kv blocks per q block
    # (minus the clipped rows at the start of the sequence)
    plan = flash_grid_plan(1024, 1024, 128, 128, True, 256, 0, 1024)
    assert plan["visited"] < plan["total"]
    assert plan["visited"] <= 8 * (256 // 128 + 1)
    # both orders enumerate the same tile set, every block has >= 1 tile
    for a, b in ((plan["qblk"], plan["qblk2"]), (plan["kblk"], plan["kblk2"])):
        assert set(np.asarray(a).tolist()) == set(np.asarray(b).tolist())
    assert set(np.asarray(plan["qblk"]).tolist()) == set(range(8))
    assert set(np.asarray(plan["kblk"]).tolist()) == set(range(8))
    # non-causal dense: nothing pruned
    plan = flash_grid_plan(256, 256, 64, 64, False, 0, 0, 256)
    assert plan["visited"] == plan["total"]
    # windowed prefill chunk (small Sq, long kv prefix): the dkv zeros
    # sentinels for unattended kv blocks must NOT leak into the fwd/dq list
    plan = flash_grid_plan(128, 1024, 128, 128, True, 256, 896, 1024)
    assert plan["visited"] == 3                 # the window band only
    assert plan["visited_dkv"] == 8             # every kv block written


# ---------------------------------------------------------------------------
# Fused GLA backward: gradient parity of the reverse chunk-scan kernel pair
# vs autodiff through the jnp chunked scan, final-state exactness with
# padded tails, and the single-pass property of the traced backward.
# ---------------------------------------------------------------------------

GLA_GRAD_CASES = [
    # B, S, H, dk, dv, chunk, dtype — S not a multiple of chunk covers the
    # zero-padded tail rows; the masked state update keeps them inert.
    (2, 128, 2, 16, 16, 32, jnp.float32),
    (1, 100, 2, 16, 16, 32, jnp.float32),     # padded tail
    (1, 33, 2, 8, 8, 16, jnp.float32),        # mostly-padding last chunk
    (1, 80, 2, 16, 16, 32, jnp.bfloat16),
]


@pytest.mark.parametrize("case", GLA_GRAD_CASES)
def test_gla_fused_backward_parity(case):
    from repro.models.ssm import chunked_gla
    B, S, H, dk, dv, chunk, dt = case
    ks = jax.random.split(jax.random.PRNGKey(13), 5)
    q = _mk(ks[0], (B, S, H, dk), dt)
    k = _mk(ks[1], (B, S, H, dk), dt) * 0.3
    v = _mk(ks[2], (B, S, H, dv), dt)
    g = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    dy = _mk(ks[4], (B, S, H, dv), dt)

    def loss(fn):
        return lambda q, k, v, g: jnp.sum(
            (fn(q, k, v, g) * dy).astype(jnp.float32))

    g_fused = jax.grad(loss(lambda q, k, v, g: ops.gla_scan(
        q, k, v, g, chunk=chunk, interpret=True)),
        argnums=(0, 1, 2, 3))(q, k, v, g)
    g_jnp = jax.grad(loss(lambda q, k, v, g: chunked_gla(
        q, k, v, g, chunk=chunk)[0]), argnums=(0, 1, 2, 3))(q, k, v, g)
    tol = 1e-1 if dt == jnp.bfloat16 else 1e-4
    for name, gf, gj in zip("qkvg", g_fused, g_jnp):
        np.testing.assert_allclose(np.asarray(gf, np.float32),
                                   np.asarray(gj, np.float32), atol=tol,
                                   rtol=tol, err_msg=f"fused d{name}")


def test_gla_final_state_exact_with_padding():
    """ops.gla_scan(return_final_state=True) matches the jnp chunked scan's
    final state when S is not a chunk multiple (regression: padded rows used
    to feed the carried state)."""
    from repro.models.ssm import chunked_gla
    B, S, H, dk, dv, chunk = 2, 77, 2, 8, 8, 32
    ks = jax.random.split(jax.random.PRNGKey(17), 4)
    q = _mk(ks[0], (B, S, H, dk), jnp.float32)
    k = _mk(ks[1], (B, S, H, dk), jnp.float32) * 0.3
    v = _mk(ks[2], (B, S, H, dv), jnp.float32)
    g = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    y, fin = ops.gla_scan(q, k, v, g, chunk=chunk, interpret=True,
                          return_final_state=True)
    y_ref, st_ref = chunked_gla(q, k, v, g, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=5e-5,
                               rtol=5e-5)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(st_ref),
                               atol=5e-5, rtol=5e-5)


def test_gla_state_update_masks_padded_rows():
    """Direct kernel call with a GARBAGE padded tail (g > 0, nonzero k/v):
    s_valid must keep the tail out of the carried state entirely."""
    from repro.kernels.ssm_scan import gla_scan_kernel
    BH, S, Spad, dk, dv, chunk = 2, 33, 48, 8, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(19), 4)
    q = _mk(ks[0], (BH, Spad, dk), jnp.float32)
    k = _mk(ks[1], (BH, Spad, dk), jnp.float32) * 0.3
    v = _mk(ks[2], (BH, Spad, dv), jnp.float32)
    g = -jax.nn.softplus(jax.random.normal(ks[3], (BH, Spad)))
    g = g.at[:, S:].set(0.7)              # decay > 1 garbage in the pad
    y, fin = gla_scan_kernel(q, k, v, g, chunk=chunk, s_valid=S,
                             interpret=True)
    ref_state = ref.gla_final_state_ref(q[:, :S], k[:, :S], v[:, :S],
                                        g[:, :S])
    np.testing.assert_allclose(np.asarray(fin), np.asarray(ref_state),
                               atol=5e-5, rtol=5e-5)
    r = ref.gla_scan_ref(q[:, :S], k[:, :S], v[:, :S], g[:, :S])
    np.testing.assert_allclose(np.asarray(y[:, :S]), np.asarray(r),
                               atol=5e-5, rtol=5e-5)


def test_gla_pallas_backward_is_single_pass():
    """The traced backward of the pallas ssm_scan path is the fused kernel
    pair: exactly two pallas_calls (fwd + reverse scan) and NO lax.scan
    recompute through the jnp chunked scan."""
    import re
    B, S, H, dk, dv = 1, 64, 2, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(23), 4)
    q = _mk(ks[0], (B, S, H, dk), jnp.float32)
    k = _mk(ks[1], (B, S, H, dk), jnp.float32) * 0.3
    v = _mk(ks[2], (B, S, H, dv), jnp.float32)
    g = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))

    def loss(q, k, v, g):
        return jnp.sum(ops.gla_scan(q, k, v, g, chunk=16, interpret=True)
                       ** 2)

    text = str(jax.make_jaxpr(jax.grad(loss, argnums=(0, 1, 2, 3)))(
        q, k, v, g))
    assert text.count("pallas_call") == 2, text.count("pallas_call")
    assert not re.search(r"\bscan\[", text)


GLA_CASES = [
    (2, 128, 2, 16, 32, 32, jnp.float32),
    (1, 100, 4, 8, 8, 16, jnp.float32),
    (1, 64, 2, 32, 64, 64, jnp.bfloat16),
]


@pytest.mark.parametrize("case", GLA_CASES)
def test_gla_scan_vs_ref(case):
    B, S, H, dk, dv, chunk, dt = case
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = _mk(ks[0], (B, S, H, dk), dt)
    k = _mk(ks[1], (B, S, H, dk), dt) * 0.3
    v = _mk(ks[2], (B, S, H, dv), dt)
    g = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    y = ops.gla_scan(q, k, v, g.astype(dt) if dt != jnp.float32 else g,
                     chunk=chunk, interpret=True)

    def fold(x):
        return jnp.moveaxis(x, 2, 1).reshape((B * H,) + x.shape[1:2] + x.shape[3:])
    r = ref.gla_scan_ref(fold(q), fold(k), fold(v),
                         jnp.moveaxis(g, 2, 1).reshape(B * H, S))
    r = jnp.moveaxis(r.reshape(B, H, S, dv), 1, 2)
    tol = 5e-2 if dt == jnp.bfloat16 else 5e-5
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.sampled_from([32, 48, 64]),
       st.sampled_from([1, 2, 4]), st.booleans())
def test_flash_property_random_shapes(b, s, kv, causal):
    h = kv * 2
    dh = 16
    ks = jax.random.split(jax.random.PRNGKey(s + b), 3)
    q = _mk(ks[0], (b, s, h, dh), jnp.float32)
    k = _mk(ks[1], (b, s, kv, dh), jnp.float32)
    v = _mk(ks[2], (b, s, kv, dh), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, bq=16, bk=16,
                              interpret=True)
    qh = jnp.moveaxis(q, 2, 1).reshape(b * h, s, dh)
    kh = jnp.moveaxis(k, 2, 1).reshape(b * kv, s, dh)
    vh = jnp.moveaxis(v, 2, 1).reshape(b * kv, s, dh)
    r = ref.flash_attention_ref(qh, kh, vh, causal=causal, group=h // kv)
    r = jnp.moveaxis(r.reshape(b, h, s, dh), 1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), atol=2e-5,
                               rtol=2e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 2), st.sampled_from([33, 64, 80]),
       st.sampled_from([8, 16]))
def test_gla_property_random_shapes(b, s, chunk):
    h, dk, dv = 2, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(s), 4)
    q = _mk(ks[0], (b, s, h, dk), jnp.float32)
    k = _mk(ks[1], (b, s, h, dk), jnp.float32) * 0.3
    v = _mk(ks[2], (b, s, h, dv), jnp.float32)
    g = -jax.nn.softplus(jax.random.normal(ks[3], (b, s, h)))
    y = ops.gla_scan(q, k, v, g, chunk=chunk, interpret=True)

    def fold(x):
        return jnp.moveaxis(x, 2, 1).reshape((b * h, s) + x.shape[3:])
    r = ref.gla_scan_ref(fold(q), fold(k), fold(v),
                         jnp.moveaxis(g, 2, 1).reshape(b * h, s))
    r = jnp.moveaxis(r.reshape(b, h, s, dv), 1, 2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), atol=5e-5,
                               rtol=5e-5)
