"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU): shape/dtype
sweeps + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref


def _mk(key, shape, dt):
    return jax.random.normal(key, shape, dt)


FLASH_CASES = [
    # B, Sq, Sk, H, KV, dh, causal, window, bq, bk, dtype
    (2, 128, 128, 4, 2, 32, True, 0, 64, 64, jnp.float32),
    (1, 100, 100, 4, 4, 16, True, 0, 32, 32, jnp.float32),
    (2, 64, 64, 8, 2, 64, True, 30, 32, 32, jnp.bfloat16),
    (1, 128, 128, 2, 1, 32, False, 0, 64, 64, jnp.float32),
    (1, 96, 160, 4, 1, 16, False, 0, 32, 64, jnp.float32),
    (2, 128, 128, 4, 2, 32, True, 64, 128, 128, jnp.float32),
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_vs_ref(case):
    B, Sq, Sk, H, KV, dh, causal, window, bq, bk, dt = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _mk(ks[0], (B, Sq, H, dh), dt)
    k = _mk(ks[1], (B, Sk, KV, dh), dt)
    v = _mk(ks[2], (B, Sk, KV, dh), dt)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              bq=bq, bk=bk, interpret=True)
    qh = jnp.moveaxis(q, 2, 1).reshape(B * H, Sq, dh)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * KV, Sk, dh)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * KV, Sk, dh)
    r = ref.flash_attention_ref(qh, kh, vh, causal=causal, window=window,
                                group=H // KV)
    r = jnp.moveaxis(r.reshape(B, H, Sq, dh), 1, 2)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


DECODE_CASES = [
    (2, 256, 4, 2, 32, 128, jnp.float32),
    (1, 100, 8, 8, 16, 64, jnp.float32),
    (4, 512, 8, 4, 64, 256, jnp.bfloat16),
]


@pytest.mark.parametrize("case", DECODE_CASES)
def test_decode_attention_vs_ref(case):
    B, T, H, KV, dh, bk, dt = case
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _mk(ks[0], (B, 1, H, dh), dt)
    k = _mk(ks[1], (B, T, KV, dh), dt)
    v = _mk(ks[2], (B, T, KV, dh), dt)
    cl = jnp.asarray(np.random.default_rng(0).integers(1, T, B), jnp.int32)
    out = ops.decode_attention(q, k, v, cl, bk=bk, interpret=True)
    qh = q[:, 0].reshape(B * H, dh)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * KV, T, dh)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * KV, T, dh)
    r = ref.decode_attention_ref(qh, kh, vh, jnp.repeat(cl, KV),
                                 group=H // KV).reshape(B, 1, H, dh)
    tol = 3e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


GLA_CASES = [
    (2, 128, 2, 16, 32, 32, jnp.float32),
    (1, 100, 4, 8, 8, 16, jnp.float32),
    (1, 64, 2, 32, 64, 64, jnp.bfloat16),
]


@pytest.mark.parametrize("case", GLA_CASES)
def test_gla_scan_vs_ref(case):
    B, S, H, dk, dv, chunk, dt = case
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = _mk(ks[0], (B, S, H, dk), dt)
    k = _mk(ks[1], (B, S, H, dk), dt) * 0.3
    v = _mk(ks[2], (B, S, H, dv), dt)
    g = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    y = ops.gla_scan(q, k, v, g.astype(dt) if dt != jnp.float32 else g,
                     chunk=chunk, interpret=True)

    def fold(x):
        return jnp.moveaxis(x, 2, 1).reshape((B * H,) + x.shape[1:2] + x.shape[3:])
    r = ref.gla_scan_ref(fold(q), fold(k), fold(v),
                         jnp.moveaxis(g, 2, 1).reshape(B * H, S))
    r = jnp.moveaxis(r.reshape(B, H, S, dv), 1, 2)
    tol = 5e-2 if dt == jnp.bfloat16 else 5e-5
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.sampled_from([32, 48, 64]),
       st.sampled_from([1, 2, 4]), st.booleans())
def test_flash_property_random_shapes(b, s, kv, causal):
    h = kv * 2
    dh = 16
    ks = jax.random.split(jax.random.PRNGKey(s + b), 3)
    q = _mk(ks[0], (b, s, h, dh), jnp.float32)
    k = _mk(ks[1], (b, s, kv, dh), jnp.float32)
    v = _mk(ks[2], (b, s, kv, dh), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, bq=16, bk=16,
                              interpret=True)
    qh = jnp.moveaxis(q, 2, 1).reshape(b * h, s, dh)
    kh = jnp.moveaxis(k, 2, 1).reshape(b * kv, s, dh)
    vh = jnp.moveaxis(v, 2, 1).reshape(b * kv, s, dh)
    r = ref.flash_attention_ref(qh, kh, vh, causal=causal, group=h // kv)
    r = jnp.moveaxis(r.reshape(b, h, s, dh), 1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), atol=2e-5,
                               rtol=2e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 2), st.sampled_from([33, 64, 80]),
       st.sampled_from([8, 16]))
def test_gla_property_random_shapes(b, s, chunk):
    h, dk, dv = 2, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(s), 4)
    q = _mk(ks[0], (b, s, h, dk), jnp.float32)
    k = _mk(ks[1], (b, s, h, dk), jnp.float32) * 0.3
    v = _mk(ks[2], (b, s, h, dv), jnp.float32)
    g = -jax.nn.softplus(jax.random.normal(ks[3], (b, s, h)))
    y = ops.gla_scan(q, k, v, g, chunk=chunk, interpret=True)

    def fold(x):
        return jnp.moveaxis(x, 2, 1).reshape((b * h, s) + x.shape[3:])
    r = ref.gla_scan_ref(fold(q), fold(k), fold(v),
                         jnp.moveaxis(g, 2, 1).reshape(b * h, s))
    r = jnp.moveaxis(r.reshape(b, h, s, dv), 1, 2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), atol=5e-5,
                               rtol=5e-5)
