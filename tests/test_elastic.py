"""Elastic CDP: rank-failure tolerance for the point-to-point ring.

Unit layer: the new fault sites, the StepWatchdog, the bounded EventLog,
MemorySnapshot checksums, the BuddySnapshotStore's replication guarantees
and the dtype-preserving stage re-cut.

Engine layer (forced-device subprocesses, like test_parallel_plan): an
injected ``rank_down@k`` re-forms the ring on the survivors from the
buddy snapshot, the post-recovery trajectory is BIT-IDENTICAL to an
uninterrupted N-1 run started from the snapshot step, the re-formed
step's HLO stays permute-only (zero all-gather, zero gradient
all-reduce), the watchdog routes a hung step into the same recovery, and
``rejoin_after`` scales back up at a step boundary.
"""
import math
import time

import numpy as np
import pytest

from repro.engine import resilience as rsl
from repro.engine.elastic import BuddySnapshotStore, SnapshotUnusable
from repro.checkpoint import MemorySnapshot


# ---------------------------------------------------------------------------
# fault sites + watchdog
# ---------------------------------------------------------------------------

def test_rank_down_and_step_hang_parse():
    faults = rsl.parse_faults("rank_down@3:1,step_hang@5:0.2,rank_down%0.5")
    assert faults[0].site == "rank_down" and faults[0].step == 3
    assert faults[0].arg == 1.0
    assert faults[1].site == "step_hang" and faults[1].arg == 0.2
    assert faults[2].prob == 0.5


def test_step_watchdog_deadline():
    wd = rsl.StepWatchdog(0.05)
    assert wd.expired() is None          # never armed
    wd.arm(7)
    assert wd.step == 7
    assert wd.expired() is None          # within deadline
    time.sleep(0.08)
    over = wd.expired()
    assert over is not None and over > 0.05
    wd.disarm()
    assert wd.expired() is None
    with pytest.raises(ValueError):
        rsl.StepWatchdog(0.0)


# ---------------------------------------------------------------------------
# bounded event log
# ---------------------------------------------------------------------------

def test_event_log_ring_buffer(tmp_path):
    log = rsl.EventLog(max_events=3)
    for i in range(5):
        log.append("tick", i)
    assert len(log) == 3 and log.dropped == 2
    assert [r["step"] for r in log] == [2, 3, 4]   # newest kept
    p = tmp_path / "events.jsonl"
    n = log.to_jsonl(p)
    lines = p.read_text().splitlines()
    assert n == len(lines) == 4                    # header + 3 records
    import json
    hdr = json.loads(lines[0])
    assert hdr["kind"] == "events_dropped"
    assert hdr["dropped"] == 2 and hdr["kept"] == 3


def test_event_log_unbounded_has_no_header(tmp_path):
    log = rsl.EventLog()
    for i in range(4):
        log.append("tick", i)
    assert log.dropped == 0
    p = tmp_path / "events.jsonl"
    # the export contract test_rollout relies on: lines == len(log)
    assert log.to_jsonl(p) == len(p.read_text().splitlines()) == 4


# ---------------------------------------------------------------------------
# memory snapshots + buddy store
# ---------------------------------------------------------------------------

def _chunked_state(n=4, chunk=8, seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"stages":
                       rng.standard_normal((n, chunk)).astype(np.float32)},
            "opt": {"mom": {"stages":
                            rng.standard_normal((n, chunk))
                            .astype(np.float32)}},
            "step": np.int32(5)}


def test_memory_snapshot_roundtrip_and_crc():
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": np.int32(7)}
    snap = MemorySnapshot.from_tree(4, tree)
    back = snap.restore(tree)
    assert np.array_equal(back["a"], tree["a"]) and back["b"] == 7
    # snapshots COPY: mutating the source must not alias
    tree["a"][0, 0] = 99.0
    assert snap.restore(tree)["a"][0, 0] == 0.0
    # corruption is detected, and a strict restore refuses it
    snap.arrays["a"][0, 1] = -1.0
    intact, reason = snap.verify()
    assert not intact and "crc32" in reason
    with pytest.raises(ValueError, match="not intact"):
        snap.restore(tree)


def test_buddy_store_survives_any_single_rank_death():
    state = _chunked_state(n=4)
    for dead in range(4):
        store = BuddySnapshotStore(4, chunked=True)
        store.take(5, state)
        store.fail(dead)
        out, step = store.assemble(state)
        assert step == 5
        assert np.array_equal(out["params"]["stages"],
                              state["params"]["stages"])
        assert np.array_equal(out["opt"]["mom"]["stages"],
                              state["opt"]["mom"]["stages"])
        assert out["step"] == 5


def test_buddy_store_adjacent_double_death_is_unusable():
    state = _chunked_state(n=4)
    store = BuddySnapshotStore(4, chunked=True)
    store.take(5, state)
    # rank 1's primary dies AND its mirror holder (ring predecessor 0)
    store.fail(1)
    store.fail(0)
    with pytest.raises(SnapshotUnusable, match="mirror holder"):
        store.assemble(state)
    # NON-adjacent double death still assembles (mirrors cover both)
    store = BuddySnapshotStore(4, chunked=True)
    store.take(5, state)
    store.fail(1)
    store.fail(3)
    out, _ = store.assemble(state)
    assert np.array_equal(out["params"]["stages"], state["params"]["stages"])


def test_buddy_store_replicated_mode():
    state = _chunked_state(n=3)
    store = BuddySnapshotStore(3, chunked=False)
    store.take(2, state)
    store.fail(0)
    store.fail(2)                        # any one survivor suffices
    out, step = store.assemble(state)
    assert step == 2
    assert np.array_equal(out["params"]["stages"], state["params"]["stages"])
    store.fail(1)
    with pytest.raises(SnapshotUnusable):
        store.assemble(state)


def test_buddy_store_take_before_assemble_required():
    store = BuddySnapshotStore(2, chunked=False)
    with pytest.raises(SnapshotUnusable, match="no snapshot"):
        store.assemble({})


# ---------------------------------------------------------------------------
# layout re-cut (dtype-preserving, n-dependent stage order)
# ---------------------------------------------------------------------------

def test_recut_chunks_matches_direct_cut_bitwise():
    import jax
    from repro.configs import get_reduced
    from repro.models import init_params
    from repro.parallel import zero_cdp as zcdp

    cfg = get_reduced("stablelm-1.6b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    l4 = zcdp.build_stage_layout(cfg, 4)
    l3 = zcdp.build_stage_layout(cfg, 3)
    c4 = np.asarray(zcdp.chunk_params(l4, params))
    c3 = zcdp.recut_chunks(l4, l3, c4)
    # the re-cut equals cutting the pristine params at n=3 directly —
    # i.e. the n-dependent stage reorder is handled exactly
    assert np.array_equal(c3, np.asarray(zcdp.chunk_params(l3, params)))
    assert c3.dtype == c4.dtype == np.float32
    # and it round-trips (grow back to 4)
    assert np.array_equal(zcdp.recut_chunks(l3, l4, c3), c4)


def test_recut_stage_state_recuts_slots_and_keeps_scalars():
    from repro.configs import get_reduced
    from repro.parallel import zero_cdp as zcdp

    cfg = get_reduced("stablelm-1.6b")
    l4 = zcdp.build_stage_layout(cfg, 4)
    l3 = zcdp.build_stage_layout(cfg, 3)
    rng = np.random.default_rng(1)
    c4 = rng.standard_normal((4, l4.chunk)).astype(np.float32)
    state = {"params": {"stages": c4},
             "opt": {"mom": {"stages": c4 * 0.5}},
             "step": np.int32(7)}
    out = zcdp.recut_stage_state(cfg, state, 4, 3)
    assert out["params"]["stages"].shape == (3, l3.chunk)
    assert np.array_equal(out["opt"]["mom"]["stages"],
                          zcdp.recut_chunks(l4, l3, c4 * 0.5))
    assert out["step"] == 7              # scalars pass through untouched


def test_plan_validate_resize():
    from repro.parallel import get_plan

    zc = get_plan("zero_cdp")
    zc.validate_resize(3, 2)             # legal shrink
    with pytest.raises(ValueError, match="re-form"):
        zc.validate_resize(2, 1)         # min_data=2: the ring degenerates
    get_plan("dp").validate_resize(2, 1)  # dp survives to a single rank
    pinned = zc.with_(n_stages=3)
    with pytest.raises(ValueError, match="pinned"):
        pinned.validate_resize(3, 2)


# ---------------------------------------------------------------------------
# engine recovery (forced-device subprocesses)
# ---------------------------------------------------------------------------

def test_elastic_recovery_dp_2_to_1(subproc):
    """Kill rank 1 of a 2-rank dp run at step 3: the engine re-forms on
    the survivor from the step-2 buddy snapshot and finishes; the
    post-recovery trajectory is bit-identical to an uninterrupted 1-rank
    run started from the recovered state."""
    subproc("""
import tempfile
import numpy as np
from repro.engine import RunSpec, TrainEngine
from repro import checkpoint as ckpt

spec = RunSpec(arch="stablelm-1.6b", reduced=True, plan="dp",
               mesh_data=2, mesh_model=1)
eng = TrainEngine(spec, steps=6, batch=4, seq=16, log_every=1,
                  elastic=True, snapshot_every=2,
                  resilience="rank_down@3:1", verbose=False)
eng.run()
assert len(eng.recoveries) == 1
rec = eng.recoveries[0]
assert rec["failed_at"] == 3 and rec["step"] == 2 and rec["dead"] == 1
assert rec["n"] == 1 and rec["source"] == "snapshot"
assert rec["steps_lost"] == 1 and eng._n_data == 1
kinds = [r["kind"] for r in eng.events]
assert "rank_down" in kinds and "recover" in kinds and "snapshot" in kinds

# baseline: clean 1-rank run STARTED from the recovered state/step
d = tempfile.mkdtemp()
ckpt.save(d, rec["step"], rec["state"])
base = TrainEngine(spec.with_(mesh_data=1), steps=6, batch=4, seq=16,
                   log_every=1, ckpt_dir=d, ckpt_every=1000, verbose=False)
base.run()
el = {}
for h in eng.history:                 # last occurrence per step: the
    el[h["step"]] = h["loss"]         # replay overwrites the pre-fail entry
bl = {h["step"]: h["loss"] for h in base.history}
for s in range(rec["step"], 6):
    assert el[s] == bl[s], (s, el[s], bl[s])
for a, b in zip((np.asarray(x) for x in __import__("jax").tree.leaves(
                    eng.state)),
                (np.asarray(x) for x in __import__("jax").tree.leaves(
                    base.state))):
    assert np.array_equal(a, b)
print("OK")
""", n_devices=2, timeout=900)


def test_elastic_recovery_zero_cdp_bitwise_and_permute_only(subproc):
    """The acceptance run: rank_down@3 on a 3-rank zero_cdp ring. The
    survivors re-form at N-1=2 from the buddy snapshot; the re-formed
    step's HLO is permute-only (zero all-gather, zero gradient
    all-reduce, same assertion style as test_parallel_plan); and the
    post-recovery loss trajectory + final stage-sharded state are
    bit-identical to an uninterrupted 2-rank run from the snapshot
    step."""
    subproc("""
import tempfile
import numpy as np
from repro.engine import RunSpec, TrainEngine
from repro import checkpoint as ckpt
from repro.launch.roofline import parse_collectives

spec = RunSpec(arch="stablelm-1.6b", reduced=True, plan="zero_cdp",
               mesh_data=3, mesh_model=1)
eng = TrainEngine(spec, steps=6, batch=6, seq=16, log_every=1,
                  elastic=True, snapshot_every=2,
                  resilience="rank_down@3:1", verbose=False)
eng.run()
rec = eng.recoveries[0]
assert rec["step"] == 2 and rec["n"] == 2 and rec["source"] == "snapshot"
assert eng.state["params"]["stages"].shape[0] == 2

# the re-formed N-1 step keeps the paper's comm signature: point-to-point
# permutes only — no all-gather, no gradient-sized all-reduce
stats = parse_collectives(eng.hlo_text())
n_new = 2
assert stats.op_counts["collective-permute"] >= 2 * (n_new - 1)
assert stats.op_counts["all-gather"] == 0
chunk_bytes = 4 * eng.state["params"]["stages"].shape[1]
assert stats.max_by_type["all-reduce"] < chunk_bytes // 100

d = tempfile.mkdtemp()
ckpt.save(d, rec["step"], rec["state"])
base = TrainEngine(spec.with_(mesh_data=2), steps=6, batch=6, seq=16,
                   log_every=1, ckpt_dir=d, ckpt_every=1000, verbose=False)
base.run()
el = {}
for h in eng.history:
    el[h["step"]] = h["loss"]
bl = {h["step"]: h["loss"] for h in base.history}
for s in range(rec["step"], 6):
    assert el[s] == bl[s], (s, el[s], bl[s])
assert np.array_equal(np.asarray(eng.state["params"]["stages"]),
                      np.asarray(base.state["params"]["stages"]))
assert np.array_equal(np.asarray(eng.state["opt"]["mom"]["stages"]),
                      np.asarray(base.state["opt"]["mom"]["stages"]))
print("OK")
""", n_devices=3, timeout=900)


def test_step_hang_watchdog_routes_into_recovery(subproc):
    """A step stalling past the watchdog deadline is classified as a hung
    collective: the presumed-dead peer is dropped and the run recovers
    through the same rank-down path, discarding the hung step's output."""
    subproc("""
from repro.engine import RunSpec, TrainEngine

spec = RunSpec(arch="stablelm-1.6b", reduced=True, plan="dp",
               mesh_data=2, mesh_model=1)
eng = TrainEngine(spec, steps=5, batch=4, seq=16, log_every=1,
                  elastic=True, snapshot_every=2, watchdog_timeout=3.0,
                  resilience="step_hang@3:4.5", verbose=False)
eng.run()
rec = eng.recoveries[0]
assert rec["cause"] == "step_hang" and rec["failed_at"] == 3
assert rec["dead"] == 1 and rec["n"] == 1 and rec["source"] == "snapshot"
hang = eng.events.of("step_hang")
assert hang and hang[0]["elapsed_s"] > 3.0
import math
assert all(math.isfinite(h["loss"]) for h in eng.history)
print("OK")
""", n_devices=2, timeout=900)


def test_rejoin_scales_back_up_at_step_boundary(subproc):
    """Shrink 3 -> 2 on the injected death, then rejoin 2 -> 3 two steps
    after recovery: the state is re-cut to the full ring and the run
    finishes at N with finite losses."""
    subproc("""
import math
from repro.engine import RunSpec, TrainEngine

spec = RunSpec(arch="stablelm-1.6b", reduced=True, plan="zero_cdp",
               mesh_data=3, mesh_model=1)
eng = TrainEngine(spec, steps=8, batch=6, seq=16, log_every=1,
                  elastic=True, snapshot_every=2, rejoin_after=2,
                  resilience="rank_down@3:1", verbose=False)
eng.run()
assert eng.recoveries[0]["step"] == 2 and eng.recoveries[0]["n"] == 2
rj = eng.events.of("rejoin")
assert len(rj) == 1 and rj[0]["step"] == 4 and rj[0]["n"] == 3
assert eng._n_data == 3
assert eng.state["params"]["stages"].shape[0] == 3
assert all(math.isfinite(h["loss"]) for h in eng.history)
print("OK")
""", n_devices=3, timeout=900)


def test_rank_down_falls_back_to_disk_and_raises_without_elastic(subproc):
    """snapshot_every=0 forces the disk path: recovery restores the
    newest intact checkpoint (template-keyed at the OLD layout, then
    re-cut). Without elastic=True a rank death is fatal, loudly."""
    subproc("""
import tempfile
from repro.engine import RunSpec, TrainEngine

spec = RunSpec(arch="stablelm-1.6b", reduced=True, plan="dp",
               mesh_data=2, mesh_model=1)
d = tempfile.mkdtemp()
eng = TrainEngine(spec, steps=5, batch=4, seq=16, log_every=1,
                  elastic=True, snapshot_every=0, ckpt_dir=d, ckpt_every=2,
                  resilience="rank_down@3:0", verbose=False)
eng.run()
rec = eng.recoveries[0]
assert rec["source"] == "checkpoint" and rec["dead"] == 0
assert rec["step"] == 2 and rec["n"] == 1

eng2 = TrainEngine(spec, steps=4, batch=4, seq=16, log_every=100,
                   resilience="rank_down@2:0", verbose=False)
try:
    eng2.run()
    raise SystemExit("expected RuntimeError")
except RuntimeError as e:
    assert "elastic" in str(e)
print("OK")
""", n_devices=2, timeout=900)


def test_shrink_mesh_drops_exactly_the_dead_rank(subproc):
    subproc("""
import numpy as np
from repro.launch.mesh import make_host_mesh
from repro.engine.spec import shrink_mesh

mesh = make_host_mesh(3, 1, 0)
small = shrink_mesh(mesh, 1)
assert small.shape["data"] == 2 and small.shape["model"] == 1
kept = [d.id for d in np.asarray(small.devices).ravel()]
orig = [d.id for d in np.asarray(mesh.devices).ravel()]
assert kept == [orig[0], orig[2]]     # survivors keep their devices
for bad in (-1, 3):
    try:
        shrink_mesh(mesh, bad)
        raise SystemExit("expected ValueError")
    except ValueError:
        pass
one = shrink_mesh(shrink_mesh(mesh, 0), 0)
assert one.shape["data"] == 1
try:
    shrink_mesh(one, 0)
    raise SystemExit("expected ValueError")
except ValueError:
    pass
print("OK")
""", n_devices=3, timeout=300)
