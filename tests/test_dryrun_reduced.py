"""Reduced-scale dry-run: every assigned arch lowers + compiles a train and a
decode step on an 8-device (2 data x 2 model x 2 pod) host mesh — the same
code path as the 512-chip production dry-run, so sharding bugs surface in CI.
Run in a subprocess (forced host device count)."""
import pytest

from repro.configs import ARCHS


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_dryrun_train_and_decode(subproc, arch):
    subproc(f"""
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh as compat_make_mesh
from repro.configs import get_reduced
from repro.configs.base import InputShape
from repro.core.trainer import TrainerConfig, init_state, make_train_step
from repro.models import model as model_mod
from repro.optim import sgd_momentum
from repro.sharding import specs as sh
from repro.launch.roofline import parse_collectives

mesh = compat_make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = get_reduced({arch!r})

# --- train step (CDP-v2, multi-pod axes) ---
opt = sgd_momentum(0.9)
tr = TrainerConfig(rule="cdp_v2", pod_axis="pod", lr_schedule=lambda s: 1e-2)
step_fn, ssh_fn, bsh_fn = make_train_step(cfg, tr, mesh, opt)
state = jax.eval_shape(lambda: init_state(
    cfg, tr, model_mod.init_params(cfg, jax.random.PRNGKey(0)), opt))
B, S = 8, 32
batch = {{"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
          "targets": jax.ShapeDtypeStruct((B, S), jnp.int32)}}
if cfg.family == "vlm":
    batch["patches"] = jax.ShapeDtypeStruct(
        (B, cfg.vlm.num_patches, cfg.vlm.vision_dim), jnp.float32)
if cfg.family == "encdec":
    batch["frames"] = jax.ShapeDtypeStruct(
        (B, S // cfg.encdec.frame_rate_divisor, cfg.encdec.frontend_dim),
        jnp.float32)
ssh = ssh_fn(state, mesh)
jt = jax.jit(step_fn, in_shardings=(ssh, bsh_fn(batch)),
             out_shardings=(ssh, None), donate_argnums=(0,))
comp = jt.lower(state, batch).compile()
stats = parse_collectives(comp.as_text())
assert stats.op_counts["collective-permute"] > 0, "CDP ring missing"
print("train OK", stats.op_counts)

# --- decode step ---
cache = jax.eval_shape(lambda: model_mod.init_cache(cfg, B, 128))
dbatch = {{"token": jax.ShapeDtypeStruct((B,), jnp.int32)}}
params = jax.eval_shape(lambda: model_mod.init_params(cfg, jax.random.PRNGKey(0)))
psh = sh.param_shardings(params, mesh, "model", None)
bsh = sh.batch_sharding(dbatch, mesh, ("pod", "data"))
csh = sh.cache_pspecs(cache, mesh, ("pod", "data"), "model", batch=B)
jd = jax.jit(lambda p, b, c: model_mod.decode_step(cfg, p, b, c),
             in_shardings=(psh, bsh, csh), out_shardings=(None, csh))
comp2 = jd.lower(params, dbatch, cache).compile()
print("decode OK")
""", timeout=1200)
