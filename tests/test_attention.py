"""Model-level attention: blockwise flash VJP vs naive, MLA decode
consistency, rotary properties."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import attention as A
from repro.models.layers import apply_rope


def naive(q, k, v, causal=True, window=0):
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qr = q.reshape(B, Sq, KV, G, dh)
    s = jnp.einsum("bsjgd,btjd->bsgjt", qr, k) / math.sqrt(dh)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    m = jnp.ones((Sq, Sk), bool)
    if causal:
        m &= kp <= qp
    if window:
        m &= kp > qp - window
    s = jnp.where(m[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bsgjt,btjd->bsjgd", p, v)
    return o.reshape(B, Sq, H, -1)


@pytest.mark.parametrize("case", [
    (2, 16, 16, 4, 2, 8, True, 0, 8),
    (1, 32, 32, 4, 4, 16, True, 5, 8),
    (2, 8, 24, 6, 2, 8, False, 0, 16),
    (2, 64, 64, 8, 2, 16, True, 17, 16),
])
def test_blockwise_fwd_bwd_vs_naive(case):
    B, Sq, Sk, H, KV, dh, causal, window, blk = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, dh))
    k = jax.random.normal(ks[1], (B, Sk, KV, dh))
    v = jax.random.normal(ks[2], (B, Sk, KV, dh))

    def f1(q, k, v):
        return jnp.sum(jnp.sin(A.blockwise_attention(
            q, k, v, causal=causal, window=window, block=blk)))

    def f2(q, k, v):
        return jnp.sum(jnp.sin(naive(q, k, v, causal, window)))

    np.testing.assert_allclose(float(f1(q, k, v)), float(f2(q, k, v)),
                               rtol=1e-5)
    g1 = jax.grad(f1, (0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_mla_decode_matches_prefill():
    """Absorbed-matmul MLA decode must agree with the materialised prefill."""
    cfg = get_reduced("deepseek-v3-671b")
    key = jax.random.PRNGKey(0)
    p = A.mla_init(key, cfg, jnp.float32)
    B, S = 1, 8
    x = 0.1 * jax.random.normal(key, (B, S, cfg.d_model))
    full = A.mla_apply(p, cfg, x, jnp.arange(S))

    cache = A.mla_cache_init(cfg, B, S + 2, jnp.float32)
    outs = []
    for i in range(S):
        o, cache = A.mla_decode(p, cfg, x[:, i:i + 1], cache)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-4, rtol=2e-3)


def test_gqa_decode_matches_full():
    cfg = get_reduced("qwen2.5-14b")
    key = jax.random.PRNGKey(0)
    p = A.gqa_init(key, cfg, jnp.float32)
    B, S = 2, 10
    x = 0.1 * jax.random.normal(key, (B, S, cfg.d_model))
    full = A.gqa_apply(p, cfg, x, jnp.arange(S), causal=True)
    cache = A.gqa_cache_init(cfg, B, S + 2, jnp.float32)
    outs = []
    for i in range(S):
        o, cache = A.gqa_decode(p, cfg, x[:, i:i + 1], cache)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=1e-4, rtol=1e-3)


def test_sliding_window_decode_ring_buffer():
    """With window < cache len, the ring buffer must agree with full-cache
    attention restricted to the window."""
    cfg = get_reduced("mixtral-8x22b").with_(moe=None, attn_window=4)
    key = jax.random.PRNGKey(0)
    p = A.gqa_init(key, cfg, jnp.float32)
    B, S = 1, 12
    x = 0.1 * jax.random.normal(key, (B, S, cfg.d_model))
    full = A.gqa_apply(p, cfg, x, jnp.arange(S), causal=True)   # windowed
    cache = A.gqa_cache_init(cfg, B, S, jnp.float32)            # T = window
    assert cache["k"].shape[1] == 4
    outs = []
    for i in range(S):
        o, cache = A.gqa_decode(p, cfg, x[:, i:i + 1], cache)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=1e-4, rtol=1e-3)


def test_rope_relative_property():
    """Rotary dot products depend only on relative positions."""
    dh, H = 16, 1
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 1, H, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, H, dh))
    def dot_at(pq, pk):
        qr = apply_rope(q, jnp.array([[pq]]), 10000.0)
        kr = apply_rope(k, jnp.array([[pk]]), 10000.0)
        return float(jnp.sum(qr * kr))
    assert dot_at(3, 1) == pytest.approx(dot_at(12, 10), rel=1e-4)
    assert dot_at(5, 0) != pytest.approx(dot_at(6, 0), rel=1e-4)


def test_partial_rope_keeps_tail_channels():
    x = jnp.ones((1, 4, 2, 16))
    y = apply_rope(x, jnp.arange(4)[None], 10000.0, rotary_fraction=0.5)
    np.testing.assert_allclose(np.asarray(y[..., 8:]), 1.0)
    assert not np.allclose(np.asarray(y[..., :8]), 1.0)
