"""Continuous batching: ragged prefill/decode parity, staggered-admission
parity, scheduler invariants, and the continuous-vs-static step count.

The load-bearing property throughout: per-row isolation. A request's token
stream may depend ONLY on its own prompt (greedy decode), never on its
co-residents, its slot, or the decode step at which it was admitted."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.engine import Request, RunSpec, poisson_trace
from repro.engine.serve import ServeEngine
from repro.models import decode_step, init_cache, init_params, \
    prefill_with_cache

SPEC = RunSpec(arch="stablelm-1.6b", reduced=True, mesh_data=1, mesh_model=1)


def _prompt(rng, vocab, n):
    return rng.integers(0, vocab, size=n).astype(np.int32)


# ---------------------------------------------------------------------------
# Model level: ragged prefill + ragged decode == each row served alone
# ---------------------------------------------------------------------------

def test_ragged_prefill_and_decode_match_solo_rows():
    cfg = get_reduced("stablelm-1.6b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    S, GEN = 12, 5
    lengths = np.array([12, 7, 4], np.int32)
    rows = [_prompt(rng, cfg.vocab_size, l) for l in lengths]
    prompts = np.zeros((len(rows), S), np.int32)
    for b, r in enumerate(rows):
        prompts[b, :len(r)] = r

    cache = init_cache(cfg, len(rows), S + GEN)
    logits, cache = prefill_with_cache(
        cfg, params, {"tokens": jnp.asarray(prompts),
                      "lengths": jnp.asarray(lengths)}, cache)
    # per-row cache lens are the ragged prompt lengths, on every layer
    for layer_len in np.asarray(cache["dense"]["len"]):
        np.testing.assert_array_equal(layer_len, lengths)
    toks = [jnp.argmax(logits, -1)]
    for _ in range(GEN - 1):
        lg, cache = decode_step(cfg, params, {"token": toks[-1]}, cache,
                                ragged=True)
        toks.append(jnp.argmax(lg, -1))
    ragged = np.stack([np.asarray(t) for t in toks], 1)

    for b, r in enumerate(rows):
        c = init_cache(cfg, 1, len(r) + GEN)
        lg, c = prefill_with_cache(cfg, params,
                                   {"tokens": jnp.asarray(r)[None]}, c)
        solo = [jnp.argmax(lg, -1)]
        for _ in range(GEN - 1):
            lg, c = decode_step(cfg, params, {"token": solo[-1]}, c)
            solo.append(jnp.argmax(lg, -1))
        np.testing.assert_array_equal(
            ragged[b], np.concatenate([np.asarray(t) for t in solo]),
            err_msg=f"row {b} (length {lengths[b]}) diverged from solo serve")


def test_ragged_prefill_rejects_recurrent_families():
    cfg = get_reduced("xlstm-350m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, 2, 16)
    with pytest.raises(NotImplementedError):
        prefill_with_cache(cfg, params,
                           {"tokens": jnp.zeros((2, 8), jnp.int32),
                            "lengths": jnp.array([8, 4], jnp.int32)}, cache)


# ---------------------------------------------------------------------------
# Engine level: staggered admission parity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine():
    eng = ServeEngine(SPEC, batch=2, prompt_len=12, gen=8, verbose=False)
    eng.build()
    return eng


def _workload(engine, n=5, seed=3):
    rng = np.random.default_rng(seed)
    vocab = engine.cfg.vocab_size
    reqs = []
    arrivals = [0, 1, 2, 4, 6, 8, 10, 12][:n]
    for i in range(n):
        plen = int(rng.integers(4, 13))
        gen = [8, 3, 6, 2, 8, 4, 7, 5][i % 8]
        reqs.append(Request(rid=i, prompt=_prompt(rng, vocab, plen),
                            max_gen=gen, arrival_step=arrivals[i]))
    return reqs


def test_staggered_admission_parity(engine):
    """A request admitted into a live batch at decode step k produces
    EXACTLY the tokens of the same prompt served alone: prefilling into a
    freed slot (cache splice) must not perturb anyone, and co-residents
    must not perturb the admitted row."""
    reqs = _workload(engine)
    res = engine.serve(reqs, max_slots=2)
    assert res["metrics"]["admitted_mid_decode"] > 0, \
        "workload too tame: nothing was admitted mid-decode"
    for r in res["requests"]:
        assert r.tokens is not None and len(r.tokens) == r.max_gen
        solo = engine.serve(
            [Request(rid=r.rid, prompt=r.prompt, max_gen=r.max_gen)],
            max_slots=2)["requests"][0]
        np.testing.assert_array_equal(
            r.tokens, solo.tokens,
            err_msg=f"request {r.rid} (admitted step "
                    f"{res['scheduler'].admit_step[r.rid]}) diverged from "
                    f"its solo serve")


def test_scheduler_invariants(engine):
    """No slot serves two live requests; a request's slot interval is
    exclusive; done rows emit nothing (every history row is attributed to
    at most one live owner per slot, and completed requests stop
    appearing)."""
    reqs = _workload(engine, n=5, seed=7)
    res = engine.serve(reqs, max_slots=2)
    sched = res["scheduler"]

    # every request admitted exactly once and completed
    admits = [e for e in res["events"] if e[0] == "admit"]
    completes = [e for e in res["events"] if e[0] == "complete"]
    assert sorted(e[3] for e in admits) == sorted(r.rid for r in reqs)
    assert sorted(e[3] for e in completes) == sorted(r.rid for r in reqs)

    # per-slot live intervals never overlap: replay the event log
    live_on_slot = {}
    for kind, step, slot, rid in res["events"]:
        if kind == "admit":
            assert slot not in live_on_slot, \
                f"slot {slot} admitted {rid} while serving {live_on_slot[slot]}"
            live_on_slot[slot] = rid
        else:
            assert live_on_slot.get(slot) == rid
            del live_on_slot[slot]
    assert not live_on_slot

    # done rows stop emitting: each request owns exactly max_gen history
    # rows, and they are CONTIGUOUS on its slot (nothing attributed after
    # completion, nothing interleaved with the slot's next tenant)
    owners = np.stack(res["owners_log"])               # [n_hist, n_slots]
    for r in reqs:
        slot = sched.slot_of[r.rid]
        hits = np.flatnonzero(owners[:, slot] == r.rid)
        assert len(hits) == r.max_gen, \
            f"request {r.rid} emitted {len(hits)} != {r.max_gen}"
        assert np.array_equal(hits, np.arange(hits[0], hits[0] + len(hits))), \
            f"request {r.rid}'s emissions are not contiguous: {hits}"
        assert hits[0] == sched.first_hist[r.rid]


def test_continuous_needs_fewer_steps_than_static(engine):
    """On a staggered-length workload the iteration-level scheduler refills
    freed slots instead of draining the batch, so it needs strictly fewer
    decode steps (the deterministic, wall-clock-free half of the
    throughput claim)."""
    def reqs():
        rng = np.random.default_rng(11)
        return [Request(rid=i, prompt=_prompt(rng, engine.cfg.vocab_size, 8),
                        max_gen=8 if i % 2 == 0 else 2, arrival_step=0)
                for i in range(6)]
    cont = engine.serve(reqs(), max_slots=2)["metrics"]
    stat = engine.serve(reqs(), max_slots=2, policy="static")["metrics"]
    assert cont["total_generated"] == stat["total_generated"]
    assert cont["decode_steps"] < stat["decode_steps"]


def test_eos_early_stop(engine):
    """An explicit eos_id truncates a request the step its row emits it."""
    rng = np.random.default_rng(5)
    prompt = _prompt(rng, engine.cfg.vocab_size, 10)
    base = engine.serve([Request(rid=0, prompt=prompt, max_gen=8)],
                        max_slots=2)["requests"][0]
    assert len(base.tokens) == 8
    eos = int(base.tokens[3])
    trunc = engine.serve([Request(rid=0, prompt=prompt, max_gen=8)],
                         max_slots=2, eos_id=eos)["requests"][0]
    assert len(trunc.tokens) <= 4
    assert int(trunc.tokens[-1]) == eos
    np.testing.assert_array_equal(trunc.tokens,
                                  base.tokens[:len(trunc.tokens)])


def test_poisson_trace_deterministic():
    a = poisson_trace(16, 0.5, seed=4)
    b = poisson_trace(16, 0.5, seed=4)
    assert a == b and len(a) == 16
    assert all(x <= y for x, y in zip(a, a[1:])), "arrivals must be sorted"
    assert poisson_trace(16, 0.5, seed=5) != a


def test_serve_rejects_recurrent_families():
    eng = ServeEngine(SPEC.with_(arch="xlstm-350m"), batch=2, prompt_len=8,
                      gen=4, verbose=False)
    with pytest.raises(NotImplementedError):
        eng.serve(max_slots=2, num_requests=2)


def test_serve_validates_request_shapes(engine):
    # degradation contract: malformed requests come back rejected with a
    # per-request error instead of failing the whole batch (the shapes
    # that used to raise ValueError mid-enqueue)
    rng = np.random.default_rng(0)
    too_long = Request(rid=0, prompt=_prompt(rng, 512, 99), max_gen=4)
    ok = Request(rid=1, prompt=_prompt(rng, 512, 4), max_gen=4)
    res = engine.serve([too_long, ok], max_slots=2)
    by_rid = {r.rid: r for r in res["requests"]}
    assert by_rid[0].status == "rejected"
    assert "prompt length" in by_rid[0].error
    assert by_rid[0].tokens.shape == (0,)
    assert by_rid[1].status == "ok"
    assert len(by_rid[1].tokens) == 4

    too_greedy = Request(rid=0, prompt=_prompt(rng, 512, 4), max_gen=99)
    res = engine.serve([too_greedy, Request(rid=1, prompt=_prompt(
        rng, 512, 4), max_gen=4)], max_slots=2)
    by_rid = {r.rid: r for r in res["requests"]}
    assert by_rid[0].status == "rejected"
    assert "max_gen" in by_rid[0].error

    # a bad eos_id is an operator config error, not a request error
    with pytest.raises(ValueError):
        engine.serve([Request(rid=0, prompt=_prompt(rng, 512, 4),
                              max_gen=4)], max_slots=2, eos_id=512)


# ---------------------------------------------------------------------------
# Per-request sampling controls (temperature / top_k / seed)
# ---------------------------------------------------------------------------

def test_per_request_sampling_controls():
    """One jitted decode step serves greedy and sampled rows side by side:
    same seed -> bitwise-identical stream, different seed -> divergent
    exploration, default rows stay greedy, and top_k=1 collapses sampling
    back to argmax."""
    eng = ServeEngine(SPEC, batch=8, prompt_len=8, gen=8, verbose=False)
    eng.build()
    rng = np.random.default_rng(11)
    p = _prompt(rng, eng.cfg.vocab_size, 8)
    reqs = [
        Request(rid=0, prompt=p.copy(), max_gen=8, temperature=1.0, seed=7),
        Request(rid=1, prompt=p.copy(), max_gen=8, temperature=1.0, seed=7),
        Request(rid=2, prompt=p.copy(), max_gen=8, temperature=1.0, seed=8),
        Request(rid=3, prompt=p.copy(), max_gen=8),             # greedy
        Request(rid=4, prompt=p.copy(), max_gen=8, temperature=1.0,
                top_k=1, seed=9),                               # argmax again
    ]
    res = eng.serve(reqs, max_slots=5)
    t = {r.rid: r.tokens.tolist() for r in res["requests"]}
    assert t[0] == t[1], "same seed must replay the same key stream"
    assert t[0] != t[2], "different seeds must explore differently"
    greedy = eng.serve([Request(rid=9, prompt=p.copy(), max_gen=8)],
                       max_slots=1)["requests"][0].tokens.tolist()
    assert t[3] == greedy, "a request without sampling fields must stay " \
                           "on the engine's greedy default"
    assert t[4] == greedy, "top_k=1 must collapse to argmax"


def test_sampled_rows_do_not_perturb_greedy_co_residents():
    """Per-row isolation extends to sampling: a greedy row's stream is
    independent of WHO shares the batch, sampled neighbours included —
    the sampler consumes per-slot keys, never a batch-global stream."""
    eng = ServeEngine(SPEC, batch=4, prompt_len=8, gen=8, verbose=False)
    eng.build()
    rng = np.random.default_rng(13)
    vocab = eng.cfg.vocab_size
    g = _prompt(rng, vocab, 8)
    mixed = eng.serve(
        [Request(rid=0, prompt=g.copy(), max_gen=8)] +
        [Request(rid=i, prompt=_prompt(rng, vocab, 8), max_gen=8,
                 temperature=1.3, seed=i) for i in (1, 2, 3)],
        max_slots=4)
    solo = eng.serve([Request(rid=0, prompt=g.copy(), max_gen=8)],
                     max_slots=1)
    mt = {r.rid: r.tokens.tolist() for r in mixed["requests"]}
    st = {r.rid: r.tokens.tolist() for r in solo["requests"]}
    assert mt[0] == st[0], "sampled co-residents perturbed a greedy row"
