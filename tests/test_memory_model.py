"""Fig. 4 reproduction: analytic activation-memory of DP vs CDP."""
import numpy as np
import pytest

from repro.configs.paper_models import resnet50_profile, vit_b16_profile
from repro.core import memory_model as M


def test_partition_equal_flops():
    prof = vit_b16_profile()
    stages = M.partition_stages(prof, 4)
    flops = np.array([f for (_, _, f) in prof], float)
    per = np.array([flops[idx].sum() for idx in stages])
    assert per.min() > 0.5 * per.mean()
    assert per.max() < 1.5 * per.mean()
    # stages are contiguous and cover everything
    flat = [i for st in stages for i in st]
    assert flat == sorted(flat) and len(flat) == len(prof)


def test_vit_reduction_near_half():
    """Paper: ViT-B/16 reaches ~42% per-worker peak reduction (homogeneous
    layers -> close to the ideal halving) and improves with N."""
    prof = vit_b16_profile()
    r8 = M.simulate(prof, 8)
    r32 = M.simulate(prof, 32)
    # ideal halving bound: 1 - (N+1)/2N -> 48.4% at N=32; paper measures 42%
    assert 0.30 < r32.reduction <= 0.52
    assert r32.reduction >= r8.reduction - 1e-9
    # CDP total is ~constant over ticks
    assert r32.cdp_timeline.std() / r32.cdp_timeline.mean() < 0.05
    # DP timeline peaks hard
    assert r32.dp_timeline.max() > 1.7 * r32.dp_timeline.mean()


def test_resnet_reduction_lower_than_vit():
    """Paper: ResNet-50's heterogeneous activation/FLOPs ratio reduces the
    gain (~30% vs ~42%)."""
    rn = M.simulate(resnet50_profile(), 32)
    vit = M.simulate(vit_b16_profile(), 32)
    assert 0.1 < rn.reduction < vit.reduction


def test_dp_peak_matches_schedule_formula():
    prof = [("m", 100, 1.0)] * 16      # homogeneous, 1600 bytes full model
    rep = M.simulate(prof, 4)
    # per-worker DP peak = full model activations retained = 1600 bytes
    assert rep.dp_per_worker_peak == pytest.approx(1600.0)
    # CDP per-worker peak = (N+1)/2N * full model = 1000 (paper Sec. 4.1)
    assert rep.cdp_per_worker_peak == pytest.approx(1000.0)
    assert rep.reduction == pytest.approx(1 - (4 + 1) / 8)
