"""Fallback for environments without ``hypothesis``.

Re-exports the real ``given``/``settings``/``strategies`` when hypothesis is
installed; otherwise provides a deterministic mini-implementation of the tiny
strategy subset the suite uses (integers, sampled_from, booleans) that runs
each property test on ``max_examples`` seeded random samples.
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                                       # pragma: no cover
    import random

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample                          # fn(rng) -> value

    class _Strategies:
        @staticmethod
        def integers(lo, hi):
            return _Strategy(lambda r: r.randint(lo, hi))

        @staticmethod
        def sampled_from(xs):
            choices = list(xs)
            return _Strategy(lambda r: r.choice(choices))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: r.random() < 0.5)

    st = _Strategies()

    def settings(max_examples: int = 10, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            # zero-arg wrapper (no functools.wraps: pytest must not see the
            # strategy params via __wrapped__ and treat them as fixtures)
            def run():
                n = getattr(run, "_max_examples", 10)
                rng = random.Random(0)
                for _ in range(n):
                    fn(*[s.sample(rng) for s in strats])
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            run._max_examples = getattr(fn, "_max_examples", 10)
            return run
        return deco
