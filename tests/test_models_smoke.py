"""Per-architecture smoke tests (deliverable f): every assigned arch, reduced
variant, one forward + one train step + one decode step on CPU; asserts
output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_reduced
from repro.data.synthetic import synthetic_batch
from repro.models import (decode_step, init_cache, init_params, loss_fn,
                          prefill_logits)
from repro.models.model import analytic_param_count, forward
from repro.models.common import count_params
from repro.optim import sgd_momentum


def _batch(cfg, B=2, S=32):
    key = jax.random.PRNGKey(1)
    b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
         "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        b["patches"] = jnp.ones((B, cfg.vlm.num_patches, cfg.vlm.vision_dim),
                                jnp.float32)
    if cfg.family == "encdec":
        b["frames"] = jnp.ones((B, S // cfg.encdec.frame_rate_divisor,
                                cfg.encdec.frontend_dim), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_reduced(arch)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux, h = forward(cfg, params, batch)
    B, S = batch["tokens"].shape
    from repro.models.model import padded_vocab
    assert logits.shape == (B, S, padded_vocab(cfg))
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert count_params(params) == analytic_param_count(cfg)


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_reduces_loss_direction(arch):
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    opt = sgd_momentum(0.0)
    state = opt.init(params)

    def loss(p):
        return loss_fn(cfg, p, batch)[0]

    l0, g = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(l0))
    new_params, _ = opt.update(g, state, params, 0.05)
    l1 = loss(new_params)
    assert np.isfinite(float(l1))
    assert float(l1) < float(l0)        # gradient direction reduces the loss


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_matches_cache_semantics(arch):
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B = 2
    cache = init_cache(cfg, B, 64)
    tok = jnp.zeros((B,), jnp.int32)
    logits, cache = decode_step(cfg, params, {"token": tok}, cache)
    from repro.models.model import padded_vocab
    assert logits.shape == (B, padded_vocab(cfg))
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    logits2, cache = decode_step(cfg, params, {"token": tok}, cache)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "chatglm3-6b",
                                  "stablelm-1.6b", "xlstm-350m",
                                  "zamba2-7b", "deepseek-v3-671b"])
def test_decode_consistent_with_prefill(arch):
    """Teacher-forcing tokens through decode_step must reproduce the full
    forward's last-position logits (cache correctness)."""
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 12
    key = jax.random.PRNGKey(3)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks}
    full = prefill_logits(cfg, params, batch)          # logits at last pos

    cache = init_cache(cfg, B, S + 4)
    logits = None
    for i in range(S):
        logits, cache = decode_step(cfg, params, {"token": toks[:, i]}, cache)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full),
                               rtol=2e-2, atol=2e-2)
