"""RolloutEngine: the generate -> score -> train -> push loop where train
and serve time-share one device.

The load-bearing properties:

  * the loop LEARNS: mean group reward on the steerable synthetic task
    (count of tokens in a known band) strictly rises across iterations —
    a correct REINFORCE step has a known optimum to move toward;
  * the weight hand-off is DEVICE-SIDE and EXACT: serve params after a
    push are bitwise identical to an independent host-side cast of the
    train state, a fresh ServeEngine given those params emits bitwise
    identical logits/tokens, and the push executes under
    ``jax.transfer_guard("disallow")`` — a host round-trip is an error;
  * the phases never stack their peaks: the serve pool is asleep at
    level 2 (zero block occupancy, KV cache freed) before the train step
    runs, and wakes cleanly for the next generate phase;
  * the trajectory layer is pure bookkeeping: group-relative advantages
    center to zero and the REINFORCE mask confines credit to
    generated-token targets — the prompt is conditioning, not behaviour.
"""
import json

import numpy as np
import pytest

from repro.engine import (Request, RolloutEngine, RunSpec, Trajectory,
                          TrajectoryGroup, reinforce_batch)
from repro.engine.serve import ServeEngine

SPEC = RunSpec(arch="stablelm-1.6b", reduced=True, mesh_data=1, mesh_model=1)


# ---------------------------------------------------------------------------
# Trajectory layer (host-side, no jax)
# ---------------------------------------------------------------------------

def _group(rewards):
    return TrajectoryGroup([
        Trajectory(rid=i, prompt=np.arange(4, dtype=np.int32),
                   tokens=np.array([7, 8], np.int32), reward=float(r))
        for i, r in enumerate(rewards)])


def test_group_advantages_center_and_normalize():
    g = _group([2.0, 2.0, 2.0])
    adv = g.compute_advantages()
    assert np.all(adv == 0.0), \
        "an all-equal-reward group must contribute zero gradient"
    g = _group([0.0, 1.0, 2.0, 3.0])
    adv = g.compute_advantages()
    assert abs(adv.mean()) < 1e-6 and adv[0] < 0 < adv[-1]
    assert [t.advantage for t in g] == [float(a) for a in adv]
    raw = _group([0.0, 1.0, 2.0, 3.0]).compute_advantages(normalize=False)
    np.testing.assert_allclose(raw, [-1.5, -0.5, 0.5, 1.5])


def test_reinforce_batch_mask_confines_credit_to_generated_targets():
    prompt = np.array([5, 6, 7], np.int32)
    g = TrajectoryGroup([
        Trajectory(rid=0, prompt=prompt, tokens=np.array([9, 8], np.int32),
                   reward=1.0, advantage=0.5),
        Trajectory(rid=1, prompt=prompt, tokens=np.array([4], np.int32),
                   reward=0.0, advantage=-0.5)])
    b = reinforce_batch([g], pad_to=6)
    assert b["tokens"].shape == (2, 5)
    # row 0: sequence 5 6 7 9 8 -> input drops the last token
    assert b["tokens"][0].tolist() == [5, 6, 7, 9, 0]
    assert b["targets"][0].tolist() == [6, 7, 9, 8, 0]
    # mask is 1 exactly where the TARGET is a sampled token
    assert b["mask"][0].tolist() == [0.0, 0.0, 1.0, 1.0, 0.0]
    assert b["mask"][1].tolist() == [0.0, 0.0, 1.0, 0.0, 0.0]
    assert b["adv"].tolist() == [0.5, -0.5]
    with pytest.raises(ValueError, match="pad_to"):
        reinforce_batch([g], pad_to=3)


# ---------------------------------------------------------------------------
# The loop (one engine, run once, audited from several angles)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def rollout():
    eng = RolloutEngine(SPEC, plan="dp", groups=2, group_size=4,
                        prompt_len=8, gen=8, iters=3, verbose=False)
    eng.run()
    return eng


def test_mean_reward_rises(rollout):
    curve = [h["mean_reward"] for h in rollout.history]
    assert len(curve) == 3
    assert curve[-1] > curve[0], f"reward did not improve: {curve}"
    for h in rollout.history:
        assert set(h["phase_s"]) == {"generate", "score", "train", "push"}
        assert all(v >= 0 for v in h["phase_s"].values())
        assert h["gen_tok_s"] > 0
        assert len(h["group_rewards"]) == rollout.groups
        assert np.isfinite(h["loss"])


def test_score_fills_behaviour_logprobs(rollout):
    """The score phase attaches finite per-generated-token logprobs (the
    importance-sampling hook) — one per sampled token, all < 0."""
    res = rollout.serve.serve(rollout._make_requests(99),
                              max_slots=rollout.B)
    groups = rollout._collect_groups(res["requests"])
    batch = reinforce_batch(groups,
                            pad_to=rollout.prompt_len + rollout.gen)
    logp = rollout._score_logprobs(batch)
    assert logp.shape == batch["tokens"].shape
    gen_positions = batch["mask"] > 0
    assert np.isfinite(logp[gen_positions]).all()
    assert (logp[gen_positions] < 0).all(), \
        "a log-probability of a sampled token must be negative"
    assert np.all(logp[~gen_positions] == 0.0), "mask leaked credit"


def test_phase_events_and_pool_sleep_discipline(rollout, tmp_path):
    """Every iteration logs generate/score/train/push in order with
    monotonic timestamps; the serve pool slept at level 2 before every
    train step and holds zero blocks now; the log exports to JSONL."""
    phases = rollout.events.of("phase")
    order = ["generate", "score", "train", "push"]
    for it in range(len(rollout.history)):
        mine = [p for p in phases if p["step"] == it]
        assert [p["phase"] for p in mine] == order
    ts = [r["t"] for r in rollout.events]
    assert ts == sorted(ts), "event timestamps must be monotonic"

    sleeps = rollout.serve.events.of("pool_sleep")
    assert len(sleeps) >= len(rollout.history)
    assert all(s["level"] == 2 for s in sleeps)
    # re-sleep (other tests may have re-woken the pool by serving): level 2
    # must free the device cache itself, not just the block table
    rollout.serve.pool_sleep(level=2)
    assert rollout.pool_occupancy() == 0
    assert rollout.serve._paged_state["cache"] is None

    path = tmp_path / "events.jsonl"
    n = rollout.events.to_jsonl(path)
    lines = path.read_text().strip().split("\n")
    assert n == len(lines) == len(rollout.events)
    for line in lines:
        rec = json.loads(line)
        assert "kind" in rec and "step" in rec and "t" in rec


def test_push_is_bitwise_exact_and_matches_fresh_engine(rollout):
    """Serve params after the push == an independent host-side cast of the
    train state, leaf for leaf; a FRESH ServeEngine handed those params
    produces bitwise-identical logits and greedy tokens."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models import model as model_mod

    eng = rollout
    expected = jax.tree.map(lambda x, d: np.asarray(x.astype(d.dtype)),
                            eng.train.state["params"], eng.serve.params)
    got = jax.tree.map(lambda d: np.asarray(d), eng.serve.params)
    for e, g in zip(jax.tree.leaves(expected), jax.tree.leaves(got)):
        assert e.dtype == g.dtype and np.array_equal(e, g), \
            "pushed serve params diverge from the train state"

    fresh = ServeEngine(SPEC, batch=eng.B, prompt_len=eng.prompt_len,
                        gen=eng.gen, temperature=eng.temperature,
                        paged=True, kv_block_size=eng.kv_block_size,
                        verbose=False)
    fresh.build()
    fresh.params = jax.device_put(
        jax.tree.map(jnp.asarray, expected),
        NamedSharding(eng.train.mesh, P()))

    tokens = jnp.asarray(np.stack([eng.prompts[g % eng.groups]
                                   for g in range(2)]))
    logits = lambda p: np.asarray(
        model_mod.forward(eng.cfg, p, {"tokens": tokens})[0])
    assert np.array_equal(logits(eng.serve.params), logits(fresh.params)), \
        "fresh engine on the pushed params computes different logits"

    def reqs():
        return [Request(rid=i, prompt=eng.prompts[i % eng.groups].copy(),
                        max_gen=eng.gen, temperature=0.0)
                for i in range(2)]
    t_push = {r.rid: r.tokens.tolist()
              for r in eng.serve.serve(reqs(), max_slots=2)["requests"]}
    t_fresh = {r.rid: r.tokens.tolist()
               for r in fresh.serve(reqs(), max_slots=2)["requests"]}
    assert t_push == t_fresh


def test_push_performs_no_host_roundtrip(rollout):
    """The hand-off must stay on device: the push executes under a
    test-owned ``transfer_guard("disallow")``. The guard flags implicit
    host-to-device uploads (the round-trip's return leg — a push that
    materialised params on host would have to re-upload them), so first
    demonstrate it is live, then run the push under it."""
    import jax
    import jax.numpy as jnp

    with jax.transfer_guard("disallow"):
        with pytest.raises(Exception, match="[Dd]isallowed"):
            jnp.sin(np.ones(4))       # the guard is live: h2d is an error
    with jax.transfer_guard("disallow"):
        rollout.push_weights()        # the hand-off passes the same guard
    for leaf in jax.tree.leaves(rollout.serve.params):
        assert isinstance(leaf, jax.Array), \
            "push left a host array in the serve params"
    assert rollout.pool_occupancy() == 0


def test_rollout_rejects_bad_shapes():
    with pytest.raises(ValueError, match="group_size"):
        RolloutEngine(SPEC, groups=2, group_size=1, verbose=False)
    spec2 = RunSpec(arch="stablelm-1.6b", reduced=True, mesh_data=2,
                    mesh_model=1)
    with pytest.raises(ValueError, match="divisible"):
        RolloutEngine(spec2, groups=1, group_size=3, verbose=False)


def test_rollout_nan_skip_never_pushes_corrupted_weights():
    """Chaos: an injected NaN loss in iteration 1's train phase trips the
    HealthGuard — the update is skipped, the PUSH is skipped (serve never
    sees the poisoned params), and the pool still wakes for iteration 2's
    generate phase. The loop finishes with finite weights on both sides."""
    import jax

    eng = RolloutEngine(SPEC, plan="dp", groups=2, group_size=4,
                        prompt_len=8, gen=8, iters=3,
                        resilience="nan_loss@1", verbose=False)
    eng.run()

    skips = eng.events.of("skip")
    assert len(skips) == 1 and skips[0]["step"] == 1 \
        and skips[0]["reason"] == "nonfinite"
    assert eng.events.of("inject")[0]["site"] == "nan_loss"
    assert np.isnan(eng.history[1]["loss"])
    assert [h["skipped"] for h in eng.history] == [False, True, False]
    pushes = eng.events.of("phase")
    push_skips = [p["skipped"] for p in pushes if p["phase"] == "push"]
    assert push_skips == [False, True, False], \
        "the poisoned iteration must not push weights to serve"

    for leaf in jax.tree.leaves(eng.serve.params):
        assert np.all(np.isfinite(np.asarray(leaf))), \
            "corrupted weights leaked into the serve engine"
    for leaf in jax.tree.leaves(eng.train.state["params"]):
        arr = np.asarray(leaf)
        if np.issubdtype(arr.dtype, np.floating):
            assert np.all(np.isfinite(arr)), \
                "the skipped update leaked into the train state"
    assert int(eng.train.state["step"]) == 3, \
        "a skipped iteration still advances the step counter"
    # the pool woke after the skipped push: iteration 2 generated tokens
    assert eng.history[2]["gen_tok_s"] > 0
    assert np.isfinite(eng.history[2]["loss"])


def test_rollout_zero_cdp_stage_sharded_push(subproc):
    """The same loop under ``zero_cdp`` on a 2-device data mesh: reward
    rises, and the serve params equal a host-side ``unchunk_params``
    reconstruction of the stage-sharded f32 masters — the push
    all-gathered inside the compiled cast, the masters never left their
    shards."""
    out = subproc("""
import numpy as np
from repro.engine import RolloutEngine, RunSpec

spec = RunSpec(arch="stablelm-1.6b", reduced=True, mesh_data=2,
               mesh_model=1, plan="zero_cdp")
eng = RolloutEngine(spec, plan="zero_cdp", groups=2, group_size=4,
                    prompt_len=8, gen=8, iters=2, verbose=False)
hist = eng.run()
curve = [h["mean_reward"] for h in hist]
assert curve[-1] > curve[0], f"zero_cdp rollout did not improve: {curve}"

import jax
from repro.parallel import zero_cdp as zcdp
n = eng.train.mesh.shape[eng.train.trainer.data_axis]
layout = zcdp.build_stage_layout(eng.cfg, n)
full = zcdp.unchunk_params(layout, eng.train.state["params"]["stages"])
exp = jax.tree.map(lambda x, d: np.asarray(x.astype(d.dtype)),
                   full, eng.serve.params)
got = jax.tree.map(lambda d: np.asarray(d), eng.serve.params)
for e, g in zip(jax.tree.leaves(exp), jax.tree.leaves(got)):
    assert np.array_equal(e, g), "staged push diverged from the masters"
print("ZCDP_ROLLOUT_OK", curve)
""", n_devices=2, timeout=900)
    assert "ZCDP_ROLLOUT_OK" in out
