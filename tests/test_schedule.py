"""Properties of the cyclic schedule — the paper's Fig. 1 / Table 1 claims."""
import numpy as np
import pytest
from hyp_compat import given, settings, st

from repro.core import schedule as S


@given(st.integers(2, 32))
def test_cdp_every_worker_busy_every_tick(n):
    # each worker performs exactly one F or B micro-step per tick
    for tau in range(2 * n, 4 * n):
        kinds = [S.cdp_phase(w, tau, n).kind for w in range(n)]
        assert all(k in "FB" for k in kinds)


@given(st.integers(2, 32))
def test_cdp_stage_occupancy_disjoint(n):
    # at any tick, the (kind, stage) slots across workers are all distinct:
    # each stage runs at most one forward and one backward micro-step (the
    # resource feasibility behind Fig. 1b/1c)
    for tau in range(2 * n, 4 * n):
        slots = [(S.cdp_phase(w, tau, n).kind, S.cdp_phase(w, tau, n).stage)
                 for w in range(n)]
        assert len(set(slots)) == n


@given(st.integers(2, 32))
def test_cdp_total_activations_constant(n):
    tl = S.total_activation_timeline(n, cyclic=True)
    # constant across ticks, equal to N(N+1)/2 stage-units (paper Sec. 4.1)
    assert np.allclose(tl, tl[0])
    assert tl[0] == pytest.approx(n * (n + 1) / 2)


@given(st.integers(2, 32))
def test_dp_peaks_at_n_times_n(n):
    tl = S.total_activation_timeline(n, cyclic=False)
    assert tl.max() == pytest.approx(S.dp_peak_activations(n))
    # DP peak is ~2x the CDP constant
    assert tl.max() >= 2 * S.cdp_total_activations(n) * (n / (n + 1))


@given(st.integers(2, 24))
def test_u_matrix_rules(n):
    u_dp = S.u_matrix(S.RULE_DP, n)
    u1 = S.u_matrix(S.RULE_CDP_V1, n)
    u2 = S.u_matrix(S.RULE_CDP_V2, n)
    assert u_dp.all()
    assert not u1.any()
    # v2 is elementwise fresher than v1, staler than DP
    assert (u2 >= u1).all() and (u_dp >= u2).all()
    # v2 structure: micro-batch i uses fresh params on stages >= N-1-i
    for i in range(n):
        assert u2[i, S.fresh_threshold(S.RULE_CDP_V2, i, n):].all()
        assert not u2[i, :S.fresh_threshold(S.RULE_CDP_V2, i, n)].any()
    # the last micro-batch of the cycle is fully fresh under v2
    assert u2[n - 1].all()


@given(st.integers(2, 24))
def test_delay_at_most_one_step(n):
    for rule in S.RULES:
        d = S.delay_matrix(rule, n)
        assert d.min() >= 0 and d.max() <= 1


@given(st.integers(2, 16))
@settings(deadline=None)
def test_comm_events_balanced(n):
    """CDP gradient sends are spread evenly: every tick has the same number
    of point-to-point messages (+-1), and each worker sends at most one."""
    events = S.comm_events(n)
    by_tau = {}
    for e in events:
        by_tau.setdefault(e["tau"], []).append(e)
    counts = [len(v) for v in by_tau.values()]
    assert max(counts) - min(counts) <= 1
    assert max(counts) == -(-n // 2)        # half the workers are in backward
    for v in by_tau.values():
        srcs = [e["src"] for e in v]
        assert len(set(srcs)) == len(srcs)


def test_table1_matches_paper():
    t = S.table1(n=4, B=32, Pp=100.0, Pa=10.0, Pa_int=1.0)
    assert t["single_gpu_cdp"]["act_mem"] == pytest.approx(
        (4 + 1) / 2 * 32 * 10.0)
    assert t["single_gpu_dp"]["act_mem"] == pytest.approx(4 * 32 * 10.0)
    assert t["multi_gpu_cdp"]["comm_steps"] == "O(1)"
    assert t["multi_gpu_dp"]["comm_steps"] == "O(log N)"
    assert t["dp_mp_cdp"]["gpus"] == 4 * 5 // 2
    assert t["dp_mp"]["gpus"] == 16
    assert t["dp_mp_cdp"]["volume"] < t["dp_mp"]["volume"]
