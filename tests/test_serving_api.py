"""Wall-clock serving API: ServePolicy, chunked prefill, SLO admission,
streaming, and the fused per-step host sync.

The acceptance bar throughout is BITWISE parity: chunked prefill must
produce token-for-token the same greedy streams as whole-prompt
admission (dense AND paged, staggered arrivals, chunk widths that do not
divide the prompt length), and streaming callbacks must not perturb the
decode at all."""
import warnings

import numpy as np
import pytest

from repro.engine import Request, RunSpec, ServePolicy
from repro.engine.serve import ServeEngine

SPEC = RunSpec(arch="stablelm-1.6b", reduced=True, mesh_data=1,
               mesh_model=1)


@pytest.fixture(scope="module")
def dense_engine():
    eng = ServeEngine(SPEC, batch=2, prompt_len=12, gen=8, verbose=False)
    eng.build()
    return eng


@pytest.fixture(scope="module")
def paged_engine():
    # pool sized well above the 2-slot working set so registered prefix
    # blocks survive across serve() calls (the warm-prefix chunked test)
    eng = ServeEngine(SPEC, batch=2, prompt_len=12, gen=8, verbose=False,
                      paged=True, kv_block_size=4, kv_pool_blocks=40)
    eng.build()
    return eng


def _staggered(vocab, n=5, seed=0, plen=12, gen=8, rid0=0):
    """Deterministic Poisson-staggered workload; rid0 offsets rids so two
    serves of "the same" workload never collide in a shared history."""
    from repro.engine import batching
    proto = batching.synthetic_requests(n, vocab, plen, gen,
                                        arrival="poisson", rate=0.7,
                                        seed=seed)
    return [Request(rid=rid0 + r.rid, prompt=list(r.prompt),
                    max_gen=r.max_gen, arrival_step=r.arrival_step)
            for r in proto]


def _tok_map(res, rid0=0):
    return {r.rid - rid0: r.tokens.tolist() for r in res["requests"]}


# ---------------------------------------------------------------------------
# ServePolicy resolver + deprecated kwargs
# ---------------------------------------------------------------------------

def test_legacy_kwargs_warn_and_match_policy(dense_engine):
    """serve(max_slots=...) still works, emits ONE DeprecationWarning
    naming the kwargs, and is bitwise identical to the ServePolicy path."""
    vocab = dense_engine.cfg.vocab_size
    with warnings.catch_warnings():
        warnings.simplefilter("error")      # no warning on the new path
        base = dense_engine.serve(_staggered(vocab, n=3),
                                  policy=ServePolicy(max_slots=2))
    with pytest.warns(DeprecationWarning, match="max_slots"):
        legacy = dense_engine.serve(_staggered(vocab, n=3, rid0=100),
                                    max_slots=2)
    assert _tok_map(legacy, rid0=100) == _tok_map(base)


def test_policy_instance_plus_legacy_kwargs_is_type_error(dense_engine):
    with pytest.raises(TypeError, match="does not combine"):
        dense_engine.serve(policy=ServePolicy(max_slots=2), max_slots=2)


def test_policy_validation():
    with pytest.raises(ValueError, match="clock"):
        ServePolicy(clock="sundial")
    with pytest.raises(ValueError, match="prefill_chunk"):
        ServePolicy(prefill_chunk=-1)
    with pytest.raises(ValueError, match="admission"):
        ServePolicy(admission="vip")


# ---------------------------------------------------------------------------
# Chunked prefill: bitwise parity with whole-prompt admission
# ---------------------------------------------------------------------------

def test_chunked_prefill_bitwise_parity_dense(dense_engine):
    """Chunk width 5 over 12-token prompts (non-multiple), staggered
    Poisson arrivals over 2 slots: token streams must be bitwise
    identical to whole-prompt prefill."""
    vocab = dense_engine.cfg.vocab_size
    base = dense_engine.serve(_staggered(vocab),
                              policy=ServePolicy(max_slots=2))
    chunk = dense_engine.serve(_staggered(vocab, rid0=100),
                               policy=ServePolicy(max_slots=2,
                                                  prefill_chunk=5))
    assert _tok_map(chunk, rid0=100) == _tok_map(base)
    # the chunked run really did split prompts: more prefill dispatches
    # than admissions (12 tokens / width 5 -> 3 chunks per request)
    assert chunk["metrics"]["prefill_calls"] > \
        base["metrics"]["prefill_calls"]
    assert chunk["metrics"]["prefill_chunk"] == 5


def test_chunked_prefill_bitwise_parity_paged(paged_engine):
    vocab = paged_engine.cfg.vocab_size
    base = paged_engine.serve(_staggered(vocab, seed=3),
                              policy=ServePolicy(max_slots=2))
    chunk = paged_engine.serve(_staggered(vocab, seed=3, rid0=100),
                               policy=ServePolicy(max_slots=2,
                                                  prefill_chunk=5))
    assert _tok_map(chunk, rid0=100) == _tok_map(base)


def test_chunked_prefill_prefix_hits_skip_cached_spans(paged_engine):
    """Re-serving identical prompts chunked must consume the prefix cache
    (hit spans skipped -> fewer marginal prefill tokens) and stay bitwise
    identical; blocks a chunked admission registers must also be
    matchable by LATER chunked admissions once marked written."""
    vocab = paged_engine.cfg.vocab_size
    base = paged_engine.serve(_staggered(vocab, n=3, seed=7),
                              policy=ServePolicy(max_slots=2))
    warm = paged_engine.serve(_staggered(vocab, n=3, seed=7, rid0=100),
                              policy=ServePolicy(max_slots=2,
                                                 prefill_chunk=5))
    assert _tok_map(warm, rid0=100) == _tok_map(base)
    assert warm["metrics"]["paging"]["prefix_hit_rate"] > 0.5
    # chunked-registered blocks feed the NEXT chunked run's prefix hits
    warm2 = paged_engine.serve(_staggered(vocab, n=3, seed=7, rid0=200),
                               policy=ServePolicy(max_slots=2,
                                                  prefill_chunk=5))
    assert _tok_map(warm2, rid0=200) == _tok_map(base)
    assert warm2["metrics"]["paging"]["prefix_hit_rate"] > 0.5


def test_long_prompt_does_not_stall_coresidents(dense_engine):
    """A long prompt prefilling in chunks must not starve its co-resident:
    the short request's first token lands BEFORE the long prompt finishes
    prefilling, and everything still completes."""
    vocab = dense_engine.cfg.vocab_size
    rng = np.random.default_rng(11)
    long_r = Request(rid=0, prompt=rng.integers(
        1, vocab, size=12).tolist(), max_gen=4)
    short_r = Request(rid=1, prompt=rng.integers(
        1, vocab, size=3).tolist(), max_gen=6)
    res = dense_engine.serve(
        [long_r, short_r],
        policy=ServePolicy(max_slots=2, prefill_chunk=3, clock="virtual"))
    assert all(r.status == "ok" for r in res["requests"])
    done = [e for e in dense_engine.events.of("prefill_done")
            if e["rid"] == 0]
    assert done and done[-1]["chunks"] == 4
    # short_r (single chunk) emits at its admission iteration (t=0);
    # long_r first emits only after its 4th chunk. ttft p50 below the
    # long prompt's chunk count proves the interleave.
    assert res["metrics"]["ttft"]["p50"] < done[-1]["chunks"]


# ---------------------------------------------------------------------------
# Fused host sync
# ---------------------------------------------------------------------------

def test_single_fused_host_transfer_per_step(dense_engine):
    """eos scanning + health quarantine + streaming share ONE [2, B] host
    transfer per emission iteration; with none of them armed there are
    ZERO per-step transfers."""
    vocab = dense_engine.cfg.vocab_size
    free = dense_engine.serve(_staggered(vocab, n=3),
                              policy=ServePolicy(max_slots=2))
    assert free["metrics"]["host_syncs"] == 0
    eng = ServeEngine(SPEC, batch=2, prompt_len=12, gen=8, verbose=False,
                      resilience="on")
    res = eng.serve(_staggered(vocab, n=3, rid0=100),
                    policy=ServePolicy(max_slots=2, eos_id=0))
    m = res["metrics"]
    assert m["emission_iters"] > 0
    assert m["host_syncs"] == m["emission_iters"]


# ---------------------------------------------------------------------------
# Streaming
# ---------------------------------------------------------------------------

def test_serve_stream_bitwise_and_full_coverage(dense_engine):
    """serve_stream() yields every emitted token in order and the greedy
    rows are bitwise identical to the callback-free serve."""
    vocab = dense_engine.cfg.vocab_size
    base = dense_engine.serve(_staggered(vocab, n=4),
                              policy=ServePolicy(max_slots=2))
    gen = dense_engine.serve_stream(_staggered(vocab, n=4, rid0=100),
                                    policy=ServePolicy(max_slots=2))
    streamed = {}
    while True:
        try:
            rid, tok = next(gen)
        except StopIteration as fin:
            res = fin.value
            break
        streamed.setdefault(rid - 100, []).append(tok)
    tb = _tok_map(base)
    assert _tok_map(res, rid0=100) == tb
    assert streamed == {k: v for k, v in tb.items() if v}


def test_on_token_callback_does_not_perturb_decode(dense_engine):
    vocab = dense_engine.cfg.vocab_size
    base = dense_engine.serve(_staggered(vocab, n=3),
                              policy=ServePolicy(max_slots=2))
    seen = []
    reqs = _staggered(vocab, n=3, rid0=100)
    for r in reqs:
        r.on_token = lambda rid, tok, step, wt: seen.append((rid, tok))
    res = dense_engine.serve(reqs, policy=ServePolicy(max_slots=2))
    tb = _tok_map(base)
    assert _tok_map(res, rid0=100) == tb
    got = {}
    for rid, tok in seen:
        got.setdefault(rid - 100, []).append(tok)
    assert got == {k: v for k, v in tb.items() if v}


# ---------------------------------------------------------------------------
# Clocks + SLO admission
# ---------------------------------------------------------------------------

def _slo_workload(rid0=0):
    """Two doomed requests (deadline < their own decode time) arriving
    first, six feasible short ones behind them. FCFS burns both slots on
    the doomed pair; SLO's feasibility cull skips them."""
    reqs = []
    for i in range(2):
        reqs.append(Request(rid=rid0 + i, prompt=list(range(1, 13)),
                            max_gen=8, arrival_step=0, deadline_steps=6))
    for i in range(6):
        reqs.append(Request(rid=rid0 + 10 + i, prompt=list(range(1, 7)),
                            max_gen=3, arrival_step=0, deadline_steps=14))
    return reqs


def test_slo_admission_beats_fcfs_goodput(dense_engine):
    fcfs = dense_engine.serve(
        _slo_workload(),
        policy=ServePolicy(max_slots=2, clock="virtual",
                           admission="fcfs"))["metrics"]
    slo = dense_engine.serve(
        _slo_workload(rid0=100),
        policy=ServePolicy(max_slots=2, clock="virtual",
                           admission="slo"))["metrics"]
    assert slo["goodput"] > fcfs["goodput"]
    assert slo["ttft"]["p99"] <= fcfs["ttft"]["p99"]
    assert np.isfinite(slo["ttft"]["p99"])


def test_virtual_clock_is_deterministic(dense_engine):
    vocab = dense_engine.cfg.vocab_size
    runs = []
    for rid0 in (0, 100):
        res = dense_engine.serve(
            _staggered(vocab, n=4, rid0=rid0),
            policy=ServePolicy(max_slots=2, clock="virtual", step_dt=0.25,
                               prefill_chunk=5))
        runs.append((_tok_map(res, rid0=rid0), res["metrics"]["ttft"],
                     res["metrics"]["goodput"]))
    assert runs[0] == runs[1]


def test_step_clock_metrics_report_policy(dense_engine):
    vocab = dense_engine.cfg.vocab_size
    m = dense_engine.serve(_staggered(vocab, n=2),
                           policy=ServePolicy(max_slots=2))["metrics"]
    assert m["clock"] == "step"
    assert m["admission"] == "fcfs"
    assert m["prefill_chunk"] == 0
