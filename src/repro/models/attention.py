"""Attention variants: GQA/MHA (+ sliding window, partial/2D RoPE), MLA.

Prefill/train attention is computed **blockwise over the KV axis** with an
online softmax (flash-attention structure in pure jnp) so that no [S, S]
score tensor is ever materialised — required for the 32k prefill shapes.

``blockwise_attention`` dispatches on the per-op kernel backend registry
(``repro.kernels.registry``; ``cfg.kernels``, with ``cfg.attn_backend`` as
the deprecated alias): the jnp path here is the reference/default, and
``backend="pallas"`` routes both forward and backward through the fused
Pallas TPU kernels in ``repro.kernels`` (``ops.flash_attention``'s
custom_vjp — dq + dk/dv kernels), falling back to interpreter mode off-TPU.
Decode dispatches ``ops.decode_attention`` (flash-decode) the same way via
the ``decode_attn`` op. See the backend matrix in ROADMAP.md.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.models.common import dense_init, split_dict
from repro.models.layers import apply_rope

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention — reference path for all archs
# ---------------------------------------------------------------------------

def _mask_for(block, Sk, q_pos, kv_pos, causal, window):
    valid = kv_pos < Sk
    if causal:
        valid = valid & (kv_pos <= q_pos)
    if window:
        valid = valid & (kv_pos > q_pos - window)
    return valid


def _flash_fwd_scan(q, k, v, *, causal, window, q_offset, block, sk_valid=None):
    """Returns (out [B,Sq,KV,G,dv], lse [B,Sq,G,KV]).

    ``q_offset`` / ``sk_valid`` may be [B] int32 arrays (per-row ragged
    offsets/lengths — the paged-prefill path, which calls this scan directly
    since custom_vjp nondiff args must be static); scalars broadcast as
    before and stay bit-identical to the original code path."""
    B, Sq, KV, G, dh = q.shape
    dv = v.shape[-1]
    scale = 1.0 / math.sqrt(dh)
    if getattr(q_offset, "ndim", 0):
        q_pos = q_offset[:, None, None] + jnp.arange(Sq)[None, :, None]
    else:
        q_pos = (jnp.arange(Sq) + q_offset)[None, :, None]       # [1,Sq,1]
    if sk_valid is None:
        Sk = k.shape[1]
    elif getattr(sk_valid, "ndim", 0):
        Sk = sk_valid[:, None, None]                             # [B,1,1]
    else:
        Sk = sk_valid

    nblk = k.shape[1] // block
    kb = jnp.moveaxis(k.reshape(B, nblk, block, KV, dh), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nblk, block, KV, dv), 1, 0)

    def step(carry, inp):
        m, l, acc, bi = carry
        kblk, vblk = inp
        kv_pos = bi * block + jnp.arange(block)[None, None, :]   # [1,1,blk]
        s = jnp.einsum("bsjgd,btjd->bsgjt", q, kblk,
                       preferred_element_type=jnp.float32) * scale
        valid = _mask_for(block, Sk, q_pos, kv_pos, causal, window)
        s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bsgjt,btjd->bsgjd", p, vblk.astype(jnp.float32))
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc, bi + 1), None

    m0 = jnp.full((B, Sq, G, KV), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, G, KV), jnp.float32)
    a0 = jnp.zeros((B, Sq, G, KV, dv), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, a0, jnp.int32(0)), (kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 2, 3)          # [B,Sq,G,KV,dv] -> [B,Sq,KV,G,dv]
    lse = m + jnp.log(jnp.maximum(l, 1e-30))   # [B,Sq,G,KV]
    return out, lse


def _flash_bwd_scan(res, do, *, causal, window, q_offset, block, sk_valid=None):
    """Flash backward: recompute scores blockwise from the saved logsumexp —
    memory O(S*block) instead of the O(S^2) an AD-of-scan would save."""
    q, k, v, out, lse = res          # q/out: [B,Sq,KV,G,*]; k/v: [B,Sk,KV,*]
    B, Sq, KV, G, dh = q.shape
    Sk = k.shape[1] if sk_valid is None else sk_valid
    dv = v.shape[-1]
    scale = 1.0 / math.sqrt(dh)
    q_pos = (jnp.arange(Sq) + q_offset)[None, :, None]

    nblk = k.shape[1] // block
    kb = jnp.moveaxis(k.reshape(B, nblk, block, KV, dh), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nblk, block, KV, dv), 1, 0)
    dof = do.astype(jnp.float32)
    delta = jnp.sum(dof * out, axis=-1)                    # [B,Sq,KV,G]

    def step(carry, inp):
        dq, bi = carry
        kblk, vblk = inp
        kv_pos = bi * block + jnp.arange(block)[None, None, :]
        s = jnp.einsum("bsjgd,btjd->bsgjt", q, kblk,
                       preferred_element_type=jnp.float32) * scale
        valid = _mask_for(block, Sk, q_pos, kv_pos, causal, window)
        s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                    # [B,Sq,G,KV,blk]
        dv_blk = jnp.einsum("bsgjt,bsjgd->btjd", p, dof)
        dp = jnp.einsum("bsjgd,btjd->bsgjt", dof, vblk.astype(jnp.float32))
        dlt = jnp.moveaxis(delta, 2, 3)                    # [B,Sq,G,KV]
        ds = p * (dp - dlt[..., None]) * scale
        dq = dq + jnp.einsum("bsgjt,btjd->bsjgd", ds, kblk.astype(jnp.float32))
        dk_blk = jnp.einsum("bsgjt,bsjgd->btjd", ds, q.astype(jnp.float32))
        return (dq, bi + 1), (dk_blk, dv_blk)

    dq0 = jnp.zeros(q.shape, jnp.float32)
    (dq, _), (dk_b, dv_b) = jax.lax.scan(step, (dq0, jnp.int32(0)), (kb, vb))
    sk_pad = k.shape[1]
    dk = jnp.moveaxis(dk_b, 0, 1).reshape(B, sk_pad, KV, dh)
    dvv = jnp.moveaxis(dv_b, 0, 1).reshape(B, sk_pad, KV, dv)
    return dq.astype(q.dtype), dk.astype(k.dtype), dvv.astype(v.dtype)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, window, q_offset, block, sk_valid):
    out, _ = _flash_fwd_scan(q, k, v, causal=causal, window=window,
                             q_offset=q_offset, block=block, sk_valid=sk_valid)
    return out


def _flash_f(q, k, v, causal, window, q_offset, block, sk_valid):
    out, lse = _flash_fwd_scan(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, block=block, sk_valid=sk_valid)
    return out, (q, k, v, out, lse)


def _flash_b(causal, window, q_offset, block, sk_valid, res, do):
    return _flash_bwd_scan(res, do, causal=causal, window=window,
                           q_offset=q_offset, block=block, sk_valid=sk_valid)


_flash.defvjp(_flash_f, _flash_b)


ATTN_BACKENDS = ("jnp", "pallas")


def blockwise_attention(q, k, v, *, causal: bool, window: int = 0,
                        q_offset: int = 0, block: int = 512,
                        backend: str = "jnp"):
    """q: [B,Sq,H,dh], k: [B,Sk,KV,dh], v: [B,Sk,KV,dv] -> [B,Sq,H,dv].

    ``backend`` selects the contraction (the ``attn_backend`` config knob):

      * ``"jnp"``    — flash-structured blockwise online softmax in pure jnp
                       with a custom VJP that recomputes scores instead of
                       storing [Sq, Sk]; runs on any jax backend. This is
                       the reference twin of kernels/flash_attention.py.
      * ``"pallas"`` — fused Pallas TPU kernels for forward AND backward
                       (``repro.kernels.ops.flash_attention``'s custom_vjp);
                       interpreter mode is selected automatically off-TPU so
                       CPU training/tests still run. ``block`` applies to
                       the jnp path only — the kernels tile at their own
                       MXU-aligned bq/bk defaults.

    GQA: H must be a multiple of KV; query head g attends kv head g*KV//H.
    ``causal`` masks kv_pos > q_pos with q_pos = q_offset + arange(Sq).
    ``window``>0 additionally masks kv_pos <= q_pos - window (sliding window).
    """
    if backend not in ATTN_BACKENDS:
        raise ValueError(
            f"unknown attn backend {backend!r}; expected one of {ATTN_BACKENDS}")
    if backend == "pallas":
        from repro.kernels import ops
        return ops.flash_attention(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            interpret=ops.default_interpret())
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    G = H // KV
    block = min(block, Sk)
    nblk = -(-Sk // block)
    pad = nblk * block - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qr = q.reshape(B, Sq, KV, G, dh)
    out = _flash(qr, k, v, causal, window, q_offset, block, Sk)
    return out.reshape(B, Sq, H, dv).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0,
                     backend: str = "jnp"):
    """Single-token attention. q: [B,1,H,dh]; caches: [B,T,KV,dh/dv].

    ``cache_len``: [B] int32 — number of valid cache entries (the new token's
    position is cache_len - 1 after insertion).  ``backend`` is the
    ``decode_attn`` registry op: ``"pallas"`` dispatches the flash-decode
    kernel (``ops.decode_attention``, interpreter mode off-TPU).
    """
    if backend == "pallas":
        from repro.kernels import ops
        return ops.decode_attention(q, k_cache, v_cache, cache_len,
                                    window=window,
                                    interpret=ops.default_interpret())
    B, _, H, dh = q.shape
    T, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(dh)
    qr = q.reshape(B, KV, G, dh)
    s = jnp.einsum("bjgd,btjd->bjgt", qr, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(T)[None, None, None, :]
    cl = cache_len[:, None, None, None]
    valid = pos < cl
    if window:
        valid = valid & (pos > cl - 1 - window)
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bjgt,btjd->bjgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, v_cache.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA projection layer (covers MHA, multi-query, SWA, partial/2D rope, bias)
# ---------------------------------------------------------------------------

def gqa_init(key, cfg, dtype):
    d, H, KV = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    ks = split_dict(key, ["wq", "wk", "wv", "wo"])
    p = {"wq": dense_init(ks["wq"], d, H * hd, dtype),
         "wk": dense_init(ks["wk"], d, KV * hd, dtype),
         "wv": dense_init(ks["wv"], d, KV * hd, dtype),
         "wo": dense_init(ks["wo"], H * hd, d, dtype)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    return p


def _project_qkv(p, cfg, x):
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (q.reshape(B, S, H, hd), k.reshape(B, S, KV, hd),
            v.reshape(B, S, KV, hd))


def _gqa_attend(p, cfg, x, positions, *, causal, window):
    """Shared project + rope + blockwise-attention body of apply/prefill.
    Returns (ctx [B,S,H*dv], roped k, v) so prefill can cache k/v without
    re-deriving them (one body — the numerics cannot diverge)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x)
    if positions.ndim == 1:
        positions = positions[None, :]
    q = apply_rope(q, positions, cfg.rope_theta, cfg.partial_rotary_factor,
                   interleaved=cfg.rope_2d)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.partial_rotary_factor,
                   interleaved=cfg.rope_2d)
    out = blockwise_attention(q, k, v, causal=causal, window=window,
                              backend=registry.active_attn_backend(cfg))
    return out.reshape(B, S, -1), k, v


def gqa_apply(p, cfg, x, positions, *, causal=True, window=None):
    """Self-attention over x: [B,S,d]. positions: [B,S] or [S]."""
    win = cfg.attn_window if window is None else window
    ctx, _, _ = _gqa_attend(p, cfg, x, positions, causal=causal, window=win)
    return ctx @ p["wo"]


def gqa_prefill(p, cfg, x, positions, cache, *, window=None, lengths=None):
    """Fused full-sequence prefill: ONE blockwise/flash attention pass over
    the prompt that also fills the decode cache (rope'd k/v at every prompt
    position) — replaces teacher-forcing the prompt through ``gqa_decode``
    token by token. Returns (out [B,S,d], new_cache).

    ``lengths`` ([B] int32, optional): ragged prompts packed left-aligned
    into the fixed [B,S] buffer. Every position is projected and written,
    but the cache ``len`` becomes per-row, so decode masking (and the next
    write slot) never sees a row's pad tail."""
    S = x.shape[1]
    win = cfg.attn_window if window is None else window
    ctx, k, v = _gqa_attend(p, cfg, x, positions, causal=True, window=win)
    T = cache["k"].shape[1]
    ring = bool(win) and win == T
    add = jnp.int32(S) if lengths is None else lengths.astype(jnp.int32)
    new_cache = {"k": _prefill_fill(cache["k"], k, ring),
                 "v": _prefill_fill(cache["v"], v, ring),
                 "len": cache["len"] + add}
    return ctx @ p["wo"], new_cache


def _prefill_fill(buf, new, ring: bool):
    """Write a [B,S,...] prefill projection into a [B,T,...] cache buffer,
    preserving the decode-slot invariant (position p lives at slot p % T on
    the ring, slot p otherwise)."""
    T, S = buf.shape[1], new.shape[1]
    new = new.astype(buf.dtype)
    if S <= T:
        return jax.lax.dynamic_update_slice_in_dim(buf, new, 0, axis=1)
    if not ring:
        raise ValueError(f"prompt length {S} exceeds cache length {T}")
    # keep the last T positions; position p = S-T+i -> slot p % T = (i + S) % T
    return jnp.roll(new[:, S - T:], S % T, axis=1)


def gqa_prefill_chunked(p, cfg, x, cache, lengths, hist):
    """Chunked dense prefill: ``x`` holds each row's NEXT prompt chunk
    (absolute positions ``hist[b]..lengths[b]``, packed left-aligned), which
    is scattered into the row's [T] cache at its absolute slots and attended
    over the row's full logical range — the dense-cache twin of
    ``gqa_prefill_paged``. Rows with ``hist == lengths`` are pure
    passengers: nothing is written, ``len`` is unchanged, and their (unused)
    output attends an empty range.

    A row's FIRST chunk (``hist == 0``) zeroes the whole cache row before
    scattering: the whole-prompt path gets fresh zero rows from the
    admission merge, and a quarantined previous tenant may have left NaN —
    which would leak through decode's exactly-zero masked probabilities
    (0 * NaN = NaN). Sliding windows are unsupported (the engine gates
    chunked prefill to non-windowed archs). Returns (out [B,S,d],
    new_cache)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x)
    pos = hist[:, None] + jnp.arange(S)[None, :]                 # [B,S]
    q = apply_rope(q, pos, cfg.rope_theta, cfg.partial_rotary_factor,
                   interleaved=cfg.rope_2d)
    k = apply_rope(k, pos, cfg.rope_theta, cfg.partial_rotary_factor,
                   interleaved=cfg.rope_2d)
    T = cache["k"].shape[1]
    reset = ((hist == 0) & (lengths > 0))[:, None, None, None]
    k_buf = jnp.where(reset, jnp.zeros_like(cache["k"]), cache["k"])
    v_buf = jnp.where(reset, jnp.zeros_like(cache["v"]), cache["v"])
    valid = jnp.arange(S)[None, :] < (lengths - hist)[:, None]   # [B,S]
    dst = jnp.where(valid, pos, T)          # invalid lanes: dropped OOB
    bidx = jnp.arange(B)[:, None]
    k_buf = k_buf.at[bidx, dst].set(k.astype(k_buf.dtype), mode="drop")
    v_buf = v_buf.at[bidx, dst].set(v.astype(v_buf.dtype), mode="drop")
    new_len = lengths.astype(jnp.int32)
    ctx = paged_prefill_attention_ref(q, k_buf, v_buf,
                                      hist.astype(jnp.int32), new_len)
    new_cache = {"k": k_buf, "v": v_buf, "len": new_len}
    return ctx.reshape(B, S, -1) @ p["wo"], new_cache


def gqa_decode(p, cfg, x, cache, *, window=None, ragged=False, active=None):
    """One-token decode. x: [B,1,d]; cache: {"k","v": [B,T,KV,hd], "len": [B]}.

    ``ragged=True`` is the continuous-batching path: every row sits at its
    own cache position (``len`` is genuinely per-row), so the write is a
    per-row scatter instead of one dynamic_update_slice.

    ``active`` ([B] bool, ragged-only) marks rows genuinely decoding this
    step: inactive rows (slots mid-chunked-prefill) drop their cache write
    and keep their ``len`` — a decode step must not clobber a half-filled
    prompt. ``active=None`` (or all-True) is value-identical to the
    historical path.
    """
    B = x.shape[0]
    q, k, v = _project_qkv(p, cfg, x)
    pos = cache["len"][:, None]                                   # [B,1]
    q = apply_rope(q, pos, cfg.rope_theta, cfg.partial_rotary_factor,
                   interleaved=cfg.rope_2d)
    k = apply_rope(k, pos, cfg.rope_theta, cfg.partial_rotary_factor,
                   interleaved=cfg.rope_2d)
    T = cache["k"].shape[1]
    win = cfg.attn_window if window is None else window
    ring = bool(win) and win == T      # cache sized exactly to the window
    if ragged:
        # Per-row slot: serving-only — the scatter would force GSPMD to
        # all-gather a batch-sharded cache, which is why the training-shaped
        # synchronized branch below stays the default.
        slot = cache["len"] % T if ring else jnp.minimum(cache["len"], T - 1)
        bidx = jnp.arange(B)
        if active is not None:
            slot = jnp.where(active, slot, T)        # inactive: dropped OOB
        k_cache = cache["k"].at[bidx, slot].set(k[:, 0], mode="drop")
        v_cache = cache["v"].at[bidx, slot].set(v[:, 0], mode="drop")
    else:
        if active is not None:
            raise ValueError("active mask requires ragged=True")
        # Synchronized batched decode: all rows advance together, so the
        # write is a dynamic_update_slice on the (unsharded) time axis. A
        # per-row scatter (`.at[arange(B), slot]`) forces GSPMD to
        # all-gather the whole batch-sharded cache — a 48 GiB burst at
        # decode_32k scale.
        if ring:
            slot0 = cache["len"][0] % T                           # ring buffer
        else:
            slot0 = jnp.minimum(cache["len"][0], T - 1)
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot0,
                                                      axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot0,
                                                      axis=1)
    new_len = cache["len"] + (jnp.int32(1) if active is None
                              else active.astype(jnp.int32))
    out = decode_attention(q, k_cache, v_cache, new_len,
                           window=0 if ring else win,
                           backend=registry.backend_for(cfg, "decode_attn"))
    new_cache = {"k": k_cache, "v": v_cache, "len": new_len}
    return out.reshape(B, 1, -1) @ p["wo"], new_cache


def gqa_cache_init(cfg, batch: int, cache_len: int, dtype):
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    T = min(cache_len, cfg.attn_window) if cfg.attn_window else cache_len
    return {"k": jnp.zeros((batch, T, KV, hd), dtype),
            "v": jnp.zeros((batch, T, KV, hd), dtype),
            "len": jnp.zeros((batch,), jnp.int32)}


# ---------------------------------------------------------------------------
# Paged GQA: the KV cache is a pool of fixed-size blocks shared by all rows,
# k/v [NB+1, bs, KV, hd] (block NB is the write-off "trash" block), plus a
# per-row block table [B, nb] owned by the engine. Logical cache position p of
# row b lives at pool slot (table[b, p // bs], p % bs). Masked/out-of-range
# writes are redirected to the trash block, and every read path zeroes V
# outside validity (pool blocks may hold garbage, even NaN, from freed or
# quarantined rows — 0 * NaN would leak through the exactly-zero masked
# probabilities). Valid lanes are untouched, which is what keeps the paged
# path bitwise-identical to the dense cache.
# ---------------------------------------------------------------------------

def paged_gqa_cache_init(cfg, batch: int, num_blocks: int, block_size: int,
                         dtype):
    """One layer's slice of the paged pool (stacked per layer by the model)."""
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    # PAGED_POISON=1 initialises the pool (trash block included) with NaN
    # instead of zeros: any read of a never-written lane that escapes the
    # masks then surfaces as NaN logits instead of silently reading zeros —
    # the debug switch that turns "rare flaky token mismatch" into a
    # deterministic failure (tests/test_paged_cache.py uses it as a canary)
    import os
    fill = float("nan") if os.environ.get("PAGED_POISON") else 0.0
    return {"k": jnp.full((num_blocks + 1, block_size, KV, hd), fill, dtype),
            "v": jnp.full((num_blocks + 1, block_size, KV, hd), fill, dtype),
            "len": jnp.zeros((batch,), jnp.int32)}


def _paged_gather(pool, table):
    """[NB+1, bs, KV, hd] gathered to the row-major logical layout
    [B, nb*bs, KV, hd] through the [B, nb] block table."""
    B, nb = table.shape
    bs = pool.shape[1]
    g = pool[table]                                  # [B, nb, bs, KV, hd]
    return g.reshape(B, nb * bs, g.shape[-2], g.shape[-1])


def paged_prefill_attention_ref(q, k_cache, v_cache, q_start, kv_len, *,
                                block: int = 512):
    """jnp reference for the ragged-tail paged prefill: q [B,Sq,H,dh] holds
    new tokens at per-row absolute offsets ``q_start``; k/v_cache [B,T,KV,*]
    is the gathered logical cache (garbage beyond ``kv_len``)."""
    B, Sq, H, dh = q.shape
    Sk, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    block = min(block, Sk)
    nblk = -(-Sk // block)
    pad = nblk * block - Sk
    vmask = (jnp.arange(Sk)[None, :] < kv_len[:, None])[:, :, None, None]
    v_cache = jnp.where(vmask, v_cache, 0)
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qr = q.reshape(B, Sq, KV, G, dh)
    out, _ = _flash_fwd_scan(qr, k_cache, v_cache, causal=True, window=0,
                             q_offset=q_start.astype(jnp.int32), block=block,
                             sk_valid=kv_len.astype(jnp.int32))
    return out.reshape(B, Sq, H, v_cache.shape[-1]).astype(q.dtype)


def gqa_prefill_paged(p, cfg, x, cache, table, lengths, hist):
    """Paged ragged prefill: scatter the new tail (absolute positions
    ``hist[b]..lengths[b]`` of each row) into the block pool through the
    table, then attend the tail queries over the row's full logical range —
    positions below ``hist`` are served by already-filled (possibly shared)
    blocks, which is how a prefix-cache hit skips recomputing the prefix.
    Rows with ``hist == lengths`` write nothing (their tail is empty).
    Returns (out [B,S,d], new layer cache)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x)
    pos = hist[:, None] + jnp.arange(S)[None, :]                 # [B,S]
    q = apply_rope(q, pos, cfg.rope_theta, cfg.partial_rotary_factor,
                   interleaved=cfg.rope_2d)
    k = apply_rope(k, pos, cfg.rope_theta, cfg.partial_rotary_factor,
                   interleaved=cfg.rope_2d)
    nb = table.shape[1]
    bs = cache["k"].shape[1]
    trash = cache["k"].shape[0] - 1
    valid = jnp.arange(S)[None, :] < (lengths - hist)[:, None]
    lb = jnp.clip(pos // bs, 0, nb - 1)
    phys = jnp.take_along_axis(table, lb, axis=1)                # [B,S]
    # invalid lanes (and lanes whose table entry is unallocated) are DROPPED
    # via an out-of-bounds index — never scattered into the trash block,
    # which stays all-zero so nothing nondeterministic can ever be read back
    phys = jnp.where(valid & (phys != trash), phys, trash + 1)
    off = pos % bs
    k_pool = cache["k"].at[phys, off].set(k.astype(cache["k"].dtype),
                                          mode="drop")
    v_pool = cache["v"].at[phys, off].set(v.astype(cache["v"].dtype),
                                          mode="drop")
    new_len = lengths.astype(jnp.int32)
    if registry.backend_for(cfg, "paged_attn") == "pallas":
        from repro.kernels import ops
        ctx = ops.paged_prefill_attention(q, k_pool, v_pool, table,
                                          hist.astype(jnp.int32), new_len,
                                          interpret=ops.default_interpret())
    else:
        gk = _paged_gather(k_pool, table)
        gv = _paged_gather(v_pool, table)
        ctx = paged_prefill_attention_ref(q, gk, gv, hist, new_len)
    new_cache = {"k": k_pool, "v": v_pool, "len": new_len}
    return ctx.reshape(B, S, -1) @ p["wo"], new_cache


def gqa_decode_paged(p, cfg, x, cache, table, active=None):
    """One-token paged decode: scatter the new K/V at pool slot
    (table[b, len // bs], len % bs), attend over the row's logical range.
    Always ragged (per-row ``len``); sliding windows are unsupported — the
    engine gates paged mode to non-windowed GQA archs.

    ``active`` ([B] bool): rows mid-chunked-prefill drop their write into
    the out-of-bounds lane and keep their ``len`` (see ``gqa_decode``)."""
    B = x.shape[0]
    q, k, v = _project_qkv(p, cfg, x)
    pos = cache["len"][:, None]                                  # [B,1]
    q = apply_rope(q, pos, cfg.rope_theta, cfg.partial_rotary_factor,
                   interleaved=cfg.rope_2d)
    k = apply_rope(k, pos, cfg.rope_theta, cfg.partial_rotary_factor,
                   interleaved=cfg.rope_2d)
    nb = table.shape[1]
    bs = cache["k"].shape[1]
    trash = cache["k"].shape[0] - 1
    lb = jnp.clip(cache["len"] // bs, 0, nb - 1)
    phys = jnp.take_along_axis(table, lb[:, None], axis=1)[:, 0]  # [B]
    # rows without an allocated block here (freed slots that keep stepping)
    # drop their write out of bounds — the trash block stays all-zero
    phys = jnp.where(phys == trash, trash + 1, phys)
    if active is not None:
        phys = jnp.where(active, phys, trash + 1)
    off = cache["len"] % bs
    k_pool = cache["k"].at[phys, off].set(k[:, 0].astype(cache["k"].dtype),
                                          mode="drop")
    v_pool = cache["v"].at[phys, off].set(v[:, 0].astype(cache["v"].dtype),
                                          mode="drop")
    new_len = cache["len"] + (jnp.int32(1) if active is None
                              else active.astype(jnp.int32))
    if registry.backend_for(cfg, "paged_attn") == "pallas":
        from repro.kernels import ops
        out = ops.paged_decode_attention(q, k_pool, v_pool, table, new_len,
                                         interpret=ops.default_interpret())
    else:
        gk = _paged_gather(k_pool, table)
        gv = _paged_gather(v_pool, table)
        T = gv.shape[1]
        vmask = (jnp.arange(T)[None, :] < new_len[:, None])[:, :, None, None]
        gv = jnp.where(vmask, gv, 0)
        out = decode_attention(q, gk, gv, new_len, window=0, backend="jnp")
    new_cache = {"k": k_pool, "v": v_pool, "len": new_len}
    return out.reshape(B, 1, -1) @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# Cross-attention (enc-dec): q from decoder, kv from encoder memory (no rope)
# ---------------------------------------------------------------------------

def cross_attn_init(key, cfg, dtype):
    return gqa_init(key, cfg.with_(qkv_bias=False), dtype)


def cross_attn_apply(p, cfg, x, memory, memory_len=None):
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (memory @ p["wk"]).reshape(B, memory.shape[1], KV, hd)
    v = (memory @ p["wv"]).reshape(B, memory.shape[1], KV, hd)
    out = blockwise_attention(q, k, v, causal=False,
                              backend=registry.active_attn_backend(cfg))
    return out.reshape(B, S, -1) @ p["wo"]


# ---------------------------------------------------------------------------
# MLA — DeepSeek-V3 multi-head latent attention
# ---------------------------------------------------------------------------

def mla_init(key, cfg, dtype):
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    ks = split_dict(key, ["wq_a", "wq_b", "wkv_a", "wkv_b", "wo",
                          "q_norm", "kv_norm"])
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": dense_init(ks["wq_a"], d, m.q_lora_rank, dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), dtype),
        "wq_b": dense_init(ks["wq_b"], m.q_lora_rank, H * qk_dim, dtype),
        "wkv_a": dense_init(ks["wkv_a"], d, m.kv_lora_rank + m.qk_rope_head_dim, dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "wkv_b": dense_init(ks["wkv_b"], m.kv_lora_rank,
                            H * (m.qk_nope_head_dim + m.v_head_dim), dtype),
        "wo": dense_init(ks["wo"], H * m.v_head_dim, d, dtype),
    }


def _rms(x, scale):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def _mla_q(p, cfg, x, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = _rms(x @ p["wq_a"], p["q_norm"]) @ p["wq_b"]
    q = q.reshape(B, S, H, qk)
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p, cfg, x, positions):
    m = cfg.mla
    kv = x @ p["wkv_a"]
    c_kv, k_rope = kv[..., :m.kv_lora_rank], kv[..., m.kv_lora_rank:]
    c_kv = _rms(c_kv, p["kv_norm"])
    k_rope = apply_rope(k_rope[..., None, :], positions, cfg.rope_theta)[..., 0, :]
    return c_kv, k_rope


def _mla_attend(p, cfg, x, positions):
    """Shared materialised full-sequence MLA body of apply/prefill.
    Returns (ctx [B,S,H*vd], c_kv, k_rope) so prefill can cache the
    compressed latents without re-deriving them."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    if positions.ndim == 1:
        positions = positions[None, :]
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c_kv, k_rope = _mla_latent(p, cfg, x, positions)
    kvb = (c_kv @ p["wkv_b"]).reshape(B, S, H, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = kvb[..., :m.qk_nope_head_dim], kvb[..., m.qk_nope_head_dim:]
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                                  q_rope.shape)], -1)
    out = blockwise_attention(q, k, v, causal=True, window=cfg.attn_window,
                              backend=registry.active_attn_backend(cfg))
    return out.reshape(B, S, -1), c_kv, k_rope


def mla_apply(p, cfg, x, positions):
    """Training/prefill MLA: materialise per-head K/V from the latent."""
    ctx, _, _ = _mla_attend(p, cfg, x, positions)
    return ctx @ p["wo"]


def mla_prefill(p, cfg, x, positions, cache, *, lengths=None):
    """Fused MLA prefill: the materialised full-sequence pass of
    ``mla_apply`` plus a fill of the compressed (c_kv, k_rope) decode cache.
    ``lengths`` ([B] int32) makes the cache ``len`` per-row for ragged
    prompts (see ``gqa_prefill``). Returns (out [B,S,d], new_cache)."""
    S = x.shape[1]
    ctx, c_kv, k_rope = _mla_attend(p, cfg, x, positions)
    T = cache["c_kv"].shape[1]
    ring = bool(cfg.attn_window) and cfg.attn_window == T
    add = jnp.int32(S) if lengths is None else lengths.astype(jnp.int32)
    new_cache = {"c_kv": _prefill_fill(cache["c_kv"], c_kv, ring),
                 "k_rope": _prefill_fill(cache["k_rope"], k_rope, ring),
                 "len": cache["len"] + add}
    return ctx @ p["wo"], new_cache


def mla_prefill_chunked(p, cfg, x, cache, lengths, hist):
    """Chunked MLA prefill: scatter each row's next chunk of compressed
    latents (c_kv rms'd, k_rope roped — exactly what ``mla_prefill``
    caches) at absolute positions ``hist[b]..lengths[b]``, then attend the
    chunk queries over the row's full logical range by re-materialising
    per-head K/V from the CACHED latents (the ``mla_apply`` math on the
    cache instead of the activations). First chunks zero the row first —
    see ``gqa_prefill_chunked`` for why (NaN from a quarantined previous
    tenant would leak through decode's masked-but-multiplied lanes).
    Returns (out [B,S,d], new_cache)."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    pos = hist[:, None] + jnp.arange(S)[None, :]                 # [B,S]
    q_nope, q_rope = _mla_q(p, cfg, x, pos)
    c_kv, k_rope = _mla_latent(p, cfg, x, pos)
    T = cache["c_kv"].shape[1]
    reset = ((hist == 0) & (lengths > 0))[:, None, None]
    c_buf = jnp.where(reset, jnp.zeros_like(cache["c_kv"]), cache["c_kv"])
    r_buf = jnp.where(reset, jnp.zeros_like(cache["k_rope"]),
                      cache["k_rope"])
    valid = jnp.arange(S)[None, :] < (lengths - hist)[:, None]   # [B,S]
    dst = jnp.where(valid, pos, T)          # invalid lanes: dropped OOB
    bidx = jnp.arange(B)[:, None]
    c_buf = c_buf.at[bidx, dst].set(c_kv.astype(c_buf.dtype), mode="drop")
    r_buf = r_buf.at[bidx, dst].set(k_rope.astype(r_buf.dtype), mode="drop")
    new_len = lengths.astype(jnp.int32)
    kvb = (c_buf @ p["wkv_b"]).reshape(B, T, H,
                                       m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = kvb[..., :m.qk_nope_head_dim], kvb[..., m.qk_nope_head_dim:]
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(r_buf[:, :, None, :],
                                  (B, T, H, m.qk_rope_head_dim))], -1)
    ctx = paged_prefill_attention_ref(q, k, v, hist.astype(jnp.int32),
                                      new_len)
    new_cache = {"c_kv": c_buf, "k_rope": r_buf, "len": new_len}
    return ctx.reshape(B, S, -1) @ p["wo"], new_cache


def mla_decode(p, cfg, x, cache, *, ragged=False, active=None):
    """Absorbed-matmul MLA decode: attention runs in the latent space, so the
    KV cache stores only (c_kv, k_rope) — the compressed cache that makes
    DeepSeek-V3 decode cheap. ``ragged=True`` scatters each row at its own
    slot (continuous batching; see ``gqa_decode``). ``active`` ([B] bool,
    ragged-only) drops the write and freezes ``len`` for rows that are
    mid-chunked-prefill (see ``gqa_decode``)."""
    m = cfg.mla
    B = x.shape[0]
    H = cfg.num_heads
    pos = cache["len"][:, None]
    q_nope, q_rope = _mla_q(p, cfg, x, pos)          # [B,1,H,*]
    c_kv, k_rope = _mla_latent(p, cfg, x, pos)       # [B,1,kvr], [B,1,rd]
    T = cache["c_kv"].shape[1]
    ring = bool(cfg.attn_window) and cfg.attn_window == T
    if ragged:
        slot = cache["len"] % T if ring else jnp.minimum(cache["len"], T - 1)
        bidx = jnp.arange(B)
        if active is not None:
            slot = jnp.where(active, slot, T)        # inactive: dropped OOB
        c_cache = cache["c_kv"].at[bidx, slot].set(c_kv[:, 0], mode="drop")
        r_cache = cache["k_rope"].at[bidx, slot].set(k_rope[:, 0],
                                                     mode="drop")
    else:
        if active is not None:
            raise ValueError("active mask requires ragged=True")
        # synchronized batched decode (see gqa_decode): time-axis DUS
        if ring:
            slot0 = cache["len"][0] % T              # ring buffer (windowed)
        else:
            slot0 = jnp.minimum(cache["len"][0], T - 1)
        c_cache = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv,
                                                      slot0, 1)
        r_cache = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope,
                                                      slot0, 1)
    new_len = cache["len"] + (jnp.int32(1) if active is None
                              else active.astype(jnp.int32))

    wkv_b = p["wkv_b"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim)
    w_uk = wkv_b[..., :m.qk_nope_head_dim]           # [kvr,H,nope]
    w_uv = wkv_b[..., m.qk_nope_head_dim:]           # [kvr,H,vd]
    # absorb W_UK into the query
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk)   # [B,1,H,kvr]
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    if registry.backend_for(cfg, "decode_attn") == "pallas":
        # flash-decode in the latent space: every head attends the SAME
        # compressed cache, i.e. GQA with one kv "head" holding
        # [c_kv | k_rope]. The kernel scales by 1/sqrt(d_cat); pre-scale q
        # so the effective scale is the MLA 1/sqrt(nope+rope).
        from repro.kernels import ops
        d_cat = m.kv_lora_rank + m.qk_rope_head_dim
        q_cat = jnp.concatenate([q_lat, q_rope], -1) * (math.sqrt(d_cat) * scale)
        k_cat = jnp.concatenate([c_cache, r_cache], -1)[:, :, None, :]
        v_lat = c_cache[:, :, None, :]               # [B,T,1,kvr]
        ctx_lat = ops.decode_attention(q_cat.astype(x.dtype), k_cat, v_lat,
                                       new_len,
                                       interpret=ops.default_interpret())
    else:
        s = (jnp.einsum("bshr,btr->bhst", q_lat, c_cache, preferred_element_type=jnp.float32)
             + jnp.einsum("bshr,btr->bhst", q_rope, r_cache, preferred_element_type=jnp.float32)
             ) * scale                               # [B,H,1,T]
        valid = jnp.arange(T)[None, None, None, :] < new_len[:, None, None, None]
        s = jnp.where(valid, s, NEG_INF)
        pattn = jax.nn.softmax(s, axis=-1)
        ctx_lat = jnp.einsum("bhst,btr->bshr", pattn, c_cache.astype(jnp.float32))
    out = jnp.einsum("bshr,rhv->bshv", ctx_lat.astype(x.dtype), w_uv)
    out = out.reshape(B, 1, -1) @ p["wo"]
    return out, {"c_kv": c_cache, "k_rope": r_cache, "len": new_len}


def mla_cache_init(cfg, batch: int, cache_len: int, dtype):
    m = cfg.mla
    T = min(cache_len, cfg.attn_window) if cfg.attn_window else cache_len
    return {"c_kv": jnp.zeros((batch, T, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, T, m.qk_rope_head_dim), dtype),
            "len": jnp.zeros((batch,), jnp.int32)}
