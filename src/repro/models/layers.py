"""Core layers: norms, rotary embeddings, MLPs — pure-jnp, shard-friendly."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split_dict


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def layernorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def norm_init(kind: str, d: int, dtype):
    return layernorm_init(d, dtype) if kind == "layernorm" else rmsnorm_init(d, dtype)


def apply_norm(kind: str, p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(xf * xf, -1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard, partial, chatglm-style 2d/paired)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim_rot: int, theta: float):
    # head_dim_rot = number of channels actually rotated (must be even)
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim_rot, 2, dtype=jnp.float32) / head_dim_rot))
    return inv  # [head_dim_rot//2]


def apply_rope(x, positions, theta: float, rotary_fraction: float = 1.0,
               interleaved: bool = False):
    """x: [..., S, n_heads, head_dim]; positions: [..., S] int32.

    ``rotary_fraction`` < 1 rotates only the first channels (StableLM /
    ChatGLM partial rotary). ``interleaved`` pairs channels (2i, 2i+1)
    (GLM 2D-RoPE layout) instead of (i, i + d/2).
    """
    hd = x.shape[-1]
    rot = int(hd * rotary_fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    inv = rope_freqs(rot, theta)
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., S, rot//2]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    if interleaved:
        # reshape-pairing instead of strided slices: a stride-2 slice on a
        # (possibly intra-head-sharded) dim hard-crashes the SPMD partitioner
        # at kv_heads << mesh; (.., rot) -> (.., rot//2, 2) is shardable
        pairs = xr.reshape(xr.shape[:-1] + (rot // 2, 2)).astype(jnp.float32)
        x1, x2 = pairs[..., 0], pairs[..., 1]
        o1 = x1 * cos - x2 * sin
        o2 = x2 * cos + x1 * sin
        out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    else:
        half = rot // 2
        x1 = xr[..., :half].astype(jnp.float32)
        x2 = xr[..., half:].astype(jnp.float32)
        o1 = x1 * cos - x2 * sin
        o2 = x2 * cos + x1 * sin
        out = jnp.concatenate([o1, o2], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, d_ff: int, act: str, dtype):
    ks = split_dict(key, ["w1", "w3", "w2"])
    p = {"w1": dense_init(ks["w1"], d, d_ff, dtype),
         "w2": dense_init(ks["w2"], d_ff, d, dtype)}
    if act == "silu":  # swiglu needs the extra gate matrix
        p["w3"] = dense_init(ks["w3"], d, d_ff, dtype)
    return p


def apply_mlp(p, x, act: str):
    h = x @ p["w1"]
    if act == "silu":
        h = jax.nn.silu(h) * (x @ p["w3"])
    else:
        h = jax.nn.gelu(h)
    return h @ p["w2"]


def mlp_param_count(d: int, d_ff: int, act: str) -> int:
    return d * d_ff * (3 if act == "silu" else 2)
