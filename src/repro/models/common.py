"""Shared functional-model utilities: initialisation, dtype policy, tree math."""
from __future__ import annotations

import math
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    """Truncated-normal fan-in init (matches common LM practice)."""
    if scale is None:
        scale = 1.0 / math.sqrt(d_in)
    w = scale * jax.random.truncated_normal(key, -3.0, 3.0, (d_in, d_out), jnp.float32)
    return w.astype(dtype)


def stacked_dense_init(key, n: int, d_in: int, d_out: int, dtype,
                       scale: float | None = None):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: dense_init(k, d_in, d_out, jnp.float32, scale))(keys).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    w = 0.02 * jax.random.truncated_normal(key, -3.0, 3.0, (vocab, d), jnp.float32)
    return w.astype(dtype)


def zeros(shape, dtype):
    return jnp.zeros(shape, dtype)


def ones(shape, dtype):
    return jnp.ones(shape, dtype)


def count_params(params: PyTree) -> int:
    return int(sum(np.prod(p.shape) for p in jax.tree.leaves(params)))


def param_bytes(params: PyTree) -> int:
    return int(sum(np.prod(p.shape) * p.dtype.itemsize for p in jax.tree.leaves(params)))


def tree_cast(tree: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def split_dict(key, names):
    ks = jax.random.split(key, len(names))
    return dict(zip(names, ks))
