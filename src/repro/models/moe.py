"""Mixture-of-Experts FFN with sort-based capacity dispatch.

TPU adaptation: tokens are dispatched into a dense ``[E, C, d]`` buffer
(capacity C per expert) via a sorted scatter-add, experts run as one grouped
einsum, and results are combined with a scatter back. With the expert dim
sharded over the ``model`` mesh axis (and optionally ``data`` for ZeRO) the
dispatch/combine scatters lower to cross-shard data movement (the all-to-all
of expert parallelism) while the expert matmuls stay local. FLOPs scale with
top_k * capacity_factor, not with num_experts — matching a real MoE system,
which matters for the roofline's useful-FLOPs ratio.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split_dict
from repro.models.layers import apply_mlp, mlp_init


def moe_capacity(tokens: int, cfg_moe) -> int:
    c = int(tokens * cfg_moe.top_k * cfg_moe.capacity_factor / cfg_moe.num_experts)
    return max(8, -(-c // 8) * 8)   # round up to 8, floor 8


def moe_init(key, cfg, dtype):
    m = cfg.moe
    d = cfg.d_model
    ks = split_dict(key, ["router", "w1", "w3", "w2", "shared"])
    E, f = m.num_experts, m.expert_d_ff

    def stack(k, din, dout):
        return jax.vmap(lambda kk: dense_init(kk, din, dout, jnp.float32))(
            jax.random.split(k, E)).astype(dtype)

    p = {"router": dense_init(ks["router"], d, E, jnp.float32),
         "w1": stack(ks["w1"], d, f),
         "w3": stack(ks["w3"], d, f),
         "w2": stack(ks["w2"], f, d)}
    if m.num_shared_experts:
        p["shared"] = mlp_init(ks["shared"], d,
                               m.shared_d_ff * m.num_shared_experts, "silu", dtype)
    return p


def moe_apply(p, cfg, x, *, drop: bool = True):
    """x: [T, d] -> (y: [T, d], aux_loss scalar).

    ``drop=True`` (training): capacity-factor dispatch, overflow tokens are
    dropped — the throughput/quality tradeoff the FLOP model assumes.
    ``drop=False`` (inference): capacity = min(T, 4x the balanced
    per-expert load). The T cap makes small shapes (every reduced/test
    config, and any E <= 4*k*cf) exactly dropless, which keeps prefill and
    one-token decode numerically consistent; at production scale the 4x
    headroom keeps the dense [E, C, d] dispatch buffer linear in T
    (true worst-case droplessness would need C = T, i.e. an E*T*d buffer
    — ~120 TB for a deepseek-v3 32k prefill).
    """
    m = cfg.moe
    T, d = x.shape
    E, k = m.num_experts, m.top_k
    if drop:
        C = moe_capacity(T, m)
    else:
        headroom = -(-4 * k * int(T * m.capacity_factor) // E)
        C = max(8, -(-min(T, headroom) // 8) * 8)

    logits = (x.astype(jnp.float32) @ p["router"])            # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                       # [T, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    fidx = idx.reshape(-1)                                    # [T*k]
    order = jnp.argsort(fidx)                                 # stable
    sorted_e = fidx[order]
    tok = order // k
    counts = jnp.bincount(fidx, length=E)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(T * k) - starts[sorted_e]
    keep = rank < C
    slot = jnp.where(keep, rank, C - 1)

    buf = jnp.zeros((E, C, d), x.dtype)
    buf = buf.at[sorted_e, slot].add(jnp.where(keep[:, None], x[tok], 0))

    h = jnp.einsum("ecd,edf->ecf", buf, p["w1"])
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, p["w3"])
    y_e = jnp.einsum("ecf,efd->ecd", h, p["w2"])              # [E, C, d]

    y_sorted = y_e[sorted_e, slot] * keep[:, None]
    w_sorted = gate.reshape(-1)[order].astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype).at[tok].add(y_sorted * w_sorted[:, None])

    # Switch-style load-balance auxiliary loss
    me = probs.mean(0)                                        # mean router prob
    one_hot = jnp.zeros((E,), jnp.float32).at[fidx].add(1.0) / (T * k)
    aux = E * jnp.sum(me * one_hot) * m.router_aux_coef

    if m.num_shared_experts:
        y = y + apply_mlp(p["shared"], x, "silu")
    return y, aux


def moe_param_count(cfg) -> int:
    m = cfg.moe
    d = cfg.d_model
    n = d * m.num_experts                                     # router
    n += m.num_experts * d * m.expert_d_ff * 3
    if m.num_shared_experts:
        n += d * m.shared_d_ff * m.num_shared_experts * 3
    return n


def moe_active_param_count(cfg) -> int:
    m = cfg.moe
    d = cfg.d_model
    n = d * m.num_experts
    n += m.top_k * d * m.expert_d_ff * 3
    if m.num_shared_experts:
        n += d * m.shared_d_ff * m.num_shared_experts * 3
    return n
