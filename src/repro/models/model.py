"""Top-level models: init, train loss, prefill, one-token decode, per family.

Public API (all pure functions of (cfg, params, ...)):
    init_params(cfg, key)               -> params pytree
    loss_fn(cfg, params, batch)         -> (loss, metrics)
    prefill_logits(cfg, params, batch)  -> last-position logits (+ cache-free)
    prefill_with_cache(cfg, params, batch, cache)
                                        -> (last-position logits, filled
                                           decode cache) — ONE fused
                                           full-sequence pass, no per-token
                                           teacher forcing
    encode(cfg, params, frames)         -> encoder memory (encdec archs)
    init_cache(cfg, batch, cache_len)   -> decode cache pytree
    decode_step(cfg, params, batch, cache) -> (logits [B,V], new cache)
    param_stage_ids(cfg, params, n_stages) -> pytree of int32 stage ids
                                           (broadcastable to each leaf; used
                                           by the CDP update rules)

Full-sequence attention dispatches on the kernel-backend registry: the
train path uses the ``train_attn`` op, ``prefill_logits`` /
``prefill_with_cache`` enter ``registry.prefill_scope()`` so the same
layer code resolves ``prefill_attn``; decode and the SSM scan read their
own ops directly.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (FAMILY_DENSE, FAMILY_ENCDEC, FAMILY_HYBRID,
                                FAMILY_MOE, FAMILY_SSM, FAMILY_VLM,
                                ModelConfig)
from repro.kernels import registry
from repro.models import blocks as B
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (count_params, dense_init, dtype_of,
                                 embed_init, split_dict)
from repro.models.layers import apply_norm, mlp_param_count, norm_init

PyTree = Any


# ---------------------------------------------------------------------------
# Layout helpers
# ---------------------------------------------------------------------------

def _moe_split(cfg) -> tuple[int, int]:
    """(n_dense_layers, n_moe_layers) for the decoder stack."""
    if cfg.family == FAMILY_MOE and cfg.moe is not None:
        k = cfg.moe.first_k_dense
        return k, cfg.num_layers - k
    return cfg.num_layers, 0


def _xlstm_layout(cfg) -> tuple[int, int]:
    """(n_periods, period) — each period = (period-1) mLSTM + 1 sLSTM."""
    every = cfg.ssm.slstm_every
    if not every:
        return 0, 0
    assert cfg.num_layers % every == 0, "num_layers must divide slstm_every"
    return cfg.num_layers // every, every


def _hybrid_layout(cfg) -> tuple[int, int, int]:
    """(n_periods, period, tail) — shared attn block after each period."""
    every = cfg.hybrid.shared_attn_every
    n_periods = cfg.num_layers // every
    tail = cfg.num_layers - n_periods * every
    return n_periods, every, tail


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> PyTree:
    dt = dtype_of(cfg)
    ks = split_dict(key, ["embed", "blocks", "blocks2", "head", "extra",
                          "enc", "mtp"])
    V = padded_vocab(cfg)
    p: Dict[str, Any] = {"embed": embed_init(ks["embed"], V, cfg.d_model, dt),
                         "final_norm": norm_init(cfg.norm, cfg.d_model, dt)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks["head"], cfg.d_model, V, dt, scale=0.02)

    fam = cfg.family
    if fam in (FAMILY_DENSE, FAMILY_MOE, FAMILY_VLM):
        n_dense, n_moe = _moe_split(cfg)
        blk = {}
        if n_dense:
            blk["dense"] = B._stack_init(
                lambda k: B.decoder_layer_init(k, cfg, dt, use_moe=False),
                ks["blocks"], n_dense)
        if n_moe:
            blk["moe"] = B._stack_init(
                lambda k: B.decoder_layer_init(k, cfg, dt, use_moe=True),
                ks["blocks2"], n_moe)
        p["blocks"] = blk
        if fam == FAMILY_VLM:
            v = cfg.vlm
            ke = split_dict(ks["extra"], ["p1", "p2"])
            p["projector"] = {
                "ln": norm_init("layernorm", v.vision_dim, dt),
                "w1": dense_init(ke["p1"], v.vision_dim, v.projector_hidden, dt),
                "w2": dense_init(ke["p2"], v.projector_hidden, cfg.d_model, dt)}
        if cfg.mtp:
            km = split_dict(ks["mtp"], ["l", "proj"])
            p["mtp"] = {"layer": B.decoder_layer_init(km["l"], cfg, dt, use_moe=False),
                        "norm": norm_init(cfg.norm, cfg.d_model, dt)}
    elif fam == FAMILY_ENCDEC:
        e = cfg.encdec
        ke = split_dict(ks["enc"], ["front", "layers"])
        p["frontend_proj"] = dense_init(ke["front"], e.frontend_dim,
                                        cfg.d_model, dt)
        p["encoder"] = {
            "blocks": B._stack_init(lambda k: B.encoder_layer_init(k, cfg, dt),
                                    ke["layers"], e.encoder_layers),
            "final_norm": norm_init(cfg.norm, cfg.d_model, dt)}
        p["blocks"] = {"xdec": B._stack_init(
            lambda k: B.xdec_layer_init(k, cfg, dt), ks["blocks"],
            cfg.num_layers)}
    elif fam == FAMILY_SSM:
        n_periods, period = _xlstm_layout(cfg)
        blk = {}
        if n_periods:
            def init_period(k):
                k1, k2 = jax.random.split(k)
                return {"mlstm": B._stack_init(
                            lambda kk: B.mlstm_layer_init(kk, cfg, dt),
                            k1, period - 1),
                        "slstm": B.slstm_layer_init(k2, cfg, dt)}
            blk["periods"] = B._stack_init(init_period, ks["blocks"], n_periods)
        else:
            blk["mlstm"] = B._stack_init(
                lambda k: B.mlstm_layer_init(k, cfg, dt), ks["blocks"],
                cfg.num_layers)
        p["blocks"] = blk
    elif fam == FAMILY_HYBRID:
        n_periods, period, tail = _hybrid_layout(cfg)
        blk = {"mamba_main": B._stack_init(
                   lambda k: jax.vmap(lambda kk: B.mamba_layer_init(kk, cfg, dt))(
                       jax.random.split(k, period)),
                   ks["blocks"], n_periods),
               "shared": B.shared_attn_block_init(ks["extra"], cfg, dt)}
        if tail:
            blk["mamba_tail"] = B._stack_init(
                lambda k: B.mamba_layer_init(k, cfg, dt), ks["blocks2"], tail)
        p["blocks"] = blk
    else:
        raise ValueError(f"unknown family {fam}")
    return p


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------

def padded_vocab(cfg) -> int:
    """Vocab padded to a multiple of 256 so the vocab dim shards over any
    reasonable tensor-parallel axis (an unshardable vocab replicates the
    embedding AND the [tokens, V] logits — tens of GiB at 32k prefill)."""
    return -(-cfg.vocab_size // 256) * 256


def _embed(cfg, params, tokens):
    return params["embed"][tokens]


def _head(cfg, params, x):
    h = apply_norm(cfg.norm, params["final_norm"], x)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = h @ w
    V = padded_vocab(cfg)
    if V != cfg.vocab_size:     # mask the padded columns
        pad = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1) >= cfg.vocab_size
        logits = jnp.where(pad, -1e30, logits)
    return logits


def _run_decoder_stack(cfg, params, x, positions, drop_tokens: bool = True):
    """dense/moe/vlm decoder trunk. Returns (hidden, aux_loss).
    ``drop_tokens=False`` -> dropless MoE routing (inference)."""
    aux = jnp.float32(0.0)
    blk = params["blocks"]
    if "dense" in blk:
        fn = lambda lp, h: B.decoder_layer_apply(lp, cfg, h, positions,
                                                 use_moe=False)
        x, a = B.scan_layers(fn, blk["dense"], x)
        aux += a
    if "moe" in blk:
        fn = lambda lp, h: B.decoder_layer_apply(lp, cfg, h, positions,
                                                 use_moe=True,
                                                 drop_tokens=drop_tokens)
        x, a = B.scan_layers(fn, blk["moe"], x)
        aux += a
    return x, aux


def _run_ssm_stack(cfg, params, x):
    aux = jnp.float32(0.0)
    blk = params["blocks"]
    if "periods" in blk:
        def period_fn(pp, h):
            fn = lambda lp, hh: B.mlstm_layer_apply(lp, cfg, hh)
            h, a = B.scan_layers(fn, pp["mlstm"], h)
            h2, _ = B.slstm_layer_apply(pp["slstm"], cfg, h)
            return h2, a
        x, aux = B.scan_layers(period_fn, blk["periods"], x)
    else:
        fn = lambda lp, h: B.mlstm_layer_apply(lp, cfg, h)
        x, aux = B.scan_layers(fn, blk["mlstm"], x)
    return x, aux


def _run_hybrid_stack(cfg, params, x, positions):
    blk = params["blocks"]
    shared = blk["shared"]

    def period_fn(pp, h):
        fn = lambda lp, hh: B.mamba_layer_apply(lp, cfg, hh)
        h, a = B.scan_layers(fn, pp, h)
        h = B.shared_attn_block_apply(shared, cfg, h, positions)
        return h, a

    x, aux = B.scan_layers(period_fn, blk["mamba_main"], x)
    if "mamba_tail" in blk:
        fn = lambda lp, h: B.mamba_layer_apply(lp, cfg, h)
        x, a = B.scan_layers(fn, blk["mamba_tail"], x)
        aux += a
    return x, aux


def _run_encoder(cfg, params, frames):
    x = frames @ params["frontend_proj"]
    pos = jnp.arange(x.shape[1])
    fn = lambda lp, h: B.encoder_layer_apply(lp, cfg, h, pos)
    x, _ = B.scan_layers(fn, params["encoder"]["blocks"], x)
    return apply_norm(cfg.norm, params["encoder"]["final_norm"], x)


def encode(cfg: ModelConfig, params: PyTree, frames) -> jnp.ndarray:
    """Public encoder forward for enc-dec archs: precomputed frame
    embeddings [B, T_frames, frontend_dim] -> memory [B, T_frames, d_model].
    Serving code uses this (under the prefill attention op) instead of
    reaching into the private ``_run_encoder``."""
    if cfg.family != FAMILY_ENCDEC:
        raise ValueError(f"encode() is for encdec archs, not {cfg.family!r}")
    with registry.prefill_scope():
        return _run_encoder(cfg, params, frames)


def forward(cfg: ModelConfig, params: PyTree, batch: Dict[str, Any]):
    """Full-sequence forward. Returns (logits [B,S,V], aux_loss, hidden)."""
    fam = cfg.family
    tokens = batch["tokens"]
    Bsz, S = tokens.shape
    x = _embed(cfg, params, tokens)
    positions = jnp.arange(S)

    if fam == FAMILY_VLM:
        v = cfg.vlm
        patches = batch["patches"]
        pr = params["projector"]
        pe = apply_norm("layernorm", pr["ln"], patches)
        pe = jax.nn.gelu(pe @ pr["w1"]) @ pr["w2"]
        x = jnp.concatenate([pe.astype(x.dtype), x], axis=1)
        positions = jnp.arange(x.shape[1])
        h, aux = _run_decoder_stack(cfg, params, x, positions)
        h = h[:, patches.shape[1]:]                 # text positions only
    elif fam in (FAMILY_DENSE, FAMILY_MOE):
        h, aux = _run_decoder_stack(cfg, params, x, positions)
    elif fam == FAMILY_ENCDEC:
        memory = _run_encoder(cfg, params, batch["frames"])
        fn = lambda lp, hh: B.xdec_layer_apply(lp, cfg, hh, positions, memory)
        h, aux = B.scan_layers(fn, params["blocks"]["xdec"], x)
    elif fam == FAMILY_SSM:
        h, aux = _run_ssm_stack(cfg, params, x)
    elif fam == FAMILY_HYBRID:
        h, aux = _run_hybrid_stack(cfg, params, x, positions)
    else:
        raise ValueError(fam)

    logits = _head(cfg, params, h)
    return logits, aux, h


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def _xent(logits, targets, mask=None):
    # one-hot contraction instead of take_along_axis: gathers along a
    # tensor-parallel (vocab-sharded) dim force GSPMD to replicate the
    # logits; the masked-sum partitions cleanly shard-local + all-reduce.
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    V = logits.shape[-1]
    onehot = (targets[..., None] == jax.lax.broadcasted_iota(
        jnp.int32, targets.shape + (V,), targets.ndim))
    ll = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def loss_fn(cfg: ModelConfig, params: PyTree, batch: Dict[str, Any]):
    logits, aux, h = forward(cfg, params, batch)
    loss = _xent(logits, batch["targets"])
    metrics = {"xent": loss, "aux": aux}
    if cfg.mtp and "mtp" in params:
        # DeepSeek-style multi-token prediction: one extra layer over the
        # trunk hidden state predicts token t+2.
        pos = jnp.arange(h.shape[1])
        h2 = apply_norm(cfg.norm, params["mtp"]["norm"], h)
        h2, _ = B.decoder_layer_apply(params["mtp"]["layer"], cfg, h2, pos,
                                      use_moe=False)
        logits2 = _head(cfg, params, h2)
        t2 = jnp.concatenate([batch["targets"][:, 1:],
                              batch["targets"][:, -1:]], axis=1)
        mtp_loss = _xent(logits2, t2)
        metrics["mtp"] = mtp_loss
        loss = loss + 0.3 * mtp_loss
    loss = loss + aux
    metrics["loss"] = loss
    return loss, metrics


def prefill_logits(cfg, params, batch):
    """Last-position logits only: the [B,S,V] logits tensor of a 32k prefill
    is tens of GiB, so the head matmul runs on the final hidden state."""
    fam = cfg.family
    with registry.prefill_scope():
        tokens = batch["tokens"]
        x = _embed(cfg, params, tokens)
        positions = jnp.arange(tokens.shape[1])
        if fam == FAMILY_VLM:
            v = cfg.vlm
            pr = params["projector"]
            pe = apply_norm("layernorm", pr["ln"], batch["patches"])
            pe = jax.nn.gelu(pe @ pr["w1"]) @ pr["w2"]
            x = jnp.concatenate([pe.astype(x.dtype), x], axis=1)
            positions = jnp.arange(x.shape[1])
            h, _ = _run_decoder_stack(cfg, params, x, positions,
                                      drop_tokens=False)
        elif fam in (FAMILY_DENSE, FAMILY_MOE):
            h, _ = _run_decoder_stack(cfg, params, x, positions,
                                      drop_tokens=False)
        elif fam == FAMILY_ENCDEC:
            memory = _run_encoder(cfg, params, batch["frames"])
            fn = lambda lp, hh: B.xdec_layer_apply(lp, cfg, hh, positions,
                                                   memory)
            h, _ = B.scan_layers(fn, params["blocks"]["xdec"], x)
        elif fam == FAMILY_SSM:
            h, _ = _run_ssm_stack(cfg, params, x)
        elif fam == FAMILY_HYBRID:
            h, _ = _run_hybrid_stack(cfg, params, x, positions)
        else:
            raise ValueError(fam)
        return _head(cfg, params, h[:, -1:])[:, 0]


# ---------------------------------------------------------------------------
# Decode (one token, cached)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, cache_len: int) -> PyTree:
    dt = dtype_of(cfg)
    fam = cfg.family
    if fam in (FAMILY_DENSE, FAMILY_MOE, FAMILY_VLM):
        n_dense, n_moe = _moe_split(cfg)
        cache: Dict[str, Any] = {}
        one = lambda: B.decoder_layer_cache_init(cfg, batch, cache_len, dt)
        if n_dense:
            cache["dense"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (n_dense,) + x.shape).copy(), one())
        if n_moe:
            cache["moe"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (n_moe,) + x.shape).copy(), one())
        if cfg.mtp:
            cache["mtp"] = one()
        return cache
    if fam == FAMILY_ENCDEC:
        e = cfg.encdec
        n_frames = cache_len // e.frame_rate_divisor
        dec_len = min(cache_len, 2048)
        one = B.decoder_layer_cache_init(cfg.with_(attn_window=0), batch,
                                         dec_len, dt)
        return {"self": jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape).copy(), one),
                "memory": jnp.zeros((batch, n_frames, cfg.d_model), dt)}
    if fam == FAMILY_SSM:
        n_periods, period = _xlstm_layout(cfg)
        if n_periods:
            m = ssm_mod.mlstm_cache_init(cfg, batch)
            s = B.slstm_layer_apply  # unused; placeholder
            return {"periods": {
                "mlstm": jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (n_periods, period - 1) + x.shape).copy(), m),
                "slstm": jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (n_periods,) + x.shape).copy(),
                    ssm_mod.slstm_cache_init(cfg, batch))}}
        m = ssm_mod.mlstm_cache_init(cfg, batch)
        return {"mlstm": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape).copy(), m)}
    if fam == FAMILY_HYBRID:
        n_periods, period, tail = _hybrid_layout(cfg)
        mc = ssm_mod.mamba2_cache_init(cfg, batch, dt)
        att_len = min(cache_len, cfg.attn_window) if cfg.attn_window else cache_len
        ac = {"k": jnp.zeros((batch, att_len, cfg.num_kv_heads,
                              cfg.resolved_head_dim), dt),
              "v": jnp.zeros((batch, att_len, cfg.num_kv_heads,
                              cfg.resolved_head_dim), dt),
              "len": jnp.zeros((batch,), jnp.int32)}
        cache = {"mamba_main": jax.tree.map(
                     lambda x: jnp.broadcast_to(x, (n_periods, period) + x.shape).copy(), mc),
                 "shared": jax.tree.map(
                     lambda x: jnp.broadcast_to(x, (n_periods,) + x.shape).copy(), ac)}
        if tail:
            cache["mamba_tail"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (tail,) + x.shape).copy(), mc)
        return cache
    raise ValueError(fam)


def paged_unsupported_reason(cfg: ModelConfig) -> Optional[str]:
    """None if the paged KV cache supports this config, else why not (the
    engine falls back to — or fails fast toward — the dense merge_caches
    path with this reason)."""
    if cfg.family not in (FAMILY_DENSE, FAMILY_MOE):
        return (f"family {cfg.family!r} (paged cache supports dense/moe "
                f"decoder stacks)")
    if cfg.attn_kind == "mla":
        return "MLA latent caches (paged cache supports GQA attention only)"
    if cfg.attn_window:
        return (f"attn_window={cfg.attn_window} (paged cache is linear; "
                f"ring-buffer windows stay dense)")
    return None


def init_paged_cache(cfg: ModelConfig, batch: int, num_blocks: int,
                     block_size: int, cache_len: int) -> PyTree:
    """Paged decode cache: per layer-group block pools (k/v
    [L, num_blocks+1, bs, KV, hd] — one physical block id spans all layers;
    the extra last block is the write-off "trash" block) plus a top-level
    ``table`` [B, nb_max] int32 owned by the engine's allocator. Unallocated
    table entries point at the trash block. ``cache_len`` (a multiple of
    ``block_size``) bounds the logical range: nb_max = cache_len // bs."""
    reason = paged_unsupported_reason(cfg)
    if reason is not None:
        raise NotImplementedError(f"paged KV cache: unsupported — {reason}")
    if cache_len % block_size:
        raise ValueError(f"cache_len {cache_len} must be a multiple of "
                         f"kv block size {block_size}")
    dt = dtype_of(cfg)
    nb_max = cache_len // block_size
    n_dense, n_moe = _moe_split(cfg)
    one = lambda: B.decoder_layer_paged_cache_init(cfg, batch, num_blocks,
                                                   block_size, dt)
    cache: Dict[str, Any] = {
        "table": jnp.full((batch, nb_max), num_blocks, jnp.int32)}
    if n_dense:
        cache["dense"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_dense,) + x.shape).copy(), one())
    if n_moe:
        cache["moe"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_moe,) + x.shape).copy(), one())
    if cfg.mtp:
        cache["mtp"] = B.decoder_layer_cache_init(cfg, batch, cache_len, dt)
    return cache


def decode_step(cfg: ModelConfig, params: PyTree, batch: Dict[str, Any],
                cache: PyTree, *, ragged: bool = False):
    """batch: {"token": [B] int32}. Returns (logits [B,V], new_cache).

    ``ragged=True`` (static, serving-only) decodes with genuinely per-row
    cache lengths: attention caches scatter each row's k/v at its own slot
    instead of one synchronized dynamic_update_slice, so a continuous-
    batching engine can run rows at different positions in ONE jitted step.
    SSM/recurrent state layers are per-row already and ignore the flag.

    ``batch["active"]`` ([B] bool, ragged attention families only): rows
    marked inactive (slots mid-chunked-prefill) drop their cache write and
    keep their per-row ``len`` — absent (or all-True) is value-identical
    to the historical step."""
    fam = cfg.family
    x = _embed(cfg, params, batch["token"][:, None])     # [B,1,d]
    blk = params["blocks"]
    new_cache: Dict[str, Any] = {}
    # paged cache pytrees carry the engine-owned block table at the top level
    # (a host-side trace-time check — no new static argument)
    table = cache.get("table") if isinstance(cache, dict) else None
    active = batch.get("active")
    if active is not None and not (ragged or table is not None):
        raise NotImplementedError(
            "batch['active'] requires ragged decode (chunked prefill is a "
            "continuous-batching feature)")

    if fam in (FAMILY_DENSE, FAMILY_MOE, FAMILY_VLM):
        if "dense" in blk:
            fn = lambda lp, h, c: B.decoder_layer_decode(lp, cfg, h, c,
                                                         use_moe=False,
                                                         ragged=ragged,
                                                         paged_table=table,
                                                         active=active)
            x, nc = _decode_scan(fn, blk["dense"], cache["dense"], x)
            new_cache["dense"] = nc
        if "moe" in blk:
            fn = lambda lp, h, c: B.decoder_layer_decode(lp, cfg, h, c,
                                                         use_moe=True,
                                                         ragged=ragged,
                                                         paged_table=table,
                                                         active=active)
            x, nc = _decode_scan(fn, blk["moe"], cache["moe"], x)
            new_cache["moe"] = nc
        if cfg.mtp:
            new_cache["mtp"] = cache["mtp"]
        if table is not None:
            new_cache["table"] = table
    elif fam == FAMILY_ENCDEC:
        memory = cache["memory"]
        fn = lambda lp, h, c: B.xdec_layer_decode(lp, cfg, h, c, memory,
                                                  ragged=ragged)
        x, nc = _decode_scan(fn, blk["xdec"], cache["self"], x)
        new_cache = {"self": nc, "memory": memory}
    elif fam == FAMILY_SSM:
        if "periods" in blk:
            def period_fn(h, inp):
                pp, pc = inp
                fn = lambda lp, hh, c: B.mlstm_layer_decode(lp, cfg, hh, c)
                h, mlc = _decode_scan(fn, pp["mlstm"], pc["mlstm"], h)
                h, slc = B.slstm_layer_apply(pp["slstm"], cfg, h, pc["slstm"])
                return h, {"mlstm": mlc, "slstm": slc}
            x, nc = jax.lax.scan(period_fn, x,
                                 (blk["periods"], cache["periods"]))
            new_cache = {"periods": nc}
        else:
            fn = lambda lp, h, c: B.mlstm_layer_decode(lp, cfg, h, c)
            x, nc = _decode_scan(fn, blk["mlstm"], cache["mlstm"], x)
            new_cache = {"mlstm": nc}
    elif fam == FAMILY_HYBRID:
        shared = blk["shared"]

        def period_fn(h, inp):
            pp, pc_m, pc_a = inp
            fn = lambda lp, hh, c: B.mamba_layer_decode(lp, cfg, hh, c)
            h, mc = _decode_scan(fn, pp, pc_m, h)
            h, ac = B.shared_attn_block_decode(shared, cfg, h, pc_a,
                                               ragged=ragged)
            return h, (mc, ac)

        x, (mc, ac) = jax.lax.scan(
            period_fn, x, (blk["mamba_main"], cache["mamba_main"],
                           cache["shared"]))
        new_cache = {"mamba_main": mc, "shared": ac}
        if "mamba_tail" in blk:
            fn = lambda lp, h, c: B.mamba_layer_decode(lp, cfg, h, c)
            x, tc = _decode_scan(fn, blk["mamba_tail"], cache["mamba_tail"], x)
            new_cache["mamba_tail"] = tc
    else:
        raise ValueError(fam)

    logits = _head(cfg, params, x)[:, 0]
    return logits, new_cache


def _decode_scan(layer_fn, stacked, caches, x):
    def body(h, inp):
        lp, c = inp
        h, nc = layer_fn(lp, h, c)
        return h, nc
    return jax.lax.scan(body, x, (stacked, caches))


# ---------------------------------------------------------------------------
# Fused prefill: one full-sequence pass that fills the decode cache
# ---------------------------------------------------------------------------

def prefill_with_cache(cfg: ModelConfig, params: PyTree,
                       batch: Dict[str, Any], cache: PyTree):
    """Fused prefill from a FRESH ``init_cache`` pytree: one blockwise/flash
    full-sequence forward per layer that also writes every layer's decode
    state (KV / latent / recurrent), replacing the per-token teacher-forcing
    loop. Returns (last-position logits [B,V], filled cache).

    The attention contraction resolves the ``prefill_attn`` registry op; the
    enc-dec memory is the EXACT encoder output (no zeros-padded splice — the
    returned cache's memory shape follows the encoder, and decode re-traces
    on it).

    Ragged prompts (continuous batching): ``batch["lengths"]`` ([B] int32)
    declares per-row prompt lengths for prompts packed LEFT-ALIGNED into the
    fixed [B,S] buffer. The cache ``len`` becomes per-row, the returned
    logits are taken at each row's last VALID position, and pad-tail cache
    slots are dead (decode masks by per-row len and overwrites them).
    Causality keeps every valid position pad-free; only attention-cache
    families support it (SSM/recurrent state would absorb the pad tail)."""
    fam = cfg.family
    lengths = batch.get("lengths")
    if lengths is not None and fam not in (FAMILY_DENSE, FAMILY_MOE,
                                           FAMILY_VLM):
        raise NotImplementedError(
            f"ragged prefill (batch['lengths']) is only supported for "
            f"attention-cache families (dense/moe/vlm), not {fam!r}: a "
            f"recurrent prefill state would absorb the pad tail")
    with registry.prefill_scope():
        tokens = batch["tokens"]
        x = _embed(cfg, params, tokens)
        positions = jnp.arange(tokens.shape[1])
        blk = params["blocks"]
        new_cache: Dict[str, Any] = {}
        eff_lengths = lengths
        tail_lengths = None     # paged: x holds only the ragged tail

        if fam == FAMILY_VLM:
            pr = params["projector"]
            pe = apply_norm("layernorm", pr["ln"], batch["patches"])
            pe = jax.nn.gelu(pe @ pr["w1"]) @ pr["w2"]
            x = jnp.concatenate([pe.astype(x.dtype), x], axis=1)
            positions = jnp.arange(x.shape[1])
            if lengths is not None:          # patch prefix is always valid
                eff_lengths = lengths + pe.shape[1]

        if fam in (FAMILY_DENSE, FAMILY_MOE, FAMILY_VLM):
            # paged cache: ragged-tail prefill through the block table.
            # batch["hist"] [B] (default zeros) = tokens already in the
            # cache (a prefix-cache hit); only positions hist..lengths are
            # computed and written.
            table = cache.get("table") if isinstance(cache, dict) else None
            paged = None
            chunk_hist = None
            if table is not None:
                if lengths is None:
                    raise NotImplementedError(
                        "paged prefill requires batch['lengths'] (the paged "
                        "cache is always ragged)")
                hist = batch.get("hist")
                if hist is None:
                    hist = jnp.zeros_like(eff_lengths)
                paged = (table, hist.astype(jnp.int32))
                # row b's hidden states cover absolute positions
                # hist[b]..lengths[b]; its last valid logit sits at tail
                # index (lengths - hist) - 1 (the allocator caps hist at
                # lengths - 1, so admitted rows always have a tail)
                tail_lengths = eff_lengths - hist
            elif batch.get("hist") is not None:
                # dense-cache chunked prefill: x holds only each row's next
                # prompt chunk (absolute positions hist..lengths), scattered
                # into the dense [B,T] cache at its absolute slots
                if lengths is None:
                    raise NotImplementedError(
                        "chunked prefill requires batch['lengths'] (chunks "
                        "are always ragged)")
                if fam == FAMILY_VLM:
                    raise NotImplementedError(
                        "chunked prefill does not support VLM prompts (the "
                        "patch prefix is prefilled in one piece)")
                chunk_hist = batch["hist"].astype(jnp.int32)
                tail_lengths = eff_lengths - chunk_hist
            if "dense" in blk:
                fn = lambda lp, h, c: B.decoder_layer_prefill(
                    lp, cfg, h, positions, c, use_moe=False,
                    lengths=eff_lengths, paged=paged, chunk_hist=chunk_hist)
                x, nc = _decode_scan(fn, blk["dense"], cache["dense"], x)
                new_cache["dense"] = nc
            if "moe" in blk:
                fn = lambda lp, h, c: B.decoder_layer_prefill(
                    lp, cfg, h, positions, c, use_moe=True,
                    lengths=eff_lengths, paged=paged, chunk_hist=chunk_hist)
                x, nc = _decode_scan(fn, blk["moe"], cache["moe"], x)
                new_cache["moe"] = nc
            if cfg.mtp:
                new_cache["mtp"] = cache["mtp"]
            if table is not None:
                new_cache["table"] = table
        elif fam == FAMILY_ENCDEC:
            memory = _run_encoder(cfg, params, batch["frames"])
            fn = lambda lp, h, c: B.xdec_layer_prefill(lp, cfg, h, positions,
                                                       c, memory)
            x, nc = _decode_scan(fn, blk["xdec"], cache["self"], x)
            new_cache = {"self": nc, "memory": memory}
        elif fam == FAMILY_SSM:
            if "periods" in blk:
                def period_fn(h, inp):
                    pp, pc = inp
                    fn = lambda lp, hh, c: B.mlstm_layer_prefill(lp, cfg, hh, c)
                    h, mlc = _decode_scan(fn, pp["mlstm"], pc["mlstm"], h)
                    h, slc = B.slstm_layer_apply(pp["slstm"], cfg, h,
                                                 pc["slstm"])
                    return h, {"mlstm": mlc, "slstm": slc}
                x, nc = jax.lax.scan(period_fn, x,
                                     (blk["periods"], cache["periods"]))
                new_cache = {"periods": nc}
            else:
                fn = lambda lp, h, c: B.mlstm_layer_prefill(lp, cfg, h, c)
                x, nc = _decode_scan(fn, blk["mlstm"], cache["mlstm"], x)
                new_cache = {"mlstm": nc}
        elif fam == FAMILY_HYBRID:
            shared = blk["shared"]

            def period_fn(h, inp):
                pp, pc_m, pc_a = inp
                fn = lambda lp, hh, c: B.mamba_layer_prefill(lp, cfg, hh, c)
                h, mc = _decode_scan(fn, pp, pc_m, h)
                h, ac = B.shared_attn_block_prefill(shared, cfg, h,
                                                    positions, pc_a)
                return h, (mc, ac)

            x, (mc, ac) = jax.lax.scan(
                period_fn, x, (blk["mamba_main"], cache["mamba_main"],
                               cache["shared"]))
            new_cache = {"mamba_main": mc, "shared": ac}
            if "mamba_tail" in blk:
                fn = lambda lp, h, c: B.mamba_layer_prefill(lp, cfg, h, c)
                x, tc = _decode_scan(fn, blk["mamba_tail"],
                                     cache["mamba_tail"], x)
                new_cache["mamba_tail"] = tc
        else:
            raise ValueError(fam)

        if eff_lengths is not None:
            # per-row last VALID position (ragged prompts, left-aligned)
            gl = eff_lengths if tail_lengths is None else tail_lengths
            idx = jnp.clip(gl - 1, 0, x.shape[1] - 1)
            x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
            return _head(cfg, params, x_last)[:, 0], new_cache
        return _head(cfg, params, x[:, -1:])[:, 0], new_cache


# ---------------------------------------------------------------------------
# Stage ids for CDP update rules
# ---------------------------------------------------------------------------

def _stage_of(layer_idx, total_layers: int, n_stages: int):
    return (layer_idx * n_stages) // max(1, total_layers)


def param_stage_ids(cfg: ModelConfig, params: PyTree, n_stages: int) -> PyTree:
    """For every leaf, an int32 array broadcastable to the leaf giving the
    CDP stage of the parameters it holds. Stacked layer axes map layer ->
    stage with an even split; embedding -> stage 0; head/final -> N-1."""
    fam = cfg.family
    enc_layers = cfg.encdec.encoder_layers if cfg.encdec else 0
    total = cfg.num_layers + enc_layers

    def ids_for(path_names, leaf):
        def stacked_ids(offset, n, extra_stack=0):
            lids = _stage_of(np.arange(n) + offset, total, n_stages)
            arr = jnp.asarray(lids, jnp.int32)
            shape = (n,) + (1,) * (leaf.ndim - 1)
            if extra_stack:
                # leaf [P, per, ...] double-stacked
                per = leaf.shape[1]
                lids = _stage_of(
                    (np.arange(n)[:, None] * per + np.arange(per)[None, :]) + offset,
                    total, n_stages)
                return jnp.asarray(lids, jnp.int32).reshape(
                    (n, per) + (1,) * (leaf.ndim - 2))
            return arr.reshape(shape)

        top = path_names[0]
        if top in ("embed", "frontend_proj", "projector"):
            return jnp.int32(0)
        if top in ("lm_head", "final_norm", "mtp"):
            return jnp.int32(n_stages - 1)
        if top == "encoder":
            if "blocks" in path_names:
                return stacked_ids(0, enc_layers)
            return jnp.int32(_stage_of(enc_layers - 1, total, n_stages))
        if top == "blocks":
            sub = path_names[1]
            if sub == "dense":
                return stacked_ids(enc_layers, leaf.shape[0])
            if sub == "moe":
                n_dense, n_moe = _moe_split(cfg)
                return stacked_ids(enc_layers + n_dense, leaf.shape[0])
            if sub == "xdec":
                return stacked_ids(enc_layers, cfg.num_layers)
            if sub == "mlstm":
                return stacked_ids(0, leaf.shape[0])
            if sub == "periods":
                n_periods, period = _xlstm_layout(cfg)
                if "slstm" in path_names:
                    lids = _stage_of(np.arange(n_periods) * period + period - 1,
                                     total, n_stages)
                    return jnp.asarray(lids, jnp.int32).reshape(
                        (n_periods,) + (1,) * (leaf.ndim - 1))
                # mlstm: [P, per-1, ...]
                per = period - 1
                lids = _stage_of(np.arange(n_periods)[:, None] * period
                                 + np.arange(per)[None, :], total, n_stages)
                return jnp.asarray(lids, jnp.int32).reshape(
                    (n_periods, per) + (1,) * (leaf.ndim - 2))
            if sub == "mamba_main":
                n_periods, period, tail = _hybrid_layout(cfg)
                return stacked_ids(0, n_periods, extra_stack=1)
            if sub == "mamba_tail":
                n_periods, period, tail = _hybrid_layout(cfg)
                return stacked_ids(n_periods * period, leaf.shape[0])
            if sub == "shared":
                return jnp.int32(n_stages - 1)
        return jnp.int32(n_stages - 1)

    def walk(path, leaf):
        names = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        names = [n for n in names if isinstance(n, str)]
        return ids_for(names, leaf)

    return jax.tree_util.tree_map_with_path(walk, params)


# ---------------------------------------------------------------------------
# Analytic parameter counts
# ---------------------------------------------------------------------------

def analytic_param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    V = padded_vocab(cfg)
    n = V * d                                                  # embed
    if not cfg.tie_embeddings:
        n += d * V                                             # head
    norm_p = 2 * d if cfg.norm == "layernorm" else d

    def attn_p():
        if cfg.attn_kind == "mla":
            m = cfg.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            a = d * m.q_lora_rank + m.q_lora_rank + m.q_lora_rank * H * qk
            a += d * (m.kv_lora_rank + m.qk_rope_head_dim) + m.kv_lora_rank
            a += m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
            a += H * m.v_head_dim * d
            return a
        a = d * H * hd + 2 * d * KV * hd + H * hd * d
        if cfg.qkv_bias:
            a += H * hd + 2 * KV * hd
        return a

    fam = cfg.family
    if fam in (FAMILY_DENSE, FAMILY_MOE, FAMILY_VLM):
        n_dense, n_moe = _moe_split(cfg)
        per_dense = attn_p() + mlp_param_count(d, cfg.d_ff, cfg.act) + 2 * norm_p
        n += n_dense * per_dense
        if n_moe:
            moe_p = (moe_mod.moe_active_param_count(cfg) if active_only
                     else moe_mod.moe_param_count(cfg))
            n += n_moe * (attn_p() + moe_p + 2 * norm_p)
        if fam == FAMILY_VLM:
            v = cfg.vlm
            n += v.vision_dim * v.projector_hidden + v.projector_hidden * d
            n += 2 * v.vision_dim
        if cfg.mtp:
            n += attn_p() + mlp_param_count(d, cfg.d_ff, cfg.act) + 3 * norm_p
    elif fam == FAMILY_ENCDEC:
        e = cfg.encdec
        n += e.frontend_dim * d
        per_enc = attn_p() + mlp_param_count(d, cfg.d_ff, cfg.act) + 2 * norm_p
        n += e.encoder_layers * per_enc + norm_p
        per_dec = 2 * attn_p() + mlp_param_count(d, cfg.d_ff, cfg.act) + 3 * norm_p
        n += cfg.num_layers * per_dec
    elif fam == FAMILY_SSM:
        from repro.models.ssm import mlstm_dims
        inner, Hh, dk, dv = mlstm_dims(cfg)
        per_m = (d * 2 * inner + inner * Hh * dk * 2 + inner * Hh * dv
                 + inner * 2 * Hh + 2 * Hh + inner + inner * d + d)
        n_periods, period = _xlstm_layout(cfg)
        dff = -(-4 * d // 3)
        per_s = d * 4 * d + 4 * d + 4 * (d // cfg.num_heads) * d + d + \
            2 * d * dff + dff * d + d
        if n_periods:
            n += n_periods * ((period - 1) * per_m + per_s)
        else:
            n += cfg.num_layers * per_m
    elif fam == FAMILY_HYBRID:
        s = cfg.ssm
        inner, Hh, conv_ch = ssm_mod.mamba2_dims(cfg)
        per_mamba = (d * (2 * inner + 2 * s.state_dim + Hh)
                     + s.conv_dim * conv_ch + conv_ch + 3 * Hh + inner
                     + inner * d + d)
        n += cfg.num_layers * per_mamba
        n += attn_p() + mlp_param_count(d, cfg.hybrid.shared_d_ff, cfg.act) + 2 * norm_p
    n += norm_p                                                # final norm
    return int(n)
