from repro.models.common import count_params
from repro.models.model import (analytic_param_count, decode_step, encode,
                                init_cache, init_params, loss_fn,
                                prefill_logits, prefill_with_cache)

__all__ = ["analytic_param_count", "init_cache", "init_params", "loss_fn",
           "prefill_logits", "prefill_with_cache", "encode", "decode_step",
           "count_params"]
