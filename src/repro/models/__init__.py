from repro.models.common import count_params
from repro.models.model import (analytic_param_count, init_cache, init_params,
                                loss_fn, prefill_logits, decode_step)

__all__ = ["analytic_param_count", "init_cache", "init_params", "loss_fn",
           "prefill_logits", "decode_step", "count_params"]
