"""State-space / recurrent blocks: Mamba2 (SSD chunked scan), mLSTM, sLSTM.

The shared compute core is ``chunked_gla`` — a chunked gated-linear-attention
scan (the "state-space duality" form of Mamba2 [arXiv:2405.21060] and the
matrix-memory mLSTM [arXiv:2405.04517]): within a chunk the recurrence is a
masked quadratic contraction (MXU-friendly), across chunks a short
``lax.scan`` carries the [dk, dv] state. ``repro.kernels.ssm_scan`` is the
Pallas TPU kernel pair for the same contraction, dispatched on the
``ssm_scan`` kernel-registry op (``cfg.kernels``): ``ops.gla_scan`` carries
a fused custom_vjp — the forward kernel checkpoints per-chunk states and a
reverse chunk-scan kernel emits dq/dk/dv/dg in one pass, so training never
recomputes through the jnp scan.

Decode is the exact recurrent update: O(1) state per token — this is what
makes the SSM/hybrid architectures eligible for the long_500k shape.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.models.common import dense_init, split_dict
from repro.models.layers import apply_norm, norm_init


# ---------------------------------------------------------------------------
# Chunked gated linear attention core
#   S_t = exp(g_t) * S_{t-1} + k_t v_t^T ;  y_t = q_t . S_t   (per head)
# ---------------------------------------------------------------------------

def chunked_gla(q, k, v, g, state=None, chunk: int = 64):
    """q,k: [B,S,H,dk]; v: [B,S,H,dv]; g: [B,S,H] log-decay (<= 0).

    Returns (y: [B,S,H,dv], final_state: [B,H,dk,dv]).
    """
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        g = jnp.pad(g, ((0, 0), (0, pad), (0, 0)))

    def resh(x):
        return jnp.moveaxis(x.reshape((B, nc, Q) + x.shape[2:]), 1, 0)

    qc, kc, vc, gc = resh(q), resh(k), resh(v), resh(g)        # [nc,B,Q,...]
    if state is None:
        state = jnp.zeros((B, H, dk, dv), jnp.float32)

    tri = jnp.tril(jnp.ones((Q, Q), bool))

    def step(S0, inp):
        qq, kk, vv, gg = inp                                   # [B,Q,H,*]
        cum = jnp.cumsum(gg.astype(jnp.float32), axis=1)       # [B,Q,H]
        # intra-chunk: A_ij = (q_i.k_j) * exp(cum_i - cum_j), j <= i
        scores = jnp.einsum("bihd,bjhd->bhij", qq, kk,
                            preferred_element_type=jnp.float32)
        dmat = cum.transpose(0, 2, 1)[:, :, :, None] - cum.transpose(0, 2, 1)[:, :, None, :]
        # mask BEFORE exp: for j > i the exponent is positive and can
        # overflow to inf, and where(mask, inf, 0) has a NaN gradient
        dmat = jnp.exp(jnp.where(tri[None, None], dmat, -jnp.inf))
        y_intra = jnp.einsum("bhij,bjhv->bihv", scores * dmat,
                             vv.astype(jnp.float32))
        # contribution of the carried state
        y_inter = jnp.einsum("bihd,bhdv->bihv",
                             qq.astype(jnp.float32) * jnp.exp(cum)[..., None], S0)
        # next state
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)           # [B,Q,H]
        S_local = jnp.einsum("bjhd,bjhv->bhdv",
                             kk.astype(jnp.float32) * decay_to_end[..., None],
                             vv.astype(jnp.float32))
        S1 = jnp.exp(cum[:, -1])[..., None, None] * S0 + S_local
        return S1, y_intra + y_inter

    state, yc = jax.lax.scan(step, state, (qc, kc, vc, gc))
    y = jnp.moveaxis(yc, 0, 1).reshape(B, nc * Q, H, dv)[:, :S]
    return y.astype(q.dtype), state


def _gla_pallas(q, k, v, g, chunk):
    """Pallas-kernel path of the zero-initial-state chunked GLA scan.

    Fully differentiable: ``ops.gla_scan`` carries a ``jax.custom_vjp``
    pairing the forward kernel (which checkpoints per-chunk states) with the
    fused reverse chunk-scan kernel — the backward is a single pass, no
    recompute through the jnp ``chunked_gla``."""
    from repro.kernels import ops
    return ops.gla_scan(q, k, v, g, chunk=chunk,
                        interpret=ops.default_interpret())


def _gla_forward(cfg, q, k, v, g, *, chunk: int):
    """Full-sequence GLA forward (no initial/final state) dispatched on the
    ``ssm_scan`` registry op. Stateful callers (prefill, chunk streaming) use
    ``chunked_gla`` directly — the kernel does not return the final state."""
    if registry.backend_for(cfg, "ssm_scan") == "pallas":
        return _gla_pallas(q, k, v, g, chunk)
    y, _ = chunked_gla(q, k, v, g, chunk=chunk)
    return y


def gla_decode_step(q, k, v, g, state):
    """One-token recurrent update. q,k: [B,H,dk]; v: [B,H,dv]; g: [B,H]."""
    a = jnp.exp(g.astype(jnp.float32))[..., None, None]
    state = a * state + jnp.einsum("bhd,bhv->bhdv", k.astype(jnp.float32),
                                   v.astype(jnp.float32))
    y = jnp.einsum("bhd,bhdv->bhv", q.astype(jnp.float32), state)
    return y.astype(q.dtype), state


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def mamba2_dims(cfg):
    s = cfg.ssm
    inner = s.expand * cfg.d_model
    heads = inner // s.head_dim
    conv_ch = inner + 2 * s.state_dim         # x, B, C all go through conv
    return inner, heads, conv_ch


def mamba2_init(key, cfg, dtype):
    s = cfg.ssm
    d = cfg.d_model
    inner, H, conv_ch = mamba2_dims(cfg)
    ks = split_dict(key, ["in", "conv", "dt", "out", "norm"])
    # separate projections (z / xBC / dt) instead of one fused in_proj:
    # each gets a clean tensor-parallel sharding without slicing a sharded dim
    p = {
        "w_z": dense_init(ks["in"], d, inner, dtype),
        "w_xbc": dense_init(ks["norm"], d, conv_ch, dtype),
        "w_dt": dense_init(ks["dt"], d, H, dtype),
        "conv_w": (0.1 * jax.random.normal(ks["conv"], (s.conv_dim, conv_ch), jnp.float32)).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.ones((inner,), dtype),
        "out_proj": dense_init(ks["out"], inner, d, dtype),
    }
    return p


def _depthwise_conv(x, w, b):
    """Causal depthwise conv. x: [B,S,C]; w: [K,C]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :] for i in range(K))
    return out + b


def _mamba2_proj(p, cfg, x):
    inner, H, conv_ch = mamba2_dims(cfg)
    return x @ p["w_z"], x @ p["w_xbc"], x @ p["w_dt"], inner, H


def _mamba2_run(p, cfg, x, state, want_state: bool):
    """Shared full-sequence Mamba2 body. Returns (out [B,S,d], final_state
    or None, raw xBC projections) — apply/prefill are thin views of this so
    their numerics can never diverge. ``want_state`` forces the jnp chunked
    scan (the kernel does not return the final state)."""
    s = cfg.ssm
    B, S, d = x.shape
    z, xbc_raw, dt, inner, H = _mamba2_proj(p, cfg, x)
    xbc = jax.nn.silu(_depthwise_conv(xbc_raw, p["conv_w"], p["conv_b"]))
    xs = xbc[..., :inner].reshape(B, S, H, s.head_dim)
    Bmat = xbc[..., inner:inner + s.state_dim]               # [B,S,N] (1 group)
    Cmat = xbc[..., inner + s.state_dim:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # [B,S,H]
    A = -jnp.exp(p["A_log"])                                  # [H]
    g = dt * A                                                # log-decay <= 0
    kk = jnp.broadcast_to(Bmat[:, :, None, :], (B, S, H, s.state_dim))
    qq = jnp.broadcast_to(Cmat[:, :, None, :], (B, S, H, s.state_dim))
    vv = xs * dt[..., None].astype(xs.dtype)
    if want_state or state is not None:
        y, st = chunked_gla(qq, kk, vv, g, state=state, chunk=s.chunk)
    else:
        y, st = _gla_forward(cfg, qq, kk, vv, g, chunk=s.chunk), None
    y = y + xs * p["D"][None, None, :, None]
    y = y.reshape(B, S, inner)
    # gated RMSNorm (Mamba2 norm-before-out)
    y = apply_norm("rmsnorm", {"scale": p["norm"]}, y * jax.nn.silu(z))
    return y @ p["out_proj"], st, xbc_raw


def mamba2_apply(p, cfg, x, state=None):
    """x: [B,S,d] -> [B,S,d] (training/prefill path)."""
    out, _, _ = _mamba2_run(p, cfg, x, state, want_state=False)
    return out


def mamba2_prefill(p, cfg, x, cache):
    """Full-sequence prefill that also fills the recurrent decode cache:
    final SSM state + the last conv_dim-1 raw xBC rows (the depthwise-conv
    history ``mamba2_decode`` consumes). x: [B,S,d] from a FRESH cache."""
    out, st, xbc_raw = _mamba2_run(p, cfg, x, cache["state"], want_state=True)
    K1 = cache["conv"].shape[1]                       # conv_dim - 1
    conv_hist = jnp.concatenate(
        [cache["conv"], xbc_raw.astype(cache["conv"].dtype)], axis=1)[:, -K1:] \
        if K1 else cache["conv"]
    return out, {"state": st, "conv": conv_hist}


def mamba2_cache_init(cfg, batch: int, dtype):
    s = cfg.ssm
    inner, H, conv_ch = mamba2_dims(cfg)
    return {"state": jnp.zeros((batch, H, s.state_dim, s.head_dim), jnp.float32),
            "conv": jnp.zeros((batch, s.conv_dim - 1, conv_ch), dtype)}


def mamba2_decode(p, cfg, x, cache):
    """x: [B,1,d]; O(1) recurrent update."""
    s = cfg.ssm
    B = x.shape[0]
    z, xbc, dt, inner, H = _mamba2_proj(p, cfg, x)
    conv_in = jnp.concatenate([cache["conv"], xbc], axis=1)   # [B,K,convch]
    xbc1 = jnp.einsum("bkc,kc->bc", conv_in, p["conv_w"]) + p["conv_b"]
    xbc1 = jax.nn.silu(xbc1)
    new_conv = conv_in[:, 1:]
    xs = xbc1[:, :inner].reshape(B, H, s.head_dim)
    Bv = xbc1[:, inner:inner + s.state_dim]
    Cv = xbc1[:, inner + s.state_dim:]
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
    A = -jnp.exp(p["A_log"])
    g = dt1 * A
    kk = jnp.broadcast_to(Bv[:, None, :], (B, H, s.state_dim))
    qq = jnp.broadcast_to(Cv[:, None, :], (B, H, s.state_dim))
    vv = xs * dt1[..., None].astype(xs.dtype)
    y, new_state = gla_decode_step(qq, kk, vv, g, cache["state"])
    y = y + xs * p["D"][None, :, None]
    y = y.reshape(B, 1, inner)
    y = apply_norm("rmsnorm", {"scale": p["norm"]}, y * jax.nn.silu(z))
    return y @ p["out_proj"], {"state": new_state, "conv": new_conv}


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM matrix memory) — GLA core with a normaliser column
# ---------------------------------------------------------------------------

def mlstm_dims(cfg):
    s = cfg.ssm
    inner = s.expand * cfg.d_model
    H = cfg.num_heads
    dv = inner // H
    dk = max(8, int(dv * s.mlstm_qk_dim_factor))
    return inner, H, dk, dv


def mlstm_init(key, cfg, dtype):
    d = cfg.d_model
    inner, H, dk, dv = mlstm_dims(cfg)
    ks = split_dict(key, ["up", "q", "k", "v", "gates", "out", "norm"])
    return {
        "up": dense_init(ks["up"], d, 2 * inner, dtype),       # x path + gate z
        "wq": dense_init(ks["q"], inner, H * dk, dtype),
        "wk": dense_init(ks["k"], inner, H * dk, dtype),
        "wv": dense_init(ks["v"], inner, H * dv, dtype),
        "w_gates": dense_init(ks["gates"], inner, 2 * H, dtype),  # i, f logits
        "gate_bias": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]).astype(jnp.float32),
        "norm": jnp.ones((inner,), dtype),
        "down": dense_init(ks["out"], inner, d, dtype),
    }


def _mlstm_qkvg(p, cfg, x):
    inner, H, dk, dv = mlstm_dims(cfg)
    B, S, _ = x.shape
    up = x @ p["up"]
    xin, z = up[..., :inner], up[..., inner:]
    q = (xin @ p["wq"]).reshape(B, S, H, dk) / math.sqrt(dk)
    k = (xin @ p["wk"]).reshape(B, S, H, dk) / math.sqrt(dk)
    v = (xin @ p["wv"]).reshape(B, S, H, dv)
    gl = (xin @ p["w_gates"]).astype(jnp.float32) + p["gate_bias"]
    i_g = jax.nn.sigmoid(gl[..., :H])                         # input gate
    log_f = jax.nn.log_sigmoid(gl[..., H:])                   # forget (log)
    return q, k, v, i_g, log_f, z, (inner, H, dk, dv)


def _mlstm_readout(p, y_aug, z, inner):
    # y_aug: [...,H,dv+1]: matrix-memory readout + normaliser column
    num = y_aug[..., :-1]
    den = jnp.maximum(jnp.abs(y_aug[..., -1:]), 1.0)
    y = (num / den).reshape(z.shape[:-1] + (inner,))
    y = apply_norm("rmsnorm", {"scale": p["norm"]}, y) * jax.nn.silu(z)
    return y @ p["down"]


def _mlstm_run(p, cfg, x, state, want_state: bool):
    """Shared full-sequence mLSTM body (see ``_mamba2_run``)."""
    s = cfg.ssm
    q, k, v, i_g, log_f, z, (inner, H, dk, dv) = _mlstm_qkvg(p, cfg, x)
    v_aug = jnp.concatenate([v, jnp.ones(v.shape[:-1] + (1,), v.dtype)], -1)
    k_in = k * i_g[..., None].astype(k.dtype)
    if want_state or state is not None:
        y_aug, st = chunked_gla(q, k_in, v_aug, log_f, state=state,
                                chunk=s.chunk)
    else:
        y_aug, st = _gla_forward(cfg, q, k_in, v_aug, log_f,
                                 chunk=s.chunk), None
    return _mlstm_readout(p, y_aug, z, inner), st


def mlstm_apply(p, cfg, x, state=None):
    out, _ = _mlstm_run(p, cfg, x, state, want_state=False)
    return out


def mlstm_prefill(p, cfg, x, cache):
    """Full-sequence prefill returning the matrix-memory decode state."""
    out, st = _mlstm_run(p, cfg, x, cache["state"], want_state=True)
    return out, {"state": st}


def mlstm_cache_init(cfg, batch: int):
    inner, H, dk, dv = mlstm_dims(cfg)
    return {"state": jnp.zeros((batch, H, dk, dv + 1), jnp.float32)}


def mlstm_decode(p, cfg, x, cache):
    q, k, v, i_g, log_f, z, (inner, H, dk, dv) = _mlstm_qkvg(p, cfg, x)
    v_aug = jnp.concatenate([v, jnp.ones(v.shape[:-1] + (1,), v.dtype)], -1)
    k_in = k * i_g[..., None].astype(k.dtype)
    y_aug, st = gla_decode_step(q[:, 0], k_in[:, 0], v_aug[:, 0],
                                log_f[:, 0], cache["state"])
    y = _mlstm_readout(p, y_aug[:, None], z, inner)
    return y, {"state": st}


# ---------------------------------------------------------------------------
# sLSTM block (scalar memory, true recurrence -> lax.scan over time)
# ---------------------------------------------------------------------------

def slstm_init(key, cfg, dtype):
    d = cfg.d_model
    H = cfg.num_heads
    hd = d // H
    ks = split_dict(key, ["w", "r", "up", "down", "norm"])
    dff = -(-4 * d // 3)
    return {
        "w": dense_init(ks["w"], d, 4 * d, dtype),            # z,i,f,o from x
        "r": (0.1 * jax.random.normal(ks["r"], (4, H, hd, hd), jnp.float32)).astype(dtype),
        "b": jnp.zeros((4 * d,), jnp.float32),
        "norm": jnp.ones((d,), dtype),
        "up1": dense_init(ks["up"], d, dff, dtype),
        "up2": dense_init(ks["down"], d, dff, dtype),
        "down": dense_init(ks["norm"], dff, d, dtype),
    }


def _slstm_cell(p, cfg, xt, h, c, n, m):
    """One sLSTM step. xt: [B,d]; h,c,n: [B,H,hd]; m: [B,H,hd] stabiliser."""
    d = cfg.d_model
    H = cfg.num_heads
    hd = d // H
    B = xt.shape[0]
    pre = (xt @ p["w"]).astype(jnp.float32) + p["b"]
    pre = pre.reshape(B, 4, H, hd)
    rec = jnp.einsum("bhd,ghde->bghe", h.astype(jnp.float32),
                     p["r"].astype(jnp.float32))
    pre = pre + rec
    z = jnp.tanh(pre[:, 0])
    log_i = pre[:, 1]                                          # exp input gate
    log_f = jax.nn.log_sigmoid(pre[:, 2])
    o = jax.nn.sigmoid(pre[:, 3])
    m_new = jnp.maximum(log_f + m, log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c_new = f_s * c + i_s * z
    n_new = f_s * n + i_s
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return h_new, c_new, n_new, m_new


def slstm_apply(p, cfg, x, cache=None):
    B, S, d = x.shape
    H = cfg.num_heads
    hd = d // H
    if cache is None:
        h = jnp.zeros((B, H, hd), jnp.float32)
        c = jnp.zeros((B, H, hd), jnp.float32)
        n = jnp.zeros((B, H, hd), jnp.float32)
        m = jnp.full((B, H, hd), -1e30, jnp.float32)
    else:
        h, c, n, m = cache["h"], cache["c"], cache["n"], cache["m"]

    def step(carry, xt):
        h, c, n, m = carry
        h, c, n, m = _slstm_cell(p, cfg, xt, h, c, n, m)
        return (h, c, n, m), h

    (h, c, n, m), hs = jax.lax.scan(step, (h, c, n, m),
                                    jnp.moveaxis(x, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(x.dtype)
    y = apply_norm("rmsnorm", {"scale": p["norm"]}, y)
    # GEGLU up/down projection
    u = jax.nn.gelu(y @ p["up1"]) * (y @ p["up2"])
    out = u @ p["down"]
    new_cache = {"h": h, "c": c, "n": n, "m": m}
    return out, new_cache


def slstm_cache_init(cfg, batch: int):
    H = cfg.num_heads
    hd = cfg.d_model // H
    z = jnp.zeros((batch, H, hd), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": jnp.full((batch, H, hd), -1e30, jnp.float32)}
