"""Per-family transformer/SSM blocks, stacked-parameter init, scan runners.

Layers are stored *stacked* (leading layer axis) and executed with
``lax.scan`` + ``jax.checkpoint`` (remat): one traced layer body keeps the
HLO small enough to compile 61-layer/512-device dry-runs quickly, and the
stacked leading axis is what the CDP update rules mask per stage.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import split_dict
from repro.models.layers import apply_mlp, apply_norm, mlp_init, norm_init

PyTree = Any

# ---------------------------------------------------------------------------
# Optional activation-sharding constraint (sequence parallelism): when set,
# the residual stream is constrained to be sharded along the sequence dim
# over the given mesh axis between layers, so the remat-saved carries cost
# 1/axis_size the memory. Set by the trainer (beyond-paper §Perf lever).
# ---------------------------------------------------------------------------
_ACT_CONSTRAINT = None            # (mesh, axis_name) or None


def set_activation_sharding(mesh, axis_name):
    global _ACT_CONSTRAINT
    _ACT_CONSTRAINT = (mesh, axis_name) if axis_name else None


def _constrain_acts(x):
    if _ACT_CONSTRAINT is None or getattr(x, "ndim", 0) != 3:
        return x
    mesh, axis = _ACT_CONSTRAINT
    if x.shape[1] % mesh.shape[axis]:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(None, axis, None)))


def _stack_init(init_one, key, n: int):
    if n == 0:
        return None
    return jax.vmap(init_one)(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# Decoder layer (dense FFN or MoE FFN; GQA or MLA attention)
# ---------------------------------------------------------------------------

def decoder_layer_init(key, cfg, dtype, *, use_moe: bool):
    ks = split_dict(key, ["attn", "ffn"])
    d = cfg.d_model
    p = {"ln1": norm_init(cfg.norm, d, dtype),
         "ln2": norm_init(cfg.norm, d, dtype)}
    if cfg.attn_kind == "mla":
        p["attn"] = attn.mla_init(ks["attn"], cfg, dtype)
    else:
        p["attn"] = attn.gqa_init(ks["attn"], cfg, dtype)
    if use_moe:
        p["ffn"] = moe_mod.moe_init(ks["ffn"], cfg, dtype)
    else:
        p["ffn"] = mlp_init(ks["ffn"], d, cfg.d_ff, cfg.act, dtype)
    return p


def decoder_layer_apply(p, cfg, x, positions, *, use_moe: bool, causal=True,
                        drop_tokens: bool = True):
    h = apply_norm(cfg.norm, p["ln1"], x)
    if cfg.attn_kind == "mla":
        a = attn.mla_apply(p["attn"], cfg, h, positions)
    else:
        a = attn.gqa_apply(p["attn"], cfg, h, positions, causal=causal)
    x = x + a
    h = apply_norm(cfg.norm, p["ln2"], x)
    if use_moe:
        B, S, d = h.shape
        y, aux = moe_mod.moe_apply(p["ffn"], cfg, h.reshape(B * S, d),
                                   drop=drop_tokens)
        return x + y.reshape(B, S, d), aux
    return x + apply_mlp(p["ffn"], h, cfg.act), jnp.float32(0.0)


def decoder_layer_decode(p, cfg, x, cache, *, use_moe: bool,
                         ragged: bool = False, paged_table=None,
                         active=None):
    h = apply_norm(cfg.norm, p["ln1"], x)
    if paged_table is not None:
        # paged KV cache: per-row block table, GQA only (model.py gates)
        a, cache = attn.gqa_decode_paged(p["attn"], cfg, h, cache,
                                         paged_table, active=active)
    elif cfg.attn_kind == "mla":
        a, cache = attn.mla_decode(p["attn"], cfg, h, cache, ragged=ragged,
                                   active=active)
    else:
        a, cache = attn.gqa_decode(p["attn"], cfg, h, cache, ragged=ragged,
                                   active=active)
    x = x + a
    h = apply_norm(cfg.norm, p["ln2"], x)
    if use_moe:
        B, S, d = h.shape
        y, _ = moe_mod.moe_apply(p["ffn"], cfg, h.reshape(B * S, d),
                                 drop=False)
        y = y.reshape(B, S, d)
    else:
        y = apply_mlp(p["ffn"], h, cfg.act)
    return x + y, cache


def decoder_layer_prefill(p, cfg, x, positions, cache, *, use_moe: bool,
                          lengths=None, paged=None, chunk_hist=None):
    """Fused full-sequence prefill of one decoder layer: the training-shaped
    forward (blockwise/flash attention, dropless MoE) that also fills the
    decode cache. ``lengths`` ([B] int32) threads ragged per-row prompt
    lengths into the cache fill. ``paged`` = (table [B,nb], hist [B]) routes
    the paged ragged-tail prefill instead (GQA only; positions are derived
    from ``hist`` inside). ``chunk_hist`` ([B] int32) routes the CHUNKED
    dense prefill: ``x`` holds each row's next prompt chunk (absolute
    positions chunk_hist..lengths), scattered into the dense cache at its
    absolute slots (positions likewise derived inside). Returns
    (x, new_cache)."""
    h = apply_norm(cfg.norm, p["ln1"], x)
    if paged is not None:
        table, hist = paged
        a, cache = attn.gqa_prefill_paged(p["attn"], cfg, h, cache, table,
                                          lengths, hist)
    elif chunk_hist is not None:
        if cfg.attn_kind == "mla":
            a, cache = attn.mla_prefill_chunked(p["attn"], cfg, h, cache,
                                                lengths, chunk_hist)
        else:
            a, cache = attn.gqa_prefill_chunked(p["attn"], cfg, h, cache,
                                                lengths, chunk_hist)
    elif cfg.attn_kind == "mla":
        a, cache = attn.mla_prefill(p["attn"], cfg, h, positions, cache,
                                    lengths=lengths)
    else:
        a, cache = attn.gqa_prefill(p["attn"], cfg, h, positions, cache,
                                    lengths=lengths)
    x = x + a
    h = apply_norm(cfg.norm, p["ln2"], x)
    if use_moe:
        B, S, d = h.shape
        y, _ = moe_mod.moe_apply(p["ffn"], cfg, h.reshape(B * S, d),
                                 drop=False)
        y = y.reshape(B, S, d)
    else:
        y = apply_mlp(p["ffn"], h, cfg.act)
    return x + y, cache


def decoder_layer_cache_init(cfg, batch, cache_len, dtype):
    if cfg.attn_kind == "mla":
        return attn.mla_cache_init(cfg, batch, cache_len, dtype)
    return attn.gqa_cache_init(cfg, batch, cache_len, dtype)


def decoder_layer_paged_cache_init(cfg, batch, num_blocks, block_size, dtype):
    if cfg.attn_kind == "mla":
        raise NotImplementedError(
            "paged KV cache supports GQA attention only (MLA latent caches "
            "stay on the dense merge_caches path)")
    return attn.paged_gqa_cache_init(cfg, batch, num_blocks, block_size, dtype)


# ---------------------------------------------------------------------------
# Scan runners
# ---------------------------------------------------------------------------

def scan_layers(layer_fn, stacked: PyTree, x, *, remat: bool = True):
    """layer_fn(layer_params, x) -> (x, aux). Scans the stacked layer axis,
    accumulating aux. Returns (x, total_aux)."""
    def body(carry, lp):
        x, aux = carry
        x, a = layer_fn(lp, x)
        return (_constrain_acts(x), aux + a), None
    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), stacked)
    return x, aux


def scan_layers_decode(layer_fn, stacked: PyTree, caches: PyTree, x):
    """layer_fn(layer_params, x, cache) -> (x, new_cache)."""
    def body(x, inp):
        lp, cache = inp
        x, new_cache = layer_fn(lp, x, cache)
        return x, new_cache
    x, new_caches = jax.lax.scan(body, x, (stacked, caches))
    return x, new_caches


# ---------------------------------------------------------------------------
# Encoder layer (bidirectional self-attn + MLP) for enc-dec
# ---------------------------------------------------------------------------

def encoder_layer_init(key, cfg, dtype):
    ks = split_dict(key, ["attn", "ffn"])
    d = cfg.d_model
    return {"ln1": norm_init(cfg.norm, d, dtype),
            "attn": attn.gqa_init(ks["attn"], cfg, dtype),
            "ln2": norm_init(cfg.norm, d, dtype),
            "ffn": mlp_init(ks["ffn"], d, cfg.d_ff, cfg.act, dtype)}


def encoder_layer_apply(p, cfg, x, positions):
    h = apply_norm(cfg.norm, p["ln1"], x)
    x = x + attn.gqa_apply(p["attn"], cfg, h, positions, causal=False)
    h = apply_norm(cfg.norm, p["ln2"], x)
    return x + apply_mlp(p["ffn"], h, cfg.act), jnp.float32(0.0)


# ---------------------------------------------------------------------------
# Enc-dec decoder layer (self + cross + MLP)
# ---------------------------------------------------------------------------

def xdec_layer_init(key, cfg, dtype):
    ks = split_dict(key, ["self", "cross", "ffn"])
    d = cfg.d_model
    return {"ln1": norm_init(cfg.norm, d, dtype),
            "self": attn.gqa_init(ks["self"], cfg, dtype),
            "ln_x": norm_init(cfg.norm, d, dtype),
            "cross": attn.cross_attn_init(ks["cross"], cfg, dtype),
            "ln2": norm_init(cfg.norm, d, dtype),
            "ffn": mlp_init(ks["ffn"], d, cfg.d_ff, cfg.act, dtype)}


def xdec_layer_apply(p, cfg, x, positions, memory):
    h = apply_norm(cfg.norm, p["ln1"], x)
    x = x + attn.gqa_apply(p["self"], cfg, h, positions, causal=True)
    h = apply_norm(cfg.norm, p["ln_x"], x)
    x = x + attn.cross_attn_apply(p["cross"], cfg, h, memory)
    h = apply_norm(cfg.norm, p["ln2"], x)
    return x + apply_mlp(p["ffn"], h, cfg.act), jnp.float32(0.0)


def xdec_layer_decode(p, cfg, x, cache, memory, *, ragged: bool = False):
    h = apply_norm(cfg.norm, p["ln1"], x)
    a, self_cache = attn.gqa_decode(p["self"], cfg, h, cache, ragged=ragged)
    x = x + a
    h = apply_norm(cfg.norm, p["ln_x"], x)
    x = x + attn.cross_attn_apply(p["cross"], cfg, h, memory)
    h = apply_norm(cfg.norm, p["ln2"], x)
    return x + apply_mlp(p["ffn"], h, cfg.act), self_cache


def xdec_layer_prefill(p, cfg, x, positions, cache, memory):
    h = apply_norm(cfg.norm, p["ln1"], x)
    a, self_cache = attn.gqa_prefill(p["self"], cfg, h, positions, cache)
    x = x + a
    h = apply_norm(cfg.norm, p["ln_x"], x)
    x = x + attn.cross_attn_apply(p["cross"], cfg, h, memory)
    h = apply_norm(cfg.norm, p["ln2"], x)
    return x + apply_mlp(p["ffn"], h, cfg.act), self_cache


# ---------------------------------------------------------------------------
# Hybrid (zamba2): mamba2 stack + ONE shared attention+MLP block
# ---------------------------------------------------------------------------

def shared_attn_block_init(key, cfg, dtype):
    ks = split_dict(key, ["attn", "ffn"])
    d = cfg.d_model
    return {"ln1": norm_init(cfg.norm, d, dtype),
            "attn": attn.gqa_init(ks["attn"], cfg, dtype),
            "ln2": norm_init(cfg.norm, d, dtype),
            "ffn": mlp_init(ks["ffn"], d, cfg.hybrid.shared_d_ff, cfg.act, dtype)}


def shared_attn_block_apply(p, cfg, x, positions):
    h = apply_norm(cfg.norm, p["ln1"], x)
    x = x + attn.gqa_apply(p["attn"], cfg, h, positions, causal=True)
    h = apply_norm(cfg.norm, p["ln2"], x)
    return x + apply_mlp(p["ffn"], h, cfg.act)


def shared_attn_block_decode(p, cfg, x, cache, *, ragged: bool = False):
    h = apply_norm(cfg.norm, p["ln1"], x)
    a, cache = attn.gqa_decode(p["attn"], cfg, h, cache, ragged=ragged)
    x = x + a
    h = apply_norm(cfg.norm, p["ln2"], x)
    return x + apply_mlp(p["ffn"], h, cfg.act), cache


def shared_attn_block_prefill(p, cfg, x, positions, cache):
    h = apply_norm(cfg.norm, p["ln1"], x)
    a, cache = attn.gqa_prefill(p["attn"], cfg, h, positions, cache)
    x = x + a
    h = apply_norm(cfg.norm, p["ln2"], x)
    return x + apply_mlp(p["ffn"], h, cfg.act), cache


def mamba_layer_init(key, cfg, dtype):
    ks = split_dict(key, ["m"])
    return {"ln": norm_init(cfg.norm, cfg.d_model, dtype),
            "mamba": ssm_mod.mamba2_init(ks["m"], cfg, dtype)}


def mamba_layer_apply(p, cfg, x):
    h = apply_norm(cfg.norm, p["ln"], x)
    y = ssm_mod.mamba2_apply(p["mamba"], cfg, h)
    return x + y.astype(x.dtype), jnp.float32(0.0)


def mamba_layer_decode(p, cfg, x, cache):
    h = apply_norm(cfg.norm, p["ln"], x)
    y, cache = ssm_mod.mamba2_decode(p["mamba"], cfg, h, cache)
    return x + y.astype(x.dtype), cache


def mamba_layer_prefill(p, cfg, x, cache):
    h = apply_norm(cfg.norm, p["ln"], x)
    y, cache = ssm_mod.mamba2_prefill(p["mamba"], cfg, h, cache)
    return x + y.astype(x.dtype), cache


# ---------------------------------------------------------------------------
# xLSTM blocks
# ---------------------------------------------------------------------------

def mlstm_layer_init(key, cfg, dtype):
    ks = split_dict(key, ["m"])
    return {"ln": norm_init(cfg.norm, cfg.d_model, dtype),
            "mlstm": ssm_mod.mlstm_init(ks["m"], cfg, dtype)}


def mlstm_layer_apply(p, cfg, x):
    h = apply_norm(cfg.norm, p["ln"], x)
    y = ssm_mod.mlstm_apply(p["mlstm"], cfg, h)
    return x + y.astype(x.dtype), jnp.float32(0.0)


def mlstm_layer_decode(p, cfg, x, cache):
    h = apply_norm(cfg.norm, p["ln"], x)
    y, cache = ssm_mod.mlstm_decode(p["mlstm"], cfg, h, cache)
    return x + y.astype(x.dtype), cache


def mlstm_layer_prefill(p, cfg, x, cache):
    h = apply_norm(cfg.norm, p["ln"], x)
    y, cache = ssm_mod.mlstm_prefill(p["mlstm"], cfg, h, cache)
    return x + y.astype(x.dtype), cache


def slstm_layer_init(key, cfg, dtype):
    ks = split_dict(key, ["s"])
    return {"ln": norm_init(cfg.norm, cfg.d_model, dtype),
            "slstm": ssm_mod.slstm_init(ks["s"], cfg, dtype)}


def slstm_layer_apply(p, cfg, x, cache=None):
    h = apply_norm(cfg.norm, p["ln"], x)
    y, new_cache = ssm_mod.slstm_apply(p["slstm"], cfg, h, cache)
    return x + y.astype(x.dtype), new_cache
