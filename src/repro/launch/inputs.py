"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, no device allocation. The dry-run lowers against these."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (FAMILY_ENCDEC, FAMILY_HYBRID, FAMILY_SSM,
                                FAMILY_VLM, InputShape, ModelConfig)
from repro.models import model as model_mod

SDS = jax.ShapeDtypeStruct

# sliding-window opt-in used by long_500k for archs whose reference form is
# full attention (recorded as a variant in DESIGN.md)
LONG_CONTEXT_WINDOW = 8192


def adapt_config_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    if shape.name == "long_500k" and not cfg.sub_quadratic \
            and cfg.family not in (FAMILY_SSM,):
        cfg = cfg.with_(attn_window=LONG_CONTEXT_WINDOW)
    if shape.name == "long_500k" and cfg.family == FAMILY_HYBRID \
            and not cfg.attn_window:
        # bound the shared attention block's cache as well
        cfg = cfg.with_(attn_window=LONG_CONTEXT_WINDOW)
    return cfg


def batch_specs(cfg: ModelConfig, shape: InputShape,
                with_targets: bool = True) -> Dict[str, Any]:
    """Inputs for train/prefill (full-sequence) steps."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    batch = {"tokens": SDS((B, S), jnp.int32)}
    if with_targets:
        batch["targets"] = SDS((B, S), jnp.int32)
    if cfg.family == FAMILY_VLM:
        v = cfg.vlm
        batch["patches"] = SDS((B, v.num_patches, v.vision_dim), dt)
    if cfg.family == FAMILY_ENCDEC:
        e = cfg.encdec
        batch["frames"] = SDS((B, max(1, S // e.frame_rate_divisor),
                               e.frontend_dim), dt)
    return batch


def decode_specs(cfg: ModelConfig, shape: InputShape) -> Tuple[Dict, Any]:
    """(batch, cache) for serve_step: ONE new token against a seq_len cache."""
    B, S = shape.global_batch, shape.seq_len
    batch = {"token": SDS((B,), jnp.int32)}
    cache = jax.eval_shape(lambda: model_mod.init_cache(cfg, B, S))
    return batch, cache


def params_specs(cfg: ModelConfig) -> Any:
    return jax.eval_shape(
        lambda: model_mod.init_params(cfg, jax.random.PRNGKey(0)))


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """The full kwargs dict for the step being lowered for (cfg, shape)."""
    cfg = adapt_config_for_shape(cfg, shape)
    if shape.is_decode:
        batch, cache = decode_specs(cfg, shape)
        return {"batch": batch, "cache": cache}
    return {"batch": batch_specs(cfg, shape, with_targets=(shape.kind == "train"))}
