"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Target: TPU v5e pods — 256 chips per pod as a
(data=16, model=16) mesh; multi-pod adds a leading "pod" axis.
"""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh over the CPU's forced host devices (tests/examples)."""
    if pod:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))
