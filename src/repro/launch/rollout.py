"""RL rollout launcher — a thin argparse shim over
``repro.engine.RolloutEngine``.

    PYTHONPATH=src python -m repro.launch.rollout --arch stablelm-1.6b \
        --reduced --iters 3 [--plan dp|zero_cdp|...] \
        [--groups 2 --group-size 4 --prompt-len 8 --gen 8] \
        [--mesh-data 2 --host-devices 2] [--events-jsonl rollout.jsonl]

One process runs the whole loop: generate (continuous batching over the
paged KV cache, per-request sampling seeds), score (steerable synthetic
reward + behaviour logprobs), train (REINFORCE through TrainEngine's
jitted step under the chosen plan, serve pool asleep at level 2), push
(device-side weight hand-off under a transfer guard). Mean group reward
on the synthetic task must RISE across iterations — the printed reward
curve is the acceptance signal.
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None):
    from repro.parallel import available_plans, plan_help

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--plan", default=None, choices=available_plans(),
                    help="parallelism strategy for the TRAIN step "
                         "(repro.parallel registry). " + plan_help())
    ap.add_argument("--groups", type=int, default=2,
                    help="trajectory groups per iteration (one prompt each)")
    ap.add_argument("--group-size", type=int, default=4,
                    help="samples per group (the group-relative baseline)")
    ap.add_argument("--iters", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--top-k", type=int, default=0,
                    help="restrict sampling to the k most likely tokens "
                         "(0 = full vocab)")
    ap.add_argument("--lr", type=float, default=0.5)
    ap.add_argument("--kv-block-size", type=int, default=4)
    ap.add_argument("--reward-target", type=int, default=None,
                    help="first token id of the rewarded band "
                         "(default vocab//2)")
    ap.add_argument("--reward-width", type=int, default=None,
                    help="width of the rewarded token band "
                         "(default vocab//8)")
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--host-devices", type=int, default=0,
                    help="force N host CPU devices (0 = auto: the mesh size "
                         "when >1; inert when an accelerator is the default "
                         "jax backend)")
    ap.add_argument("--events-jsonl", default=None,
                    help="export the engine event log (phase boundaries, "
                         "pool sleeps) to this JSONL path on exit")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.engine import RunSpec
    spec = RunSpec(arch=args.arch, reduced=args.reduced,
                   plan=args.plan, mesh_data=args.mesh_data,
                   mesh_model=args.mesh_model,
                   host_devices=args.host_devices, seed=args.seed)
    spec = spec.auto_host_devices()     # CPU container: default to mesh size
    spec.ensure_host_devices()          # before anything imports jax state

    from repro.engine import RolloutEngine
    engine = RolloutEngine(spec, plan=args.plan,
                           groups=args.groups, group_size=args.group_size,
                           prompt_len=args.prompt_len, gen=args.gen,
                           iters=args.iters, temperature=args.temperature,
                           top_k=args.top_k, lr=args.lr,
                           kv_block_size=args.kv_block_size,
                           reward_target=args.reward_target,
                           reward_width=args.reward_width)
    history = engine.run()
    curve = [h["mean_reward"] for h in history]
    print(f"reward curve: {[round(r, 3) for r in curve]}")
    if args.events_jsonl:
        n = engine.events.to_jsonl(args.events_jsonl)
        print(f"wrote {n} events to {args.events_jsonl}")
    improved = len(curve) >= 2 and curve[-1] > curve[0]
    print("reward improved." if improved else
          "WARNING: reward did not improve.")
    print("done.")
    return 0 if improved or len(curve) < 2 else 1


if __name__ == "__main__":
    sys.exit(main())
