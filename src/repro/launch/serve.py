"""Serving launcher: prefill a batch of prompts, then batched decode.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
        --reduced --batch 4 --prompt-len 64 --gen 32 --host-devices 4
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh-data", type=int, default=2)
    ap.add_argument("--mesh-model", type=int, default=2)
    ap.add_argument("--host-devices", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   f" --xla_force_host_platform_device_count={args.host_devices}").strip()

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config, get_reduced
    from repro.launch.mesh import make_host_mesh
    from repro.models import decode_step, init_cache, init_params
    from repro.models import model as model_mod
    from repro.data.synthetic import make_lm_data

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_host_mesh(args.mesh_data, args.mesh_model)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)

    B = args.batch
    cache_len = args.prompt_len + args.gen
    toks = make_lm_data(cfg.vocab_size, B * args.prompt_len + 1, seed=args.seed)
    prompts = jnp.asarray(
        toks[:B * args.prompt_len].reshape(B, args.prompt_len) % cfg.vocab_size)

    # prefill by teacher-forcing the prompt through decode_step (exercises the
    # cache path end to end; a production server would use the fused prefill)
    cache = init_cache(cfg, B, cache_len)
    step = jax.jit(lambda p, b, c: decode_step(cfg, p, b, c))

    if cfg.family == "encdec":
        frames = jnp.zeros((B, max(1, args.prompt_len), cfg.encdec.frontend_dim),
                           jnp.dtype(cfg.dtype))
        memory = jax.jit(lambda p, f: model_mod._run_encoder(cfg, p, f))(params, frames)
        cache["memory"] = jnp.zeros_like(cache["memory"]).at[:, :memory.shape[1]].set(
            memory[:, :cache["memory"].shape[1]])

    t0 = time.time()
    logits = None
    for i in range(args.prompt_len):
        logits, cache = step(params, {"token": prompts[:, i]}, cache)
    t_prefill = time.time() - t0

    out = []
    tok = jnp.argmax(logits, -1)
    t0 = time.time()
    for i in range(args.gen):
        out.append(np.asarray(tok))
        logits, cache = step(params, {"token": tok}, cache)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / args.temperature, -1)
        else:
            tok = jnp.argmax(logits, -1)
    t_gen = time.time() - t0

    gen = np.stack(out, 1)
    print(f"prefill: {args.prompt_len} steps in {t_prefill:.2f}s; "
          f"decode: {args.gen} tokens x batch {B} in {t_gen:.2f}s "
          f"({B*args.gen/max(t_gen,1e-9):.1f} tok/s)")
    for b in range(min(B, 2)):
        print(f"  sample {b}: {gen[b][:16].tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
