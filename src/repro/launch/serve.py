"""Serving launcher — a thin argparse shim over ``repro.engine.ServeEngine``.

Static batch (the original path — one fixed batch from prefill to last
token):

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
        --reduced --batch 4 --prompt-len 64 --gen 32 --host-devices 4 \
        [--kernels decode_attn=pallas]

Continuous batching (``--max-slots`` switches to the iteration-level
scheduler: ragged prompts prefill with per-row cache lengths and a queued
request is admitted the moment a decode slot frees up; ``--arrival
poisson`` replays a deterministic Poisson arrival trace):

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
        --reduced --max-slots 4 --arrival poisson --rate 0.5 \
        --num-requests 8

Paged KV cache (with --max-slots): ``--paged`` serves from a block pool
with prefix sharing and host-RAM offload (``--kv-block-size``,
``--kv-pool-blocks``, ``--no-prefix-cache``, ``--sleep-level``); the
paging metrics line reports peak pool occupancy and the prefix hit rate.

Prefill runs as ONE fused ``prefill_with_cache`` pass (prefill tok/s is
reported alongside decode tok/s); enc-dec archs go through the public
``models.encode``.

Wall-clock serving knobs (all built into one explicit ``ServePolicy``):
``--prefill-chunk N`` interleaves chunked prompt prefill with decode,
``--clock {step,wall,virtual}`` picks the scheduler clock, ``--admission
slo`` (or the ``--policy slo`` shorthand) enables deadline-aware
admission, and ``--stream`` prints each token live via
``serve_stream()``.
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--kernels", default=None,
                    help="per-op kernel backends (see launch.train --help)")
    ap.add_argument("--attn-backend", default=None,
                    choices=["jnp", "pallas"],
                    help="DEPRECATED alias: sets train_attn+prefill_attn")
    ap.add_argument("--mesh-data", type=int, default=2)
    ap.add_argument("--mesh-model", type=int, default=2)
    ap.add_argument("--host-devices", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    # continuous batching
    ap.add_argument("--max-slots", type=int, default=0,
                    help="serve with continuous batching over N decode "
                         "slots (0 = static batch via --batch)")
    ap.add_argument("--arrival", default="none",
                    choices=["none", "poisson"],
                    help="request arrival trace: all at step 0, or a "
                         "deterministic Poisson replay (--rate)")
    ap.add_argument("--rate", type=float, default=0.5,
                    help="poisson arrival rate in requests per decode step")
    ap.add_argument("--num-requests", type=int, default=8,
                    help="synthetic staggered workload size (continuous)")
    ap.add_argument("--policy", default="continuous",
                    choices=["continuous", "static", "slo"],
                    help="scheduler policy for --max-slots serving (static "
                         "= fixed-batch baseline on the same jitted fns; "
                         "slo = continuous scheduling with deadline-aware "
                         "admission, shorthand for --admission slo)")
    ap.add_argument("--admission", default=None, choices=["fcfs", "slo"],
                    help="queue-ordering policy: fcfs (default) or "
                         "earliest-deadline-first with feasibility culling")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="cut admitted prompts into chunks of N tokens, "
                         "prefilled one chunk per scheduler iteration "
                         "interleaved with decode (0 = whole-prompt)")
    ap.add_argument("--clock", default="step",
                    choices=["step", "wall", "virtual"],
                    help="scheduler clock: step units (default), the "
                         "monotonic wall clock, or a deterministic virtual "
                         "clock advancing --step-dt seconds per step")
    ap.add_argument("--step-dt", type=float, default=1.0,
                    help="virtual seconds per decode step (--clock virtual)")
    ap.add_argument("--stream", action="store_true",
                    help="serve via serve_stream() and print each token "
                         "the moment its decode step syncs to host")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="optional early-stop token id (costs one host "
                         "sync per decode step)")
    ap.add_argument("--deadline-steps", type=int, default=None,
                    help="engine-wide per-request step budget (queue wait "
                         "+ decode); expired requests return "
                         "status='timeout' with partial tokens")
    ap.add_argument("--queue-limit", type=int, default=None,
                    help="bound the admission queue: arrivals beyond the "
                         "limit are rejected with a per-request error "
                         "instead of waiting forever")
    ap.add_argument("--resilience", default=None,
                    help="arm the resilience layer: 'on' enables the "
                         "health/quarantine pass only, or a fault spec "
                         "('poison_request@3') to poison request rid 3's "
                         "cache rows deterministically")
    # paged KV cache (continuous batching only)
    ap.add_argument("--paged", action="store_true",
                    help="serve from a paged KV block pool (prefix "
                         "sharing + host-RAM offload) instead of the "
                         "dense per-slot cache")
    ap.add_argument("--kv-block-size", type=int, default=16,
                    help="tokens per KV block (--paged)")
    ap.add_argument("--kv-pool-blocks", type=int, default=None,
                    help="total pool blocks shared by all slots (--paged; "
                         "default: slots x cache blocks, the dense "
                         "equivalent)")
    ap.add_argument("--prefix-cache", dest="prefix_cache",
                    action="store_true", default=True,
                    help="share full prompt-prefix blocks across requests "
                         "(--paged; default on)")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false")
    ap.add_argument("--sleep-level", type=int, default=1, choices=[1, 2],
                    help="preemption mode under pool pressure (--paged): "
                         "1 = offload blocks to host RAM and restore "
                         "bitwise on wake, 2 = discard and re-prefill")
    args = ap.parse_args(argv)

    from repro.engine import RunSpec
    spec = RunSpec(arch=args.arch, reduced=args.reduced,
                   kernels=args.kernels, attn_backend=args.attn_backend,
                   mesh_data=args.mesh_data, mesh_model=args.mesh_model,
                   host_devices=args.host_devices, seed=args.seed)
    spec = spec.auto_host_devices()     # CPU container: default to mesh size
    spec.ensure_host_devices()          # before anything imports jax state

    if args.paged and not args.max_slots:
        print("--paged requires --max-slots (continuous batching)",
              file=sys.stderr)
        return 2

    from repro.engine import ServeEngine
    engine = ServeEngine(spec, batch=args.batch, prompt_len=args.prompt_len,
                         gen=args.gen, temperature=args.temperature,
                         resilience=args.resilience, paged=args.paged,
                         kv_block_size=args.kv_block_size,
                         kv_pool_blocks=args.kv_pool_blocks,
                         prefix_cache=args.prefix_cache,
                         sleep_level=args.sleep_level)

    if args.max_slots:
        from repro.engine import ServePolicy
        sched = "continuous" if args.policy == "slo" else args.policy
        admission = args.admission or \
            ("slo" if args.policy == "slo" else "fcfs")
        sp = ServePolicy(max_slots=args.max_slots,
                         num_requests=args.num_requests,
                         arrival=args.arrival, rate=args.rate,
                         policy=sched, admission=admission,
                         eos_id=args.eos_id,
                         deadline_steps=args.deadline_steps,
                         queue_limit=args.queue_limit,
                         prefill_chunk=args.prefill_chunk,
                         clock=args.clock, step_dt=args.step_dt)
        if args.stream:
            gen = engine.serve_stream(policy=sp)
            n_streamed = 0
            while True:
                try:
                    rid, tok = next(gen)
                except StopIteration as fin:
                    res = fin.value
                    break
                print(f"  [stream] rid {rid} token {tok}")
                n_streamed += 1
            print(f"  streamed {n_streamed} tokens live")
        else:
            res = engine.serve(policy=sp)
        for r in res["requests"][:2]:
            print(f"  request {r.rid} (arrival step {r.arrival_step}, "
                  f"{len(r.prompt)}-token prompt, status {r.status}): "
                  f"{r.tokens[:16].tolist()}")
        m = res["metrics"]
        print(f"  admitted mid-decode: {m['admitted_mid_decode']} / "
              f"{m['n_requests']}")
        print(f"  status counts: {m['status_counts']}")
        print(f"  clock {m['clock']} admission {m['admission']} "
              f"goodput {m['goodput']} ttft p50/p99 "
              f"{m['ttft']['p50']}/{m['ttft']['p99']}")
        if "paging" in m:
            pg = m["paging"]
            print(f"  paging: {pg['blocks_in_use_peak']}/"
                  f"{pg['pool_blocks']} blocks peak, prefix hit rate "
                  f"{pg['prefix_hit_rate']}, "
                  f"{pg['marginal_prefill_tokens']}/"
                  f"{pg['prefill_tokens_requested']} prefill tokens "
                  f"computed, {pg['preemptions']} preemptions")
        return 0

    result = engine.generate()
    for b in range(min(args.batch, 2)):
        print(f"  sample {b}: {result['tokens'][b][:16].tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
