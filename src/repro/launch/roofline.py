"""Roofline terms from a compiled dry-run artifact (no hardware needed).

Terms (per device, seconds), TPU v5e constants:
    compute    = HLO_FLOPs / peak_FLOPs            (197 TF/s bf16 per chip)
    memory     = HLO_bytes_accessed / HBM_bw       (819 GB/s per chip)
    collective = collective_bytes / ICI_bw         (~50 GB/s per link)

``compiled.cost_analysis()`` supplies FLOPs / bytes of the SPMD-partitioned
(per-device) module. Collective bytes are NOT in cost_analysis: we parse the
HLO text, summing result-shape bytes of every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute — including ops inside
``while`` bodies (scan over layers, blockwise attention), whose trip counts
are recovered from the loop-condition constant.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of an HLO result type, incl. tuples '(f32[2,3], bf16[4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    total_bytes: int
    by_type: Dict[str, int]
    max_single_op_bytes: int          # largest burst (the CDP balance metric)
    op_counts: Dict[str, int]
    # largest single op per collective type; lets callers look at the
    # gradient-merge burst (all-reduce / collective-permute / reduce-scatter)
    # in isolation from e.g. param all-gathers
    max_by_type: Dict[str, int] = dataclasses.field(default_factory=dict)

    def max_grad_merge_bytes(self) -> int:
        return max(self.max_by_type.get(t, 0) for t in
                   ("all-reduce", "reduce-scatter", "collective-permute"))


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        s = line.strip()
        # computation headers can have nested tuple parens and /*index=N*/
        # comments in the signature; exclude op-assignment lines instead
        m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->\s*.*\{\s*$", s)
        is_op = re.match(r"(?:ROOT\s+)?%[\w\.\-]+\s*=", s)
        if m and not is_op:
            cur = m.group(1)
            comps[cur] = []
            continue
        if s == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(s)
    return comps


_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')


def _while_trip(line: str, comps, cond_name: Optional[str]) -> int:
    m = _TRIP_RE.search(line)
    if m:
        return int(m.group(1))
    return _cond_trip_count(comps.get(cond_name, [])) if cond_name else 1


def _cond_trip_count(cond_lines: List[str]) -> int:
    best = 1
    for ln in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", ln):
            best = max(best, int(m.group(1)))
    return best


def parse_collectives(hlo: str) -> CollectiveStats:
    comps = _split_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line.strip())
        if m:
            entry = m.group(1)
            break
    if entry is None:       # fall back: treat whole text as one computation
        comps = {"main": hlo.splitlines()}
        entry = "main"

    by_type: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    op_counts: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    max_by_type: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    max_single = 0

    def comp_bytes(name: str, mult: int, seen) -> int:
        nonlocal max_single
        if name not in comps or name in seen:
            return 0
        seen = seen | {name}
        total = 0
        for ln in comps[name]:
            mm = re.match(r"(?:ROOT\s+)?%[\w\.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(",
                          ln)  # lazy: tuple types contain /*index=N*/
            if not mm:
                continue
            shape_str, op = mm.group(1), mm.group(2)
            if op in ("all-reduce-start", "all-gather-start",
                      "collective-permute-start", "reduce-scatter-start",
                      "all-to-all-start"):
                op = op[:-6]
            if op in _COLLECTIVES:
                b = _shape_bytes(shape_str)
                by_type[op] += b * mult
                op_counts[op] += mult
                total += b * mult
                max_single = max(max_single, b)
                max_by_type[op] = max(max_by_type[op], b)
            elif op == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", ln)
                mc = re.search(r"condition=%?([\w\.\-]+)", ln)
                if mb:
                    trip = _while_trip(ln, comps, mc.group(1) if mc else None)
                    total += comp_bytes(mb.group(1), mult * trip, seen)
            elif op in ("call", "conditional", "custom-call", "fusion"):
                for mc in re.finditer(r"(?:to_apply=|calls=)%?([\w\.\-]+)", ln):
                    total += comp_bytes(mc.group(1), mult, seen)
        return total

    total = comp_bytes(entry, 1, frozenset())
    return CollectiveStats(total_bytes=total, by_type=by_type,
                           max_single_op_bytes=max_single,
                           op_counts=op_counts, max_by_type=max_by_type)


@dataclasses.dataclass
class Roofline:
    flops: float                  # per-device HLO flops
    bytes_accessed: float         # per-device HLO bytes
    collective_bytes: float       # per-device collective bytes
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float            # 6*N*D useful flops per device
    useful_ratio: float
    collectives: CollectiveStats


def analyze(compiled, *, chips: int, model_flops_global: float) -> Roofline:
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    flops_once = float(ca.get("flops", 0.0))
    bytes_once = float(ca.get("bytes accessed", 0.0))
    # cost_analysis counts while (scan) bodies once; the parsed dot-FLOPs
    # carry loop trip counts. Bytes are scaled by the same loop factor
    # (scan-dominated programs: loop-body bytes scale like loop-body flops).
    flops = max(flops_once, parse_dot_flops(hlo))
    loop_factor = flops / flops_once if flops_once else 1.0
    bytes_acc = bytes_once * min(loop_factor, 128.0)
    stats = parse_collectives(hlo)
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    coll_s = stats.total_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops_global / chips
    return Roofline(flops=flops, bytes_accessed=bytes_acc,
                    collective_bytes=float(stats.total_bytes),
                    compute_s=compute_s, memory_s=memory_s,
                    collective_s=coll_s, bottleneck=bottleneck,
                    model_flops=mf,
                    useful_ratio=(mf / flops if flops else 0.0),
                    collectives=stats)


_DOT_RE = re.compile(
    r"=\s*(\S+?)\s+dot\(([^)]*)\).*?lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _dims(dims_str):
    return [int(d) for d in dims_str.split(",") if d]


def parse_dot_flops(hlo: str) -> float:
    """Sum matmul FLOPs from the HLO text, multiplying ops inside ``while``
    bodies by the loop trip count. ``cost_analysis()`` counts a scan body
    ONCE, under-reporting a 61-layer model by ~61x — this parse is the
    per-device compute number the roofline needs."""
    comps = _split_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line.strip())
        if m:
            entry = m.group(1)
            break
    if entry is None:
        comps = {"main": hlo.splitlines()}
        entry = "main"

    def comp_flops(name, mult, seen):
        if name not in comps or name in seen:
            return 0.0
        seen = seen | {name}
        # symbol table: op name -> result type string (for operand lookups)
        symbols = {}
        for ln in comps[name]:
            ms = re.match(r"(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\S+)", ln)
            if ms:
                symbols[ms.group(1)] = ms.group(2)
        total = 0.0
        for ln in comps[name]:
            mm = re.match(r"(?:ROOT\s+)?%[\w\.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(",
                          ln)  # lazy: tuple types contain /*index=N*/
            if not mm:
                continue
            op = mm.group(2)
            if op == "dot":
                md = _DOT_RE.search(ln)
                if not md:
                    continue
                res_elems = 1
                rm = _OPERAND_SHAPE_RE.search(md.group(1))
                if rm:
                    for d in _dims(rm.group(2)):
                        res_elems *= d
                # contraction size: look the lhs operand's shape up in the
                # computation-local symbol table
                k = 1
                lhs_name = re.match(r"\s*%([\w\.\-]+)", md.group(2))
                lhs_type = symbols.get(lhs_name.group(1), "") if lhs_name else ""
                sm = _OPERAND_SHAPE_RE.search(lhs_type)
                if sm:
                    lhs_dims = _dims(sm.group(2))
                    for ci in _dims(md.group(3)):
                        if ci < len(lhs_dims):
                            k *= lhs_dims[ci]
                total += 2.0 * res_elems * k * mult
            elif op in ("fusion", "call", "conditional"):
                for mc in re.finditer(r"calls=%?([\w\.\-]+)", ln):
                    total += comp_flops(mc.group(1), mult, seen)
                for mc in re.finditer(r"to_apply=%?([\w\.\-]+)", ln):
                    total += comp_flops(mc.group(1), mult, seen)
            elif op == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", ln)
                mc = re.search(r"condition=%?([\w\.\-]+)", ln)
                if mb:
                    trip = _while_trip(ln, comps, mc.group(1) if mc else None)
                    total += comp_flops(mb.group(1), mult * trip, seen)
        return total

    return comp_flops(entry, 1, frozenset())


def largest_ops(hlo: str, top: int = 25):
    """Largest result shapes in the optimized HLO — the usual suspects when
    memory_analysis reports an unexpected peak. Returns [(bytes, op line)]."""
    out = []
    for line in hlo.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT\s+)?%[\w\.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", s)
        if not m:
            continue
        b = _shape_bytes(m.group(1))
        if b > (64 << 20):
            out.append((b, s[:160]))
    out.sort(key=lambda t: -t[0])
    return out[:top]


def model_flops_for(cfg, shape, param_count_active: int) -> float:
    """6*N*D for training; 2*N*D for inference forward (per step)."""
    tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * param_count_active * tokens


def as_dict(r: Roofline) -> Dict:
    return {
        "flops": r.flops, "bytes_accessed": r.bytes_accessed,
        "collective_bytes": r.collective_bytes,
        "compute_s": r.compute_s, "memory_s": r.memory_s,
        "collective_s": r.collective_s, "bottleneck": r.bottleneck,
        "model_flops": r.model_flops, "useful_ratio": r.useful_ratio,
        "coll_by_type": r.collectives.by_type,
        "coll_op_counts": r.collectives.op_counts,
        "coll_max_burst": r.collectives.max_single_op_bytes,
    }
