import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""§Perf hillclimb runner: evaluate named optimisation variants of one
(arch x shape) pair and report the roofline-term deltas vs the
paper-faithful baseline.

    PYTHONPATH=src python -m repro.launch.perf --arch qwen2.5-14b \
        --shape train_4k --variants baseline,zero1,zero1_bf16,seqpar,combo
"""
import argparse
import json
import sys
import traceback

VARIANTS = {
    # train-step variants
    "baseline": {},
    "zero1": dict(zero1_ring=True),
    "zero1_bf16": dict(zero1_ring=True, grad_comm_dtype="bfloat16"),
    "seqpar": dict(seq_parallel=True),
    "combo": dict(zero1_ring=True, grad_comm_dtype="bfloat16",
                  seq_parallel=True),
    # decode-step variants
    "donate": dict(donate_cache=True),
    "cache_tp": dict(cache_model_shard=True),
    "serve_combo": dict(donate_cache=True, cache_model_shard=True),
    # f32 emulation (structurally clean CPU numbers; halve bytes for bf16)
    "f32_emu": dict(force_dtype="float32"),
    "f32_serve_combo": dict(force_dtype="float32", donate_cache=True,
                            cache_model_shard=True),
    "f32_combo": dict(force_dtype="float32", zero1_ring=True,
                      grad_comm_dtype="bfloat16", seq_parallel=True),
    "f32_zero1": dict(force_dtype="float32", zero1_ring=True),
    "f32_seqpar": dict(force_dtype="float32", seq_parallel=True),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--rule", default="cdp_v2")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    from repro.launch.dryrun import lower_pair

    records = []
    base = None
    for name in args.variants.split(","):
        kw = VARIANTS[name]
        try:
            rec = lower_pair(args.arch, args.shape, multi_pod=args.multi_pod,
                             rule=args.rule, extra={"variant": name}, **kw)
            rl = rec["roofline"]
            bpd = rec["bytes_per_device"]
            if name == "baseline":
                base = rec
            line = (f"[{name:12s}] compute={rl['compute_s']*1e3:8.2f}ms "
                    f"memory={rl['memory_s']*1e3:8.2f}ms "
                    f"collective={rl['collective_s']*1e3:8.2f}ms "
                    f"peak={bpd['peak_est']/2**30:7.2f}GiB "
                    f"(corr {bpd['peak_tpu_corrected']/2**30:7.2f}) "
                    f"burst={rl['coll_max_burst']/2**20:6.1f}MiB")
            if base is not None and name != "baseline":
                b = base["roofline"]
                dom = b["bottleneck"]
                key = {"compute": "compute_s", "memory": "memory_s",
                       "collective": "collective_s"}[dom]
                delta = (rl[key] - b[key]) / max(b[key], 1e-12) * 100
                line += f"  [{dom} {delta:+.1f}%]"
            print(line, flush=True)
            records.append(rec)
        except Exception as e:
            traceback.print_exc()
            print(f"[{name}] FAILED: {e}", flush=True)
            records.append({"variant": name, "ok": False,
                            "error": str(e)[:300]})
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
