"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --reduced --rule cdp_v2 --steps 100 --batch 8 --seq 128 \
        --mesh-data 2 --mesh-model 2 [--host-devices 4] [--ckpt-dir ckpts/]

On the CPU container use --reduced + --host-devices; on a real TPU slice the
same flags drive the production mesh (mesh sizes = the slice topology).
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--rule", default="cdp_v2",
                    choices=["dp", "cdp_v1", "cdp_v2", "cdp_random"])
    ap.add_argument("--attn-backend", default=None,
                    choices=["jnp", "pallas"],
                    help="train/prefill attention contraction (default: the "
                         "arch config's attn_backend; pallas = fused "
                         "fwd+bwd kernels, interpreter mode off-TPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--momentum", type=float, default=0.9)
    ap.add_argument("--weight-decay", type=float, default=1e-4)
    ap.add_argument("--mesh-data", type=int, default=2)
    ap.add_argument("--mesh-model", type=int, default=2)
    ap.add_argument("--mesh-pod", type=int, default=0)
    ap.add_argument("--host-devices", type=int, default=0,
                    help="force N host CPU devices (CPU container only)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   f" --xla_force_host_platform_device_count={args.host_devices}").strip()

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import checkpoint as ckpt
    from repro.configs import get_config, get_reduced
    from repro.core.trainer import TrainerConfig, init_state, jit_train_step
    from repro.data import ShardedLoader, lm_batch_iterator, make_lm_data
    from repro.data.synthetic import synthetic_batch
    from repro.launch.mesh import make_host_mesh
    from repro.models import init_params
    from repro.optim import sgd_momentum, cosine_warmup

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.attn_backend:
        cfg = cfg.with_(attn_backend=args.attn_backend)
    mesh = make_host_mesh(args.mesh_data, args.mesh_model, args.mesh_pod)
    print(f"mesh: {dict(mesh.shape)}  arch: {cfg.name}  rule: {args.rule}")

    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.2f}M")

    opt = sgd_momentum(args.momentum, args.weight_decay)
    trainer = TrainerConfig(
        rule=args.rule, pod_axis="pod" if args.mesh_pod else None,
        lr_schedule=cosine_warmup(args.lr, args.steps // 10, args.steps))
    state = init_state(cfg, trainer, params, opt)

    tokens = make_lm_data(cfg.vocab_size, 200_000, seed=args.seed)
    host_it = lm_batch_iterator(tokens, args.batch, args.seq, seed=args.seed)

    def to_batch(hb):
        b = {"tokens": jnp.asarray(hb["tokens"]),
             "targets": jnp.asarray(hb["targets"])}
        proto = synthetic_batch(cfg, type("S", (), {
            "global_batch": args.batch, "seq_len": args.seq})())
        for k in ("patches", "frames"):
            if k in proto:
                b[k] = proto[k]
        return b

    batch0 = to_batch(next(host_it))
    jitted, ssh, bsh = jit_train_step(cfg, trainer, mesh, opt, state, batch0)

    start_step = 0
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        state, start_step = ckpt.restore(args.ckpt_dir, state)
        print(f"restored step {start_step}")

    loader = ShardedLoader((to_batch(b) for b in host_it), bsh)
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = next(loader)
        state, metrics = jitted(state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            print(f"step {step:5d}  loss {loss:.4f}  "
                  f"lr {float(metrics['lr']):.4f}  "
                  f"{(time.time()-t0):.1f}s", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, step + 1, state)
    loader.close()
    print("done.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
