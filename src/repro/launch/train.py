"""Training launcher — a thin argparse shim over ``repro.engine.TrainEngine``.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --reduced --plan cdp_v2 --steps 100 --batch 8 --seq 128 \
        --mesh-data 2 --mesh-model 2 [--host-devices 4] [--ckpt-dir ckpts/] \
        [--kernels pallas | --kernels decode_attn=pallas,ssm_scan=pallas]

``--plan`` selects the parallelism strategy from the ``repro.parallel``
registry (dp | cdp_v1 | cdp_v2 | cdp_random | zero1_ring | zero_cdp);
``--rule`` survives as a deprecated alias for the plan of the same name,
exactly as ``--attn-backend`` aliases ``--kernels``.

On the CPU container use --reduced (+ --host-devices, auto-defaulted to the
mesh size when the host platform is the default backend); on a real TPU
slice the same flags drive the production mesh (mesh sizes = the slice
topology; the host-device flag only multiplies CPU devices and is inert).
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None):
    from repro.parallel import available_plans, plan_help

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--plan", default=None, choices=available_plans(),
                    help="parallelism strategy (repro.parallel registry). "
                         + plan_help())
    ap.add_argument("--rule", default=None,
                    choices=["dp", "cdp_v1", "cdp_v2", "cdp_random"],
                    help="DEPRECATED alias: selects the plan of the same "
                         "name (use --plan)")
    ap.add_argument("--kernels", default=None,
                    help="per-op kernel backends: one backend for all ops "
                         "('pallas') or a comma list of op=backend pairs "
                         "('decode_attn=pallas,ssm_scan=jnp'); ops: "
                         "train_attn, prefill_attn, decode_attn, ssm_scan")
    ap.add_argument("--attn-backend", default=None,
                    choices=["jnp", "pallas"],
                    help="DEPRECATED alias: sets train_attn+prefill_attn in "
                         "the kernel registry")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--momentum", type=float, default=0.9)
    ap.add_argument("--weight-decay", type=float, default=1e-4)
    ap.add_argument("--mesh-data", type=int, default=2)
    ap.add_argument("--mesh-model", type=int, default=2)
    ap.add_argument("--mesh-pod", type=int, default=0)
    ap.add_argument("--host-devices", type=int, default=0,
                    help="force N host CPU devices (0 = auto: the mesh size "
                         "when >1; inert when an accelerator is the default "
                         "jax backend)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--keep-last", type=int, default=None,
                    help="retain only the newest N checkpoints (GC runs "
                         "after each successful save)")
    ap.add_argument("--resilience", default=None,
                    help="arm the resilience layer: 'on' enables the "
                         "health guard only, or a comma fault spec "
                         "('nan_loss@7,loader%%0.01,slow_step@3:0.2') for "
                         "deterministic chaos injection (sites: "
                         "loader nan_loss loss_spike slow_step "
                         "ckpt_truncate ckpt_io rank_down step_hang)")
    ap.add_argument("--elastic", action="store_true",
                    help="survive a data-rank loss: re-form the ring at N-1 "
                         "from the newest buddy snapshot (disk checkpoint "
                         "as fallback) and keep training")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="buddy-replicated host-RAM snapshot interval in "
                         "steps (0 = off; recovery then needs --ckpt-dir)")
    ap.add_argument("--watchdog-timeout", type=float, default=0.0,
                    help="per-step wall-clock deadline in seconds (0 = off); "
                         "an overrun counts as a hung collective and "
                         "triggers elastic recovery")
    ap.add_argument("--rejoin-after", type=int, default=0,
                    help="scale back up to the full mesh N steps after a "
                         "recovery (simulates the failed rank returning)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.plan and args.rule:
        ap.error("pass --plan or --rule (deprecated alias), not both")
    if args.rule:
        import warnings
        warnings.warn(f"--rule is deprecated; use --plan {args.rule}",
                      DeprecationWarning, stacklevel=2)

    from repro.engine import RunSpec
    spec = RunSpec(arch=args.arch, reduced=args.reduced,
                   kernels=args.kernels, attn_backend=args.attn_backend,
                   plan=args.plan or args.rule,
                   mesh_data=args.mesh_data, mesh_model=args.mesh_model,
                   mesh_pod=args.mesh_pod, host_devices=args.host_devices,
                   seed=args.seed)
    spec = spec.auto_host_devices()     # CPU container: default to mesh size
    spec.ensure_host_devices()          # before anything imports jax state

    from repro.engine import TrainEngine
    engine = TrainEngine(spec, steps=args.steps,
                         batch=args.batch, seq=args.seq, lr=args.lr,
                         momentum=args.momentum,
                         weight_decay=args.weight_decay,
                         ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                         keep_last=args.keep_last,
                         resilience=args.resilience,
                         elastic=args.elastic,
                         snapshot_every=args.snapshot_every,
                         watchdog_timeout=args.watchdog_timeout,
                         rejoin_after=args.rejoin_after,
                         log_every=args.log_every)
    engine.run()
    print("done.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
