"""Crash-isolated dry-run grid driver: one subprocess per (arch, shape,
mesh) so an XLA hard-abort cannot take down the whole grid; results are
merged incrementally into the output JSON.

    PYTHONPATH=src python -m repro.launch.run_grid \
        --out benchmarks/artifacts/dryrun_grid.json [--multi-pod] [--resume]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--resume", action="store_true",
                    help="skip pairs already present in --out")
    ap.add_argument("--rule", default="cdp_v2")
    ap.add_argument("--timeout", type=int, default=1800)
    ap.add_argument("--archs", default=None)
    ap.add_argument("--shapes", default=None)
    args = ap.parse_args(argv)

    from repro.configs import ARCHS, INPUT_SHAPES   # no jax init needed

    archs = args.archs.split(",") if args.archs else list(ARCHS)
    shapes = args.shapes.split(",") if args.shapes else list(INPUT_SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    records = []
    done = set()
    if args.resume and os.path.exists(args.out):
        with open(args.out) as f:
            records = json.load(f)
        done = {(r["arch"], r["shape"], r["mesh"]) for r in records
                if r.get("ok")}

    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                if (arch, shape, mesh_name) in done:
                    continue
                tmp = args.out + f".{arch}.{shape}.{mesh_name}.tmp"
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--rule", args.rule,
                       "--out", tmp]
                if mp:
                    cmd.append("--multi-pod")
                t0 = time.time()
                try:
                    res = subprocess.run(cmd, capture_output=True, text=True,
                                         timeout=args.timeout, env=env)
                    if os.path.exists(tmp):
                        with open(tmp) as f:
                            recs = json.load(f)
                        os.remove(tmp)
                    else:
                        tail = (res.stderr or res.stdout or "")[-400:]
                        recs = [{"arch": arch, "shape": shape,
                                 "mesh": mesh_name, "ok": False,
                                 "error": f"subprocess rc={res.returncode}: "
                                          f"{tail}"}]
                except subprocess.TimeoutExpired:
                    recs = [{"arch": arch, "shape": shape, "mesh": mesh_name,
                             "ok": False, "error": "timeout"}]
                # replace any stale record for this triple
                records = [r for r in records
                           if (r["arch"], r["shape"], r["mesh"]) !=
                              (arch, shape, mesh_name)] + recs
                with open(args.out, "w") as f:
                    json.dump(records, f, indent=1)
                r = recs[0]
                status = "OK  " if r.get("ok") else "FAIL"
                extra = ""
                if r.get("ok"):
                    rl = r["roofline"]
                    extra = (f"bottleneck={rl['bottleneck']} "
                             f"peak={r['bytes_per_device']['peak_est']/2**30:.1f}GiB")
                else:
                    extra = r.get("error", "")[:120].replace("\n", " ")
                print(f"[{status}] {arch} x {shape} x {mesh_name} "
                      f"({time.time()-t0:.0f}s) {extra}", flush=True)

    n_ok = sum(1 for r in records if r.get("ok"))
    print(f"grid: {n_ok}/{len(records)} ok -> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
