import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination against ShapeDtypeStruct inputs — proving the sharding config is
coherent — and record memory/cost/roofline terms.

MUST be run as a module entry point (the XLA_FLAGS line above executes before
any jax import):

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-14b \
        --shape train_4k [--multi-pod] [--rule cdp_v2] [--out out.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json
"""
import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, INPUT_SHAPES, get_config
from repro.core.schedule import RULE_CDP_V2, RULE_DP
from repro.core.trainer import (TrainerConfig, init_state, make_train_step)
from repro.launch import roofline as rl
from repro.launch.inputs import (adapt_config_for_shape, batch_specs,
                                 decode_specs, input_specs, params_specs)
from repro.launch.mesh import make_production_mesh
from repro.models import model as model_mod
from repro.models.model import analytic_param_count
from repro.optim import sgd_momentum
from repro.sharding import specs as sh


def _per_device_bytes(tree, mesh, bf16_only: bool = False) -> int:
    """Analytic per-device bytes of a (ShapeDtypeStruct) tree under the
    standard param shardings."""
    psh = sh.param_pspecs(tree, mesh, "model", None)
    total = 0
    for leaf, spec in zip(jax.tree.leaves(tree),
                          jax.tree.leaves(sh.param_pspecs(tree, mesh, "model", None),
                                          is_leaf=lambda x: isinstance(x, type(jax.sharding.PartitionSpec())))):
        if bf16_only and leaf.dtype != jnp.bfloat16:
            continue
        div = 1
        for ax in spec:
            if ax is None:
                continue
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                div *= mesh.shape[a]
        total += int(np.prod(leaf.shape)) * leaf.dtype.itemsize // div
    return total


def _cache_model_shard(cache, csh, mesh):
    """Add model-axis sharding on the trailing head dim of cache leaves
    where divisible (kv caches: [..., KV, hd] or MLA latent [..., r])."""
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P
    msz = mesh.shape["model"]

    def one(leaf, nsh):
        spec = list(nsh.spec) + [None] * (leaf.ndim - len(nsh.spec))
        if leaf.ndim >= 3 and leaf.shape[-1] % msz == 0 and spec[-1] is None:
            spec[-1] = "model"
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(one, cache, csh)


def _eval_shape_state(cfg, trainer, opt):
    def build():
        params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
        return init_state(cfg, trainer, params, opt)
    return jax.eval_shape(build)


def lower_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
               rule: str = RULE_CDP_V2, remat: bool = True,
               extra: Dict[str, Any] | None = None,
               # ---- §Perf variant knobs (baseline = all defaults) ----
               zero1_ring: bool = False, seq_parallel: bool = False,
               grad_comm_dtype: str = "float32",
               donate_cache: bool = False,
               cache_model_shard: bool = False,
               force_dtype: str = None) -> Dict[str, Any]:
    """Lower + compile one (arch, shape, mesh). Returns the record dict.

    ``force_dtype='float32'`` compiles the model in f32: XLA:CPU then does no
    bf16->f32 operand promotion, giving structurally clean memory/collective
    numbers for the TPU target (report byte quantities / 2 for bf16)."""
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    shape = INPUT_SHAPES[shape_name]
    cfg = adapt_config_for_shape(get_config(arch), shape)
    if force_dtype:
        cfg = cfg.with_(dtype=force_dtype)
    daxes = ("pod", "data") if multi_pod else ("data",)

    # Serving paths (no CDP manual axis): if tensor parallelism alone leaves
    # more than ~10 GiB of weights per chip, additionally shard weights over
    # the data axes (weight-gathered inference) so the model fits HBM.
    def _serve_zero_axis(params):
        per_dev = _per_device_bytes(params, mesh)
        if per_dev > 10 * 2**30:
            return daxes if len(daxes) > 1 else daxes[0]
        return None

    if shape.is_decode:
        batch, cache = decode_specs(cfg, shape)
        params = params_specs(cfg)
        psh = sh.param_shardings(params, mesh, "model", _serve_zero_axis(params))
        bsh = sh.batch_sharding(batch, mesh, daxes)
        csh = sh.cache_pspecs(cache, mesh, daxes, "model",
                              batch=shape.global_batch)
        if cache_model_shard:
            # also shard the head/state dim of KV caches over the model axis
            csh = _cache_model_shard(cache, csh, mesh)

        def serve_step(params, batch, cache):
            return model_mod.decode_step(cfg, params, batch, cache)

        jitted = jax.jit(serve_step, in_shardings=(psh, bsh, csh),
                         out_shardings=(None, csh),
                         donate_argnums=(2,) if donate_cache else ())
        lowered = jitted.lower(params, batch, cache)
    elif shape.kind == "prefill":
        batch = batch_specs(cfg, shape, with_targets=False)
        params = params_specs(cfg)
        psh = sh.param_shardings(params, mesh, "model", _serve_zero_axis(params))
        bsh = sh.batch_sharding(batch, mesh, daxes)

        def prefill_step(params, batch):
            return model_mod.prefill_logits(cfg, params, batch)

        jitted = jax.jit(prefill_step, in_shardings=(psh, bsh))
        lowered = jitted.lower(params, batch)
    else:
        opt = sgd_momentum(0.9, state_dtype=jnp.bfloat16
                           if analytic_param_count(cfg) > 5e10 else jnp.float32)
        from repro.parallel import plan_from_legacy_flags
        trainer = TrainerConfig(
            plan=plan_from_legacy_flags(rule=rule, zero1_ring=zero1_ring),
            pod_axis="pod" if multi_pod else None,
            lr_schedule=lambda s: 1e-2,
            seq_parallel=seq_parallel,
            grad_comm_dtype=grad_comm_dtype)
        step_fn, state_sh_fn, batch_sh_fn = make_train_step(
            cfg, trainer, mesh, opt)
        state = _eval_shape_state(cfg, trainer, opt)
        batch = batch_specs(cfg, shape, with_targets=True)
        ssh = state_sh_fn(state, mesh)
        bsh = batch_sh_fn(batch)
        jitted = jax.jit(step_fn, in_shardings=(ssh, bsh),
                         out_shardings=(ssh, None), donate_argnums=(0,))
        lowered = jitted.lower(state, batch)

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mf = rl.model_flops_for(cfg, shape,
                            analytic_param_count(cfg, active_only=True))
    roof = rl.analyze(compiled, chips=chips, model_flops_global=mf)

    # XLA:CPU promotes bf16 matmul operands to f32 (native bf16 on the TPU
    # target): estimate that inflation so the recorded peak can be corrected
    bf16_param_dev = _per_device_bytes(params_specs(cfg), mesh, bf16_only=True)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "rule": rule if shape.kind == "train" else "-",
        "ok": True,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "bytes_per_device": {
            "arguments": mem.argument_size_in_bytes,
            "output": mem.output_size_in_bytes,
            "temp": mem.temp_size_in_bytes,
            "alias": mem.alias_size_in_bytes,
            "peak_est": mem.argument_size_in_bytes + mem.output_size_in_bytes
                        + mem.temp_size_in_bytes - mem.alias_size_in_bytes,
            "bf16_params": bf16_param_dev,
            # TPU-corrected: remove the f32 copies of bf16 weights that the
            # CPU backend materialises (2x the bf16 bytes per copy)
            "peak_tpu_corrected": max(
                0, mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes
                - 2 * bf16_param_dev),
        },
        "roofline": rl.as_dict(roof),
    }
    if extra:
        rec.update(extra)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS))
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--rule", default=RULE_CDP_V2)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    pairs = []
    if args.all:
        for a in ARCHS:
            for s in INPUT_SHAPES:
                pairs.append((a, s))
    else:
        pairs = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    records = []
    for arch, shape in pairs:
        for mp in meshes:
            tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
            try:
                rec = lower_pair(arch, shape, multi_pod=mp, rule=args.rule)
                r = rec["roofline"]
                print(f"[OK]   {tag}: compute={r['compute_s']*1e3:.2f}ms "
                      f"memory={r['memory_s']*1e3:.2f}ms "
                      f"collective={r['collective_s']*1e3:.2f}ms "
                      f"bottleneck={r['bottleneck']} "
                      f"peak={rec['bytes_per_device']['peak_est']/2**30:.2f}GiB "
                      f"(compile {rec['compile_s']}s)", flush=True)
            except Exception as e:
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16", "ok": False,
                       "error": f"{type(e).__name__}: {e}"}
                print(f"[FAIL] {tag}: {rec['error']}", flush=True)
                traceback.print_exc()
            records.append(rec)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {len(records)} records to {args.out}")
    return 0 if all(r.get("ok") for r in records) else 1


if __name__ == "__main__":
    sys.exit(main())
