"""Gradient synchronisation: the paper's balanced point-to-point ring vs the
baseline all-reduce burst.

``ring_all_reduce``: bandwidth-optimal ring (reduce-scatter + all-gather as
2*(N-1) neighbour ``lax.ppermute`` steps, [20] Patarasuk & Yuan) — this is
exactly the communication schedule CDP spreads over the training step
(Fig. 1c / Sec. 4.2): each tick one point-to-point chunk per worker, never a
collective burst. In the lowered HLO these are ``collective-permute`` ops of
size P/N, whereas the DP baseline emits a single ``all-reduce`` of size P —
the roofline analysis reads exactly this difference.

Runs inside ``jax.shard_map`` manual over the given axis.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _flatten_to_vec(tree: PyTree):
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [l.shape for l in leaves]
    dtypes = [l.dtype for l in leaves]
    sizes = [int(np.prod(s)) for s in shapes]
    vec = jnp.concatenate([l.astype(jnp.float32).reshape(-1) for l in leaves])
    return vec, (treedef, shapes, dtypes, sizes)


def _unflatten_from_vec(vec, spec):
    treedef, shapes, dtypes, sizes = spec
    out, off = [], 0
    for shape, dt, sz in zip(shapes, dtypes, sizes):
        out.append(vec[off:off + sz].reshape(shape).astype(dt))
        off += sz
    return jax.tree.unflatten(treedef, out)


def _ring_perm(n: int):
    return [(j, (j + 1) % n) for j in range(n)]


def ring_all_reduce_vec(vec, axis_name: str, n: int):
    """Ring all-reduce of a flat f32 vector over a manual mesh axis.

    The 2*(n-1) ppermute steps are UNROLLED (n is static) so each hop is a
    distinct ``collective-permute`` HLO op: the scheduler can overlap them
    with compute, and the roofline pass can count their bytes statically —
    this chain *is* the paper's balanced point-to-point timeline.
    """
    if n == 1:
        return vec
    r = jax.lax.axis_index(axis_name)
    size = vec.shape[0]
    chunk = -(-size // n)
    pad = chunk * n - size
    x = jnp.pad(vec, (0, pad))
    perm = _ring_perm(n)

    # --- reduce-scatter: after n-1 steps rank r holds reduced chunk (r+1)%n
    send = jax.lax.dynamic_slice_in_dim(x, r * chunk, chunk)
    for s in range(n - 1):
        send = jax.lax.ppermute(send, axis_name, perm)
        idx = (r - s - 1) % n
        send = send + jax.lax.dynamic_slice_in_dim(x, idx * chunk, chunk)
    reduced = send

    # --- all-gather ring: circulate the reduced chunks
    out = jnp.zeros_like(x)
    out = jax.lax.dynamic_update_slice_in_dim(out, reduced,
                                              ((r + 1) % n) * chunk, 0)
    send = reduced
    for s in range(n - 1):
        send = jax.lax.ppermute(send, axis_name, perm)
        idx = (r - s) % n          # owner of the chunk just received
        out = jax.lax.dynamic_update_slice_in_dim(out, send, idx * chunk, 0)
    return out[:size]


def _pick_slice_axis(shape, pspec, n: int):
    """Largest dim divisible by n that is NOT sharded (so slicing it never
    forces a GSPMD reshard of the tensor-parallel layout)."""
    best = None
    for i, d in enumerate(shape):
        sharded = pspec is not None and i < len(pspec) and pspec[i] is not None
        if d % n == 0 and d >= n and not sharded:
            if best is None or d > shape[best]:
                best = i
    return best


def ring_all_reduce_leaf(x, axis_name: str, n: int, slice_axis: int):
    """Bandwidth-optimal ring all-reduce of one array, slicing chunks along
    ``slice_axis`` (an unsharded dim) — model-axis tensor parallelism is
    preserved chunk-wise, so no resharding collectives are introduced."""
    r = jax.lax.axis_index(axis_name)
    perm = _ring_perm(n)
    c = x.shape[slice_axis] // n
    xf = x.astype(jnp.float32)

    def get_chunk(idx):
        return jax.lax.dynamic_slice_in_dim(xf, idx * c, c, axis=slice_axis)

    # reduce-scatter
    send = get_chunk(r)
    for s in range(n - 1):
        send = jax.lax.ppermute(send, axis_name, perm)
        send = send + get_chunk((r - s - 1) % n)
    # all-gather ring
    out = jnp.zeros_like(xf)
    out = jax.lax.dynamic_update_slice_in_dim(
        out, send, ((r + 1) % n) * c, axis=slice_axis)
    for s in range(n - 1):
        send = jax.lax.ppermute(send, axis_name, perm)
        out = jax.lax.dynamic_update_slice_in_dim(
            out, send, ((r - s) % n) * c, axis=slice_axis)
    return (out / n).astype(x.dtype)


def ring_all_reduce(tree: PyTree, axis_name: str, n: int,
                    pspecs: PyTree = None) -> PyTree:
    """Mean-reduce a gradient pytree over ``axis_name`` with the CDP ring.

    Large leaves ring point-to-point (2*(n-1) unrolled ppermute hops, chunk
    = leaf/n); leaves with no ring-sliceable dim (norm scales, biases — a
    negligible byte fraction) fall back to pmean.
    """
    if n == 1:
        return tree

    def one(leaf, spec):
        ax = _pick_slice_axis(leaf.shape, spec, n)
        if ax is None or leaf.size < 1024:
            # fall back to a (f32) all-reduce: bf16 all-reduce trips
            # XLA:CPU's promotion pass and loses precision anyway
            return psum_all_reduce(leaf, axis_name)
        return ring_all_reduce_leaf(leaf, axis_name, n, ax)

    if pspecs is None:
        from jax.sharding import PartitionSpec as P
        pspecs = jax.tree.map(lambda _: P(), tree)
    return jax.tree.map(one, tree, pspecs)


def psum_all_reduce(tree: PyTree, axis_name: str) -> PyTree:
    """Baseline DP collective (lowers to all-reduce HLO). Reduction in f32
    (bf16 all-reduce both loses precision and trips XLA:CPU's promotion
    pass in the 512-device dry-run)."""
    def one(x):
        if jnp.issubdtype(x.dtype, jnp.floating) and x.dtype != jnp.float32:
            return jax.lax.pmean(x.astype(jnp.float32), axis_name).astype(x.dtype)
        return jax.lax.pmean(x, axis_name)
    return jax.tree.map(one, tree)


def ring_reduce_scatter_leaf(x, axis_name: str, n: int, slice_axis: int,
                             comm_dtype=jnp.float32):
    """Ring reduce-scatter of one array along ``slice_axis``: after n-1 hops
    (+1 alignment hop) rank r holds the fully-reduced chunk r. Returns the
    local chunk (shape = x.shape with slice_axis divided by n). This is the
    first half of the CDP ring; with ZeRO-1 the second half becomes the
    *parameter* all-gather after the sharded optimizer update."""
    r = jax.lax.axis_index(axis_name)
    perm = _ring_perm(n)
    c = x.shape[slice_axis] // n
    xf = x.astype(comm_dtype)

    def get_chunk(idx):
        return jax.lax.dynamic_slice_in_dim(xf, idx * c, c, axis=slice_axis)

    send = get_chunk(r)
    for s in range(n - 1):
        send = jax.lax.ppermute(send, axis_name, perm)
        send = send + get_chunk((r - s - 1) % n)
    # rank r now holds chunk (r+1)%n; one alignment hop puts chunk r on rank r
    send = jax.lax.ppermute(send, axis_name, perm)
    return send / n


def zero1_reduce_scatter(tree: PyTree, axis_name: str, n: int,
                         pspecs: PyTree, comm_dtype=jnp.float32):
    """Per-leaf ring reduce-scatter for the ZeRO-1 optimizer path.

    Returns (chunk_tree, layout) where layout maps each leaf to its slice
    axis (or None for pmean-fallback leaves, which stay replicated)."""
    def one(leaf, spec):
        ax = _pick_slice_axis(leaf.shape, spec, n)
        if ax is None or leaf.size < 1024:
            return psum_all_reduce(leaf, axis_name), None
        return ring_reduce_scatter_leaf(leaf, axis_name, n, ax,
                                        comm_dtype), ax

    flat, treedef = jax.tree.flatten(tree)
    specs_flat = jax.tree.leaves(pspecs)
    outs = [one(l, s) for l, s in zip(flat, specs_flat)]
    chunk_tree = jax.tree.unflatten(treedef, [o[0] for o in outs])
    layout = jax.tree.unflatten(treedef, [(o[1] if o[1] is not None else -1)
                                          for o in outs])
    return chunk_tree, layout


def zero1_layout(tree: PyTree, n: int, pspecs: PyTree) -> PyTree:
    """Static slice-axis layout (leaf -> axis or -1) without any compute."""
    def one(leaf, spec):
        ax = _pick_slice_axis(leaf.shape, spec, n)
        return -1 if (ax is None or leaf.size < 1024) else ax
    return jax.tree.map(one, tree, pspecs)


def sync_gradients(sync: str, tree: PyTree, axis_name: str, n: int,
                   pspecs: PyTree = None,
                   comm_dtype=jnp.float32) -> PyTree:
    """Gradient-merge dispatch for ``repro.parallel`` plans.

    ``psum`` -> the baseline all-reduce burst; ``ring`` -> the CDP balanced
    point-to-point ring; ``zero1_ring`` -> per-leaf ring reduce-scatter
    (returns data-sharded chunks whose layout ``zero1_layout`` describes).
    ``stream`` never reaches here: ZeRO-CDP's gradient merge is the
    transposed parameter ring itself (repro.parallel.zero_cdp).
    """
    if sync == "psum":
        return psum_all_reduce(tree, axis_name)
    if sync == "ring":
        return ring_all_reduce(tree, axis_name, n, pspecs)
    if sync == "zero1_ring":
        chunks, _ = zero1_reduce_scatter(tree, axis_name, n, pspecs,
                                         comm_dtype=comm_dtype)
        return chunks
    raise ValueError(f"no gradient-sync implementation for {sync!r}")


def reduce_scatter_ring(vec, axis_name: str, n: int):
    """Ring reduce-scatter only: rank r returns reduced chunk (r+1)%n.
    Used by the ZeRO-CDP optimizer path (each rank updates only its shard)."""
    if n == 1:
        return vec
    r = jax.lax.axis_index(axis_name)
    size = vec.shape[0]
    chunk = -(-size // n)
    pad = chunk * n - size
    x = jnp.pad(vec, (0, pad))
    perm = _ring_perm(n)
    send = jax.lax.dynamic_slice_in_dim(x, r * chunk, chunk)
    for s in range(n - 1):
        send = jax.lax.ppermute(send, axis_name, perm)
        idx = (r - s - 1) % n
        send = send + jax.lax.dynamic_slice_in_dim(x, idx * chunk, chunk)
    return send
