"""Eq. (CDP) parameter-selection rules applied to real parameter pytrees.

``select_params`` implements theta_hat_{i,t}^j = u_{i,j}(theta_t^j,
theta_{t-1}^j) leaf-wise: each leaf carries a stage-id array (from
``repro.models.model.param_stage_ids``) and micro-batch i's freshness
threshold decides, per stage, whether the fresh or the previous parameters
are used. Works with a traced (device-dependent) micro-batch index, which is
how the SPMD trainer gives every data-parallel rank its own theta_hat.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.schedule import (ALL_RULES, RULE_CDP_RANDOM, RULE_CDP_V1,
                                 RULE_CDP_V2, RULE_DP, RULES, fresh_threshold)

PyTree = Any


def fresh_threshold_traced(rule: str, microbatch, n: int, step=None):
    """Like schedule.fresh_threshold but microbatch may be a traced int.

    ``cdp_random`` (beyond-paper, the paper's stated future work): a per-step
    random threshold uniform in [thr_v2, n] — i.e. anywhere between the
    freshest schedule the cyclic execution permits (v2) and fully stale (v1);
    every realisation keeps the delay <= 1 step. Deterministic in (step, i).
    """
    if rule == RULE_DP:
        return jnp.int32(0)
    if rule == RULE_CDP_V1:
        return jnp.int32(n)
    if rule == RULE_CDP_V2:
        return jnp.int32(n - 1) - jnp.asarray(microbatch, jnp.int32)
    if rule == RULE_CDP_RANDOM:
        lo = jnp.int32(n - 1) - jnp.asarray(microbatch, jnp.int32)
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(17),
                               jnp.asarray(step if step is not None else 0,
                                           jnp.int32)),
            jnp.asarray(microbatch, jnp.int32))
        return lo + jax.random.randint(key, (), 0, jnp.int32(n) - lo + 1)
    raise ValueError(rule)


def select_params(params_new: PyTree, params_prev: PyTree,
                  stage_ids: PyTree, threshold) -> PyTree:
    """theta_hat: leaf-wise where(stage >= threshold, new, old)."""
    def sel(new, old, sid):
        pred = sid >= threshold
        return jnp.where(pred, new, old)
    return jax.tree.map(sel, params_new, params_prev, stage_ids)


def needs_prev_params(rule: str) -> bool:
    return rule in (RULE_CDP_V1, RULE_CDP_V2, RULE_CDP_RANDOM)


def validate_rule(rule: str) -> str:
    if rule not in ALL_RULES:
        raise ValueError(f"unknown update rule {rule!r}; one of {ALL_RULES}")
    return rule
