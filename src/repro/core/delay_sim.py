"""Single-process simulator of the three update rules (paper Sec. 5 protocol).

The paper's own experiments *simulate* the CDP delays ("we simulate our
delayed activations for DP, CDP-v1 and CDP-v2"); this module is that
simulator: per training step it computes the N micro-batch gradients, each at
its own theta_hat (vmapped over the freshness threshold), averages them, and
applies SGD-with-momentum. Used by the convergence experiments
(benchmarks/table2_convergence.py, fig3_loss.py).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.core.schedule import RULE_DP, fresh_threshold
from repro.core.update_rules import needs_prev_params, select_params

PyTree = Any


def make_sim_step(loss_fn: Callable, stage_ids: PyTree, rule: str,
                  n_stages: int, opt, lr_fn: Callable):
    """loss_fn(params, microbatch) -> scalar.

    Returns step(state, batch) where batch leaves have leading dim
    [n_stages, ...] (one micro-batch per stage index).
    """
    thresholds = jnp.asarray(
        [fresh_threshold(rule, i, n_stages) for i in range(n_stages)],
        jnp.int32)
    use_prev = needs_prev_params(rule)

    def one_grad(params, params_prev, thr, microbatch):
        theta_hat = select_params(params, params_prev, stage_ids, thr)
        loss, g = jax.value_and_grad(loss_fn)(theta_hat, microbatch)
        return loss, g

    @jax.jit
    def step(state, batch):
        params = state["params"]
        prev = state["params_prev"] if use_prev else params
        losses, grads = jax.vmap(
            lambda thr, mb: one_grad(params, prev, thr, mb))(thresholds, batch)
        gbar = jax.tree.map(lambda g: g.mean(0), grads)
        lr = lr_fn(state["step"])
        new_params, new_opt = opt.update(gbar, state["opt"], params, lr)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        if use_prev:
            new_state["params_prev"] = params
        return new_state, losses.mean()

    return step


def init_sim_state(params: PyTree, rule: str, opt) -> Dict:
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    if needs_prev_params(rule):
        state["params_prev"] = jax.tree.map(jnp.copy, params)
    return state
