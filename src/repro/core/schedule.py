"""The cyclic schedule (paper Fig. 1) and the Table-1 cost formulas.

Pure-python/numpy — this module is the *specification* of CDP: who computes
what at every time step, which parameters each micro-batch may use (the
``u_{i,j}`` rule), when gradients are communicated, and the resulting memory
and communication costs. The distributed trainer and the analytic memory
model are both validated against it.

Conventions (matching the paper):
  * N workers == N stages == N micro-batches.
  * A training step = 2N time steps (N forward + N backward per micro-batch).
  * Worker/micro-batch i (0-indexed) is delayed by 2*i time steps.
  * At local step l in [0, 2N): l < N -> forward of stage l;
    l >= N -> backward of stage 2N-1-l.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

FORWARD = "F"
BACKWARD = "B"


@dataclasses.dataclass(frozen=True)
class Phase:
    kind: str          # "F" or "B"
    stage: int         # stage index in [0, N)
    microbatch: int    # micro-batch being processed


def local_step_phase(l: int, n: int) -> Tuple[str, int]:
    l = l % (2 * n)
    if l < n:
        return FORWARD, l
    return BACKWARD, 2 * n - 1 - l


def dp_phase(worker: int, tau: int, n: int) -> Phase:
    """Standard DP: all workers synchronous; micro-batch == worker."""
    kind, stage = local_step_phase(tau, n)
    return Phase(kind, stage, worker)


def cdp_phase(worker: int, tau: int, n: int) -> Phase:
    """CDP: worker i runs with a delay of 2*i time steps (Fig. 1b/1c).

    The micro-batch index increments every wrap of the 2N-cycle, but within
    one training step worker i always handles micro-batch i.
    """
    kind, stage = local_step_phase(tau - 2 * worker, n)
    return Phase(kind, stage, worker)


# ---------------------------------------------------------------------------
# Activation accounting (drives Fig. 4 and the Table 1 memory column)
# ---------------------------------------------------------------------------

def activations_held(worker: int, tau: int, n: int, cyclic: bool,
                     stage_bytes: Optional[np.ndarray] = None) -> float:
    """Bytes (or stage-counts if stage_bytes None) of activations retained by
    ``worker`` at the *end* of time step tau (steady state)."""
    if stage_bytes is None:
        stage_bytes = np.ones(n)
    l = (tau - 2 * worker) % (2 * n) if cyclic else tau % (2 * n)
    kind, stage = local_step_phase(l, n)
    # activations retained DURING the tick: a forward of stage s has produced
    # stages 0..s; a backward of stage s still holds 0..s (s is released at
    # the end of the tick)
    return float(stage_bytes[: stage + 1].sum())


def total_activation_timeline(n: int, cyclic: bool,
                              stage_bytes: Optional[np.ndarray] = None,
                              steps: int = None) -> np.ndarray:
    """Sum of retained activations across all N workers per time step."""
    steps = steps if steps is not None else 2 * n
    return np.array([
        sum(activations_held(w, tau, n, cyclic, stage_bytes)
            for w in range(n))
        for tau in range(2 * n, 2 * n + steps)   # steady state
    ])


def dp_peak_activations(n: int) -> float:
    """Peak total activations of DP in stage-units: N workers x N stages."""
    return float(n * n)


def cdp_total_activations(n: int) -> float:
    """CDP steady-state total in stage-units: (N+1)N/2 .. constant-ish."""
    return float(n * (n + 1) / 2)


# ---------------------------------------------------------------------------
# u_{i,j} rules (paper Sec. 3.2). 0-indexed: micro-batch i, stage j.
# ---------------------------------------------------------------------------

RULE_DP = "dp"
RULE_CDP_V1 = "cdp_v1"
RULE_CDP_V2 = "cdp_v2"
# beyond-paper (the paper's stated future work): per-step random freshness
# threshold, uniform between CDP-v2's (the freshest schedule the cyclic
# execution permits) and CDP-v1's (all stale) — delay still <= 1 everywhere
RULE_CDP_RANDOM = "cdp_random"
RULES = (RULE_DP, RULE_CDP_V1, RULE_CDP_V2)
ALL_RULES = RULES + (RULE_CDP_RANDOM,)


def fresh_threshold(rule: str, microbatch: int, n: int) -> int:
    """Stages j >= threshold use theta_t ("fresh"); below use theta_{t-1}.

    DP:      all fresh                      -> 0
    CDP-v1:  all stale                      -> n
    CDP-v2:  fresh iff j >= n - 1 - i       (paper: j >= N - i + 1, 1-indexed)
    """
    if rule == RULE_DP:
        return 0
    if rule == RULE_CDP_V1:
        return n
    if rule == RULE_CDP_V2:
        return n - 1 - microbatch
    raise ValueError(rule)


def u_matrix(rule: str, n: int) -> np.ndarray:
    """[N, N] boolean: True where micro-batch i uses fresh theta_t at stage j."""
    out = np.zeros((n, n), bool)
    for i in range(n):
        thr = fresh_threshold(rule, i, n)
        out[i, thr:] = True
    return out


def delay_matrix(rule: str, n: int) -> np.ndarray:
    """Gradient delay per (microbatch, stage): 0 = fresh, 1 = one step stale."""
    return (~u_matrix(rule, n)).astype(int)


# ---------------------------------------------------------------------------
# Communication schedule (CDP-v2, Fig. 1c): after worker i finishes the
# backward of stage j it sends that stage's gradient to worker (i+1) mod N —
# one point-to-point message per time step per active stage.
# ---------------------------------------------------------------------------

def comm_events(n: int, steps: Optional[int] = None) -> List[Dict]:
    """P2P sends per time step in steady state. Each event:
    {tau, src, dst, stage}. With CDP, at every time step exactly
    floor(N/2)..ceil(N/2) workers finish a backward micro-step."""
    steps = steps if steps is not None else 2 * n
    events = []
    for tau in range(2 * n, 2 * n + steps):
        for w in range(n):
            ph = cdp_phase(w, tau, n)
            if ph.kind == BACKWARD:
                events.append({"tau": tau - 2 * n, "src": w,
                               "dst": (w + 1) % n, "stage": ph.stage})
    return events


def ascii_timeline(n: int, ticks: int = None, cyclic: bool = True) -> str:
    """Fig. 1 as text: one row per worker, F<stage>/B<stage> per tick."""
    ticks = ticks if ticks is not None else 2 * n
    rows = [f"{'CDP' if cyclic else 'DP'} timeline, N={n} "
            f"(row=worker, col=tick)"]
    for w in range(n):
        cells = []
        for tau in range(2 * n, 2 * n + ticks):
            ph = cdp_phase(w, tau, n) if cyclic else dp_phase(w, tau, n)
            cells.append(f"{ph.kind}{ph.stage}")
        rows.append(f"w{w}: " + " ".join(f"{c:>3}" for c in cells))
    return "\n".join(rows)


def max_comm_steps_per_tick(n: int, cyclic: bool) -> str:
    """Table 1 'Max com. steps': collective all-reduce needs O(log N) steps
    between two time steps; CDP needs exactly one p2p hop."""
    return "O(1)" if cyclic else "O(log N)"


# ---------------------------------------------------------------------------
# Table 1 (theoretical costs). Symbols: Pp = parameter bytes of full model,
# Pa = activation bytes of full model for ONE sample, Pa_int = stage-boundary
# activations, B = micro-batch size, N = workers/stages.
# ---------------------------------------------------------------------------

def table1(n: int, B: int, Pp: float, Pa: float, Pa_int: float) -> Dict[str, Dict]:
    rows = {
        "single_gpu_dp": dict(act_mem=n * B * Pa, param_mem=n * Pp,
                              volume=0.0, comm_steps="-", gpus=1, rule="DP"),
        "single_gpu_cdp": dict(act_mem=(n + 1) / 2 * B * Pa,
                               param_mem=(n + 1) / 2 * Pp,
                               volume=0.0, comm_steps="-", gpus=1, rule="CDP"),
        "multi_gpu_dp": dict(act_mem=B * Pa, param_mem=Pp, volume=Pp,
                             comm_steps="O(log N)", gpus=n, rule="DP"),
        "multi_gpu_cdp": dict(act_mem=B * Pa, param_mem=Pp, volume=Pp,
                              comm_steps="O(1)", gpus=n, rule="CDP"),
        "dp_mp": dict(act_mem=B * Pa / n, param_mem=Pp / n,
                      volume=Pp + B * Pa_int, comm_steps="O(log N)",
                      gpus=n * n, rule="DP"),
        "dp_mp_cdp": dict(act_mem=B * Pa / n, param_mem=Pp / n,
                          volume=0.5 * Pp + B * Pa_int, comm_steps="O(1)",
                          gpus=n * (n + 1) // 2, rule="CDP"),
        "pp": dict(act_mem=B * Pa, param_mem=Pp / n, volume=B * Pa_int,
                   comm_steps="O(1)", gpus=n, rule="CDP"),
        "zero_dp": dict(act_mem=B * Pa, param_mem=Pp / n, volume=Pp,
                        comm_steps="O(log N)", gpus=n, rule="DP"),
        "zero_cdp": dict(act_mem=B * Pa, param_mem=Pp / n, volume=Pp,
                         comm_steps="O(1)", gpus=n, rule="CDP"),
    }
    return rows
