"""ZeRO-DP vs ZeRO-CDP (paper Sec. 4.4) as SPMD programs.

Baseline ZeRO-DP: parameters stage-sharded over the data axis; every stage
execution starts with a *broadcast/all-gather* of that stage's parameters to
all ranks (``lax.all_gather``).

ZeRO-CDP: the same stage-sharded parameters, but the model states travel the
ring **point-to-point** (``lax.ppermute``), one hop per time step, while each
rank runs the *cyclic* schedule on its own micro-batch: at inner tick t, rank
r computes stage (t - r) mod N. Stage j's parameters start at rank (-j) mod N
and move +1 each tick, so they are exactly where they are needed — the
paper's "model states are communicated to a single GPU at the next time
step", with no collective broadcast. The backward pass is obtained by
``jax.grad`` through the ppermute chain (transposed automatically), giving
the reverse point-to-point schedule.

Implemented here for a homogeneous stack of stages (stage = contiguous
layer group folded into one callable) — the minimal, schedule-exact
SPECIFICATION of the streaming pattern, kept as the reference the tests
check hop-by-hop. The production path — any registered architecture,
stages partitioned from real parameter trees via ``models.model``'s stage
ids, driven by ``--plan zero_cdp`` through ``RunSpec``/``TrainEngine`` —
lives in ``repro.parallel.zero_cdp``.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


def _ring_perm(n: int):
    return [(j, (j + 1) % n) for j in range(n)]


def initial_stage_for_rank(rank: int, n: int) -> int:
    """Stage owned by ``rank`` at tick 0: (-rank) mod n."""
    return (-rank) % n


def roll_stage_params(stacked: PyTree, n: int) -> PyTree:
    """Re-order a [n_stages, ...]-stacked tree so that slice r holds the
    stage initially owned by rank r (stage (-r) mod n)."""
    idx = jnp.asarray([initial_stage_for_rank(r, n) for r in range(n)])
    return jax.tree.map(lambda x: x[idx], stacked)


def zero_cdp_apply(stage_fn: Callable, my_params: PyTree, x, axis: str, n: int):
    """Cyclic streaming forward.

    stage_fn(stage_params, x) -> x, applied n times per micro-batch.
    my_params: THIS rank's current parameter shard (from a [n, ...] tree
    sharded over ``axis`` after ``roll_stage_params``).
    x: this rank's micro-batch activations.

    Runs 2n-1 ticks: rank r is active for t in [r, r+n). One ppermute per
    tick = the point-to-point schedule. Steady-state training overlaps
    consecutive steps; the (n-1)-tick ramp matches the pyramid of Fig. 2c.
    """
    r = jax.lax.axis_index(axis)
    perm = _ring_perm(n)

    def tick(carry, t):
        x, buf = carry
        active = (t >= r) & (t < r + n)
        y = stage_fn(buf, x)
        x = jax.tree.map(lambda a, b: jnp.where(active, a, b), y, x)
        buf = jax.lax.ppermute(buf, axis, perm)
        return (x, buf), None

    (x, _), _ = jax.lax.scan(tick, (x, my_params), jnp.arange(2 * n - 1))
    return x


def zero_dp_apply(stage_fn: Callable, my_params: PyTree, x, axis: str, n: int):
    """Baseline: all-gather each stage's parameters then run stages in order.
    One collective broadcast per stage — the pattern ZeRO-CDP removes."""
    gathered = jax.lax.all_gather(my_params, axis)         # [n, ...] per rank
    # undo the ownership roll: stage j sits at gathered index (-j) mod n
    idx = jnp.asarray([initial_stage_for_rank(j, n) for j in range(n)])

    def body(x, j):
        stage_params = jax.tree.map(lambda g: g[idx[j]], gathered)
        return stage_fn(stage_params, x), None

    x, _ = jax.lax.scan(body, x, jnp.arange(n))
    return x
