"""The CDP trainer: Eq. (CDP) as one SPMD program.

``make_train_step`` builds a jitted training step for any registered
architecture, parametrised by the update rule:

  * ``dp``      — baseline Data Parallelism: every rank differentiates at
                  theta_t; gradients merge with a single collective
                  (``lax.pmean`` -> all-reduce HLO burst at step end).
  * ``cdp_v1``  — all ranks differentiate at theta_{t-1}; gradients merge on
                  the point-to-point ring (collective-permute chain).
  * ``cdp_v2``  — rank i (the micro-batch index = ``lax.axis_index('data')``)
                  differentiates at theta_hat_i = stage-wise mix of theta_t /
                  theta_{t-1} per the paper's u_{i,j}; ring merge.

The step runs under ``jax.shard_map`` manual over the data axis (and the pod
axis when multi-pod), auto (GSPMD) over the model axis — so tensor
parallelism composes freely with the cyclic schedule.

State layout:
    {"params": theta_t, "params_prev": theta_{t-1} (CDP only),
     "opt": optimizer state, "step": int32}
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import grad_sync
from repro.core.schedule import RULE_CDP_V1, RULE_CDP_V2, RULE_DP
from repro.core.update_rules import (fresh_threshold_traced, needs_prev_params,
                                     select_params, validate_rule)
from repro.models import model as model_mod
from repro.optim import Optimizer
from repro.sharding import specs as sh

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    rule: str = RULE_CDP_V2
    data_axis: str = "data"
    pod_axis: Optional[str] = None        # set for the multi-pod mesh
    model_axis: str = "model"
    zero_axis: Optional[str] = None       # FSDP-style param sharding (DP path
                                          # or pod axis under CDP)
    donate: bool = True
    ring_grads: bool = True               # CDP: ring; False -> psum even for CDP
    lr_schedule: Callable = None
    grad_clip: float = 0.0                # global-norm clip (0 = off)
    # ---- beyond-paper §Perf levers ----
    zero1_ring: bool = False              # ring reduce-scatter + data-sharded
                                          # optimizer state + param all-gather
    grad_comm_dtype: str = "float32"      # ring communication dtype
    seq_parallel: bool = False            # sequence-sharded residual stream


def init_state(cfg, trainer: TrainerConfig, params: PyTree, opt: Optimizer):
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    if needs_prev_params(trainer.rule):
        state["params_prev"] = jax.tree.map(jnp.copy, params)
    return state


def _zero1_specs(params, mesh, trainer) -> PyTree:
    """Param pspecs with the data axis inserted at each leaf's ring slice
    axis — the layout of reduce-scattered grads and ZeRO-1 optimizer state."""
    gps = sh.param_pspecs(params, mesh, trainer.model_axis, trainer.zero_axis)
    n = mesh.shape[trainer.data_axis]
    layout = grad_sync.zero1_layout(params, n, gps)

    def one(leaf, spec, ax):
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        if ax >= 0:
            entries[ax] = trainer.data_axis
        return P(*entries)
    return jax.tree.map(one, params, gps, layout)


def optimizer_slot_keys(opt_state: PyTree, params: PyTree) -> set:
    """Params-shaped optimizer slots (see ``sharding.specs.param_slot_keys``
    — one structural detector shared by the ZeRO-1 and mirrored paths)."""
    return sh.param_slot_keys(opt_state, params)


def state_shardings(cfg, trainer: TrainerConfig, state: PyTree, mesh):
    psh = sh.param_shardings(state["params"], mesh, trainer.model_axis,
                             trainer.zero_axis)
    if trainer.zero1_ring:
        slots = optimizer_slot_keys(state["opt"], state["params"])
        z1 = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          _zero1_specs(state["params"], mesh, trainer))
        opt_sh = {k: (z1 if k in slots else NamedSharding(mesh, P()))
                  for k in state["opt"]}
    else:
        opt_sh = sh.state_shardings(state["opt"], psh)
    out = {"params": psh,
           "opt": opt_sh,
           "step": NamedSharding(mesh, P())}
    if "params_prev" in state:
        out["params_prev"] = psh
    return out


def _data_axes(trainer: TrainerConfig):
    return ((trainer.pod_axis,) if trainer.pod_axis else ()) + (trainer.data_axis,)


def make_train_step(cfg, trainer: TrainerConfig, mesh, opt: Optimizer,
                    loss_fn: Callable = None):
    """Returns (train_step, state_sharding_fn, batch_sharding_fn).

    train_step(state, batch) -> (state, metrics); jit-ready with shardings.
    """
    rule = validate_rule(trainer.rule)
    # fail fast on a bad kernel backend: the registry is threaded
    # configs/base.py -> kernels/registry.py -> models/* -> here, and a typo
    # would otherwise only surface mid-trace inside the first jitted step
    from repro.kernels import registry as kernel_registry
    kernel_registry.resolve(cfg)
    loss_fn = loss_fn or (lambda p, b: model_mod.loss_fn(cfg, p, b))
    n_data = mesh.shape[trainer.data_axis]
    n_pod = mesh.shape[trainer.pod_axis] if trainer.pod_axis else 1
    lr_fn = trainer.lr_schedule or (lambda s: 1e-3)
    daxes = _data_axes(trainer)
    grad_pspecs_cache = {}

    def grad_pspecs(params):
        # tensor-parallel specs of the grads (mirror the params) so the ring
        # slices along unsharded dims only. Keyed on the treedef itself
        # (hashable): id() of a temporary treedef can be recycled by the
        # allocator after GC and alias a different params structure.
        key = jax.tree.structure(params)
        if key not in grad_pspecs_cache:
            grad_pspecs_cache[key] = sh.param_pspecs(
                params, mesh, trainer.model_axis, trainer.zero_axis)
        return grad_pspecs_cache[key]

    # ---- the per-rank gradient computation, manual over data (+ pod) ------
    def grad_shard(params, params_prev, batch, step):
        i = jax.lax.axis_index(trainer.data_axis)
        if rule == RULE_DP or params_prev is None:
            theta_hat = params
        else:
            ids = model_mod.param_stage_ids(cfg, params, n_data)
            thr = fresh_threshold_traced(rule, i, n_data, step)
            theta_hat = select_params(params, params_prev, ids, thr)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            theta_hat, batch)
        if trainer.zero1_ring:
            grads, _ = grad_sync.zero1_reduce_scatter(
                grads, trainer.data_axis, n_data, grad_pspecs(params),
                comm_dtype=jnp.dtype(trainer.grad_comm_dtype))
        elif rule == RULE_DP or not trainer.ring_grads:
            grads = grad_sync.psum_all_reduce(grads, trainer.data_axis)
        else:
            grads = grad_sync.ring_all_reduce(grads, trainer.data_axis,
                                              n_data, grad_pspecs(params))
        if trainer.pod_axis:
            grads = grad_sync.psum_all_reduce(grads, trainer.pod_axis)
        loss = jax.lax.pmean(loss, daxes)
        metrics = jax.lax.pmean(metrics, daxes)
        return grads, loss, metrics

    batch_manual_spec = P(daxes if len(daxes) > 1 else daxes[0])

    def shard_batch_specs(batch):
        return jax.tree.map(
            lambda x: batch_manual_spec if getattr(x, "ndim", 0) else P(),
            batch)

    use_prev = needs_prev_params(rule)

    def grad_out_specs(params):
        if not trainer.zero1_ring:
            return jax.tree.map(lambda _: P(), params)
        # reduce-scattered grads come out data-sharded along the slice axis
        layout = grad_sync.zero1_layout(
            params, n_data, grad_pspecs(params))

        def one(leaf, ax):
            entries = [None] * leaf.ndim
            if ax >= 0:
                entries[ax] = trainer.data_axis
            return P(*entries)
        return jax.tree.map(one, params, layout)

    def train_step(state, batch):
        params = state["params"]
        params_prev = state["params_prev"] if use_prev else params
        if trainer.seq_parallel and compat.PARTIAL_AUTO_SHARD_MAP:
            # perf lever only: on old jax the shard_map fallback is fully
            # manual, where an in-body sharding constraint over the model
            # axis is illegal — skip it (numerics are unaffected)
            from repro.models import blocks as blocks_mod
            blocks_mod.set_activation_sharding(mesh, trainer.model_axis)
        rep = lambda t: jax.tree.map(lambda _: P(), t)
        in_specs = (rep(params), rep(params_prev), shard_batch_specs(batch),
                    P())
        out_specs = (grad_out_specs(params), P(), P())
        grads, loss, metrics = compat.shard_map(
            grad_shard, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names=set(daxes), check_vma=False)(
                params, params_prev, batch, state["step"])
        if trainer.seq_parallel:
            from repro.models import blocks as blocks_mod
            blocks_mod.set_activation_sharding(None, None)

        if trainer.grad_clip:
            gnorm = jnp.sqrt(sum(
                jnp.sum(g.astype(jnp.float32) ** 2)
                for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, trainer.grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads)
        lr = lr_fn(state["step"])
        new_params, new_opt = opt.update(grads, state["opt"], params, lr)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        if use_prev:
            new_state["params_prev"] = params            # theta_t -> theta_{t-1}
        metrics = dict(metrics)
        metrics["lr"] = lr
        return new_state, metrics

    def batch_shardings(batch):
        return sh.batch_sharding(batch, mesh, daxes)

    return train_step, partial(state_shardings, cfg, trainer), batch_shardings


def jit_train_step(cfg, trainer: TrainerConfig, mesh, opt: Optimizer,
                   state: PyTree, batch_example: PyTree, loss_fn=None):
    """Convenience: build + jit with explicit in/out shardings."""
    step_fn, state_sh_fn, batch_sh_fn = make_train_step(
        cfg, trainer, mesh, opt, loss_fn)
    ssh = state_sh_fn(state, mesh)
    bsh = batch_sh_fn(batch_example)
    jitted = jax.jit(step_fn,
                     in_shardings=(ssh, bsh),
                     out_shardings=(ssh, None),
                     donate_argnums=(0,) if trainer.donate else ())
    return jitted, ssh, bsh


# ---------------------------------------------------------------------------
# Serving steps (no CDP — decode/prefill are inference paths)
# ---------------------------------------------------------------------------

def make_prefill_step(cfg, mesh, data_axes=("data",)):
    def prefill(params, batch):
        return model_mod.prefill_logits(cfg, params, batch)
    return prefill


def make_serve_step(cfg, mesh, data_axes=("data",)):
    def serve_step(params, batch, cache):
        return model_mod.decode_step(cfg, params, batch, cache)
    return serve_step
