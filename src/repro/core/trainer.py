"""The CDP trainer: Eq. (CDP) as one SPMD program.

``make_train_step`` builds a jitted training step for any registered
architecture, parametrised by a :class:`repro.parallel.ParallelPlan` — the
strategy object that owns the update rule, the gradient-sync implementation,
and the parameter/optimizer placement:

  * ``dp``         — every rank differentiates at theta_t; gradients merge
                     with a single collective (all-reduce HLO burst).
  * ``cdp_v1``     — all ranks differentiate at theta_{t-1}; gradients merge
                     on the point-to-point ring (collective-permute chain).
  * ``cdp_v2``     — rank i (micro-batch = ``lax.axis_index('data')``)
                     differentiates at theta_hat_i = stage-wise mix of
                     theta_t / theta_{t-1} per the paper's u_{i,j}; ring.
  * ``cdp_random`` — beyond-paper randomized freshness threshold; ring.
  * ``zero1_ring`` — ring reduce-scatter + data-sharded optimizer state +
                     parameter all-gather.
  * ``zero_cdp``   — stage-sharded parameters streamed point-to-point
                     (paper Sec. 4.4; ``repro.parallel.zero_cdp``).

The legacy ``TrainerConfig`` flags (``rule=``, ``ring_grads=``,
``zero1_ring=``, ``zero_axis=``) are DEPRECATED aliases that resolve to a
plan — exactly how ``attn_backend`` maps onto the kernel registry.

The step runs under ``jax.shard_map`` manual over the data axis (and the pod
axis when multi-pod), auto (GSPMD) over the model axis — so tensor
parallelism composes freely with the cyclic schedule.

State layout (tree placements):
    {"params": theta_t, "params_prev": theta_{t-1} (CDP only),
     "opt": optimizer state, "step": int32}
ZeRO-CDP replaces each params tree with {"stages": [N, chunk]} stage chunks
sharded over the data axis.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import grad_sync
from repro.core.schedule import RULE_CDP_V1, RULE_CDP_V2, RULE_DP
from repro.core.update_rules import (fresh_threshold_traced, needs_prev_params,
                                     select_params, validate_rule)
from repro.models import model as model_mod
from repro.optim import Optimizer
from repro.parallel import plan as plan_mod
from repro.sharding import specs as sh

# (repro.parallel.plan reads rule constants from repro.core.schedule; the
# core package __init__ re-exports this module lazily, so that import chain
# does not cycle back here. repro.parallel.zero_cdp is still imported
# lazily below — it is only needed for stage-sharded plans.)

PyTree = Any

_LEGACY_PLAN_FLAGS = ("rule", "ring_grads", "zero1_ring", "zero_axis")


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    # The parallelism strategy: a registered plan name ("dp", "cdp_v1",
    # "cdp_v2", "cdp_random", "zero1_ring", "zero_cdp") or a ParallelPlan.
    # None -> the legacy flags below (deprecated), else the cdp_v2 default.
    plan: Any = None                      # ParallelPlan | plan name | None
    # ---- DEPRECATED aliases (resolve to a plan; see resolved_plan) -------
    rule: Optional[str] = None            # DEPRECATED -> plan
    ring_grads: Optional[bool] = None     # DEPRECATED: False -> psum merge
    zero1_ring: Optional[bool] = None     # DEPRECATED -> plan "zero1_ring"
    zero_axis: Optional[str] = None       # DEPRECATED -> plan.zero_axis
    # ---- axes / loop knobs (not plan-owned) ------------------------------
    data_axis: str = "data"
    pod_axis: Optional[str] = None        # set for the multi-pod mesh
    model_axis: str = "model"
    donate: bool = True
    lr_schedule: Optional[Callable] = None
    grad_clip: float = 0.0                # global-norm clip (0 = off)
    grad_comm_dtype: str = "float32"      # ring communication dtype
    seq_parallel: bool = False            # sequence-sharded residual stream

    def __post_init__(self):
        # resolve once at construction: legacy-flag warnings fire here (not
        # on every make_train_step/state_shardings call) and a bad plan or
        # plan+legacy mix fails fast.
        object.__setattr__(self, "_plan", _resolve_trainer_plan(self))

    def resolved_plan(self):
        return self._plan


def _resolve_trainer_plan(tc: TrainerConfig):
    legacy = {k: getattr(tc, k) for k in _LEGACY_PLAN_FLAGS
              if getattr(tc, k) is not None}
    if tc.plan is not None:
        if legacy:
            raise ValueError(
                f"TrainerConfig: pass either plan= or the deprecated flags "
                f"({', '.join(sorted(legacy))}), not both")
        return plan_mod.resolve_plan(tc.plan)
    if legacy:
        warnings.warn(
            f"TrainerConfig({', '.join(f'{k}=' for k in sorted(legacy))}...) "
            f"is deprecated; pass plan= (a ParallelPlan or one of "
            f"{plan_mod.available_plans()})", DeprecationWarning, stacklevel=4)
        return plan_mod.plan_from_legacy_flags(
            rule=tc.rule, ring_grads=tc.ring_grads,
            zero1_ring=tc.zero1_ring, zero_axis=tc.zero_axis)
    return plan_mod.resolve_plan(None)


def init_state(cfg, trainer: TrainerConfig, params: PyTree, opt: Optimizer,
               mesh=None):
    """Initial train state for the trainer's plan. ``mesh`` is required for
    stage-sharded placement (the stage count is the data-axis size)."""
    plan = trainer.resolved_plan()
    if plan.placement == plan_mod.PLACE_STAGE_SHARDED:
        from repro.parallel import zero_cdp as zcdp
        if mesh is None:
            raise ValueError(
                f"plan {plan.name!r} needs the mesh at init_state (stage "
                "count = data-axis size)")
        return zcdp.init_stage_state(cfg, plan, params, opt,
                                     mesh.shape[trainer.data_axis])
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    if needs_prev_params(plan.rule):
        state["params_prev"] = jax.tree.map(jnp.copy, params)
    return state


def optimizer_slot_keys(opt_state: PyTree, params: PyTree) -> set:
    """Params-shaped optimizer slots (see ``sharding.specs.param_slot_keys``
    — one structural detector shared by the ZeRO-1 and mirrored paths)."""
    return sh.param_slot_keys(opt_state, params)


def state_shardings(cfg, trainer: TrainerConfig, state: PyTree, mesh):
    plan = trainer.resolved_plan()
    if plan.placement == plan_mod.PLACE_STAGE_SHARDED:
        psh = sh.stage_chunk_shardings(state["params"], mesh,
                                       trainer.data_axis)
    else:
        psh = sh.param_shardings(state["params"], mesh, trainer.model_axis,
                                 plan.zero_axis)
    if plan.placement == plan_mod.PLACE_ZERO1:
        slots = optimizer_slot_keys(state["opt"], state["params"])
        z1 = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          sh.zero1_param_pspecs(
                              state["params"], mesh, trainer.data_axis,
                              trainer.model_axis, plan.zero_axis))
        opt_sh = {k: (z1 if k in slots else NamedSharding(mesh, P()))
                  for k in state["opt"]}
    else:
        opt_sh = sh.state_shardings(state["opt"], psh)
    out = {"params": psh,
           "opt": opt_sh,
           "step": NamedSharding(mesh, P())}
    if "params_prev" in state:
        out["params_prev"] = psh
    return out


def _data_axes(trainer: TrainerConfig):
    return ((trainer.pod_axis,) if trainer.pod_axis else ()) + (trainer.data_axis,)


def make_train_step(cfg, trainer: TrainerConfig, mesh, opt: Optimizer,
                    loss_fn: Callable = None):
    """Returns (train_step, state_sharding_fn, batch_sharding_fn).

    train_step(state, batch) -> (state, metrics); jit-ready with shardings.
    The strategy comes from ``trainer.resolved_plan()``; stage-sharded plans
    (``zero_cdp``) delegate to ``repro.parallel.zero_cdp``.
    """
    plan = trainer.resolved_plan()
    rule = validate_rule(plan.rule)
    # fail fast on a bad kernel backend: the registry is threaded
    # configs/base.py -> kernels/registry.py -> models/* -> here, and a typo
    # would otherwise only surface mid-trace inside the first jitted step
    from repro.kernels import registry as kernel_registry
    kernel_registry.resolve(cfg)
    plan.validate_mesh(mesh, data_axis=trainer.data_axis,
                       pod_axis=trainer.pod_axis)
    if plan.placement == plan_mod.PLACE_STAGE_SHARDED:
        from repro.parallel import zero_cdp as zcdp
        step_fn = zcdp.make_train_step(cfg, trainer, plan, mesh, opt, loss_fn)
        return (step_fn, partial(state_shardings, cfg, trainer),
                lambda batch: sh.batch_sharding(batch, mesh,
                                                _data_axes(trainer)))
    loss_fn = loss_fn or (lambda p, b: model_mod.loss_fn(cfg, p, b))
    n_data = mesh.shape[trainer.data_axis]
    lr_fn = trainer.lr_schedule or (lambda s: 1e-3)
    daxes = _data_axes(trainer)
    zero1 = plan.sync == plan_mod.SYNC_ZERO1_RING
    grad_pspecs_cache = {}

    def grad_pspecs(params):
        # tensor-parallel specs of the grads (mirror the params) so the ring
        # slices along unsharded dims only. Keyed on the treedef itself
        # (hashable): id() of a temporary treedef can be recycled by the
        # allocator after GC and alias a different params structure.
        key = jax.tree.structure(params)
        if key not in grad_pspecs_cache:
            grad_pspecs_cache[key] = sh.param_pspecs(
                params, mesh, trainer.model_axis, plan.zero_axis)
        return grad_pspecs_cache[key]

    # ---- the per-rank gradient computation, manual over data (+ pod) ------
    def grad_shard(params, params_prev, batch, step):
        i = jax.lax.axis_index(trainer.data_axis)
        if rule == RULE_DP or params_prev is None:
            theta_hat = params
        else:
            ids = model_mod.param_stage_ids(cfg, params, n_data)
            thr = fresh_threshold_traced(rule, i, n_data, step)
            theta_hat = select_params(params, params_prev, ids, thr)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            theta_hat, batch)
        grads = grad_sync.sync_gradients(
            plan.sync, grads, trainer.data_axis, n_data, grad_pspecs(params),
            comm_dtype=jnp.dtype(trainer.grad_comm_dtype))
        if trainer.pod_axis:
            grads = grad_sync.psum_all_reduce(grads, trainer.pod_axis)
        loss = jax.lax.pmean(loss, daxes)
        metrics = jax.lax.pmean(metrics, daxes)
        return grads, loss, metrics

    use_prev = needs_prev_params(rule)

    def grad_out_specs(params):
        if not zero1:
            return jax.tree.map(lambda _: P(), params)
        # reduce-scattered grads come out data-sharded along the slice axis
        layout = grad_sync.zero1_layout(
            params, n_data, grad_pspecs(params))

        def one(leaf, ax):
            entries = [None] * leaf.ndim
            if ax >= 0:
                entries[ax] = trainer.data_axis
            return P(*entries)
        return jax.tree.map(one, params, layout)

    def train_step(state, batch):
        params = state["params"]
        params_prev = state["params_prev"] if use_prev else params
        if trainer.seq_parallel and compat.PARTIAL_AUTO_SHARD_MAP:
            # perf lever only: on old jax the shard_map fallback is fully
            # manual, where an in-body sharding constraint over the model
            # axis is illegal — skip it (numerics are unaffected)
            from repro.models import blocks as blocks_mod
            blocks_mod.set_activation_sharding(mesh, trainer.model_axis)
        rep = lambda t: jax.tree.map(lambda _: P(), t)
        in_specs = (rep(params), rep(params_prev),
                    sh.batch_manual_pspecs(batch, daxes), P())
        out_specs = (grad_out_specs(params), P(), P())
        grads, loss, metrics = compat.shard_map(
            grad_shard, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names=set(daxes), check_vma=False)(
                params, params_prev, batch, state["step"])
        if trainer.seq_parallel:
            from repro.models import blocks as blocks_mod
            blocks_mod.set_activation_sharding(None, None)

        if trainer.grad_clip:
            gnorm = jnp.sqrt(sum(
                jnp.sum(g.astype(jnp.float32) ** 2)
                for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, trainer.grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads)
        lr = lr_fn(state["step"])
        new_params, new_opt = opt.update(grads, state["opt"], params, lr)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        if use_prev:
            new_state["params_prev"] = params            # theta_t -> theta_{t-1}
        metrics = dict(metrics)
        metrics["lr"] = lr
        return new_state, metrics

    def batch_shardings(batch):
        return sh.batch_sharding(batch, mesh, daxes)

    return train_step, partial(state_shardings, cfg, trainer), batch_shardings


def jit_train_step(cfg, trainer: TrainerConfig, mesh, opt: Optimizer,
                   state: PyTree, batch_example: PyTree, loss_fn=None):
    """Convenience: build + jit with explicit in/out shardings."""
    step_fn, state_sh_fn, batch_sh_fn = make_train_step(
        cfg, trainer, mesh, opt, loss_fn)
    ssh = state_sh_fn(state, mesh)
    bsh = batch_sh_fn(batch_example)
    jitted = jax.jit(step_fn,
                     in_shardings=(ssh, bsh),
                     out_shardings=(ssh, None),
                     donate_argnums=(0,) if trainer.donate else ())
    return jitted, ssh, bsh


# ---------------------------------------------------------------------------
# Serving steps (no CDP — decode/prefill are inference paths)
# ---------------------------------------------------------------------------

def make_prefill_step(cfg, mesh, data_axes=("data",)):
    def prefill(params, batch):
        return model_mod.prefill_logits(cfg, params, batch)
    return prefill


def make_serve_step(cfg, mesh, data_axes=("data",)):
    def serve_step(params, batch, cache):
        return model_mod.decode_step(cfg, params, batch, cache)
    return serve_step
