from repro.core.schedule import (RULE_CDP_V1, RULE_CDP_V2, RULE_DP, RULES,
                                 cdp_phase, comm_events, dp_phase,
                                 fresh_threshold, table1, u_matrix)
from repro.core.trainer import (TrainerConfig, init_state, jit_train_step,
                                make_train_step)

__all__ = ["RULE_CDP_V1", "RULE_CDP_V2", "RULE_DP", "RULES", "cdp_phase",
           "comm_events", "dp_phase", "fresh_threshold", "table1", "u_matrix",
           "TrainerConfig", "init_state", "jit_train_step", "make_train_step"]
