"""Core CDP machinery: the schedule spec (numpy-only) and the SPMD trainer.

The schedule symbols are re-exported eagerly (numpy-only, cheap); the
trainer symbols lazily — importing this package must NOT pull in jax, so
that ``repro.parallel`` (which reads the rule constants from
``repro.core.schedule``) stays genuinely jax-free for launchers that list
``--plan`` choices before device initialisation.
"""
from repro.core.schedule import (RULE_CDP_V1, RULE_CDP_V2, RULE_DP, RULES,
                                 cdp_phase, comm_events, dp_phase,
                                 fresh_threshold, table1, u_matrix)

__all__ = ["RULE_CDP_V1", "RULE_CDP_V2", "RULE_DP", "RULES", "cdp_phase",
           "comm_events", "dp_phase", "fresh_threshold", "table1", "u_matrix",
           "TrainerConfig", "init_state", "jit_train_step", "make_train_step"]

_TRAINER_EXPORTS = ("TrainerConfig", "init_state", "jit_train_step",
                    "make_train_step")


def __getattr__(name):
    if name in _TRAINER_EXPORTS:
        from repro.core import trainer
        return getattr(trainer, name)
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")
