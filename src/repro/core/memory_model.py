"""Analytic activation-memory model — reproduces paper Fig. 4 and Table 1.

Given a per-module activation/FLOPs profile (e.g. from
``repro.configs.paper_models``), partition the model into N stages of equal
FLOPs (the paper's fvcore protocol), then simulate the DP vs CDP execution
timelines of ``repro.core.schedule`` and report per-worker activation memory.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core import schedule


def refine_profile(profile, units: int):
    """Subdivide modules so the profile has >= ``units`` entries (needed when
    N approaches the module count — the paper's memory traces are effectively
    continuous). Activation bytes and FLOPs split proportionally."""
    total_flops = sum(f for (_, _, f) in profile)
    out = []
    for name, a, f in profile:
        k = max(1, round(units * f / max(total_flops, 1)))
        for i in range(k):
            out.append((f"{name}.{i}", a / k, f / k))
    return out


def partition_stages(profile: Sequence[Tuple[str, int, int]], n: int
                     ) -> List[List[int]]:
    """Split module indices into n contiguous stages with ~equal FLOPs."""
    flops = np.array([f for (_, _, f) in profile], dtype=np.float64)
    cum = np.cumsum(flops)
    total = cum[-1]
    stages: List[List[int]] = [[] for _ in range(n)]
    for idx, c in enumerate(cum):
        s = min(n - 1, int((c - flops[idx] / 2) / total * n))
        stages[s].append(idx)
    # guarantee non-empty stages
    for s in range(n):
        if not stages[s]:
            # steal from the largest neighbour
            donor = max(range(n), key=lambda t: len(stages[t]))
            stages[s] = [stages[donor].pop()]
    return stages


def stage_activation_bytes(profile, stages) -> np.ndarray:
    act = np.array([a for (_, a, _) in profile], dtype=np.float64)
    return np.array([act[idx].sum() for idx in stages])


@dataclasses.dataclass
class MemoryReport:
    n: int
    dp_per_worker_peak: float
    cdp_per_worker_peak: float
    dp_timeline: np.ndarray       # total bytes across workers per tick
    cdp_timeline: np.ndarray
    reduction: float              # (dp - cdp) / dp on the peak


def simulate(profile, n: int, batch_per_worker: int = 1) -> MemoryReport:
    if len(profile) < 4 * n:
        profile = refine_profile(profile, 4 * n)
    stages = partition_stages(profile, n)
    sb = stage_activation_bytes(profile, stages) * batch_per_worker
    dp_tl = schedule.total_activation_timeline(n, cyclic=False, stage_bytes=sb)
    cdp_tl = schedule.total_activation_timeline(n, cyclic=True, stage_bytes=sb)
    dp_peak = dp_tl.max() / n
    cdp_peak = cdp_tl.max() / n
    return MemoryReport(
        n=n, dp_per_worker_peak=float(dp_peak),
        cdp_per_worker_peak=float(cdp_peak),
        dp_timeline=dp_tl, cdp_timeline=cdp_tl,
        reduction=float((dp_peak - cdp_peak) / dp_peak))


def fig4_table(profile, ns=(4, 8, 32)) -> Dict[int, MemoryReport]:
    return {n: simulate(profile, n) for n in ns}
