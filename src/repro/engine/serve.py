"""ServeEngine: fused-prefill cache fill + batched decode with sampling.

The one serving code path: ``launch/serve.py`` is an argparse shim over
this class. Prefill is ONE full-sequence ``prefill_with_cache`` pass (the
blockwise/flash `prefill_attn` kernel op) that writes every layer's decode
state — not the old per-token teacher-forcing loop — and is timed so
prefill tok/s is a first-class serving metric alongside decode tok/s.

    spec = RunSpec(arch="stablelm-1.6b", reduced=True, host_devices=4)
    engine = ServeEngine(spec, batch=4, prompt_len=64, gen=32)
    result = engine.generate()
    print(result["prefill_tok_s"], result["decode_tok_s"])

For enc-dec archs the encoder runs through the public ``models.encode``
and the memory cache is the EXACT encoder output (shape follows the
encoder; no zeros-padded splice for cross-attention to leak onto).
"""
from __future__ import annotations

import time
from typing import Any, Dict, Optional

from repro.engine.spec import RunSpec

PyTree = Any


class ServeEngine:
    def __init__(self, spec: RunSpec, *,
                 batch: int = 4,
                 prompt_len: int = 64,
                 gen: int = 32,
                 cache_len: Optional[int] = None,
                 temperature: float = 0.0,
                 verbose: bool = True):
        spec.ensure_host_devices()
        self.spec = spec
        self.batch = batch
        self.prompt_len = prompt_len
        self.gen = gen
        self.temperature = temperature
        self.verbose = verbose

        self.cfg = spec.resolve_config()
        self.cache_len = cache_len or (prompt_len + gen)
        self.mesh = None
        self.params = None
        self.cache = None
        self._built = False
        self._warm = set()                # traced (fn, shapes) signatures

    def _log(self, msg: str) -> None:
        if self.verbose:
            print(msg, flush=True)

    # -- lifecycle ---------------------------------------------------------

    def build(self) -> "ServeEngine":
        if self._built:
            return self
        import jax
        from repro.models import init_params
        from repro.models import model as model_mod

        self.mesh = self.spec.build_mesh()
        self.params = init_params(self.cfg,
                                  jax.random.PRNGKey(self.spec.seed))
        cfg = self.cfg
        self._prefill_fn = jax.jit(
            lambda p, b, c: model_mod.prefill_with_cache(cfg, p, b, c))
        self._decode_fn = jax.jit(
            lambda p, b, c: model_mod.decode_step(cfg, p, b, c))
        if cfg.family == "encdec":
            self._encode_fn = jax.jit(
                lambda p, f: model_mod.encode(cfg, p, f))
        self._built = True
        return self

    def _warmup(self, tag, fn, *args):
        """Compile outside the timed region, once per argument-shape
        signature (the fns are pure — discarding outputs is side-effect
        free). Steady-state calls pay exactly one execution."""
        import jax
        sig = (tag, str(jax.tree.map(lambda x: getattr(x, "shape", None),
                                     args)))
        if sig not in self._warm:
            jax.block_until_ready(fn(*args))
            self._warm.add(sig)

    # -- public API --------------------------------------------------------

    def encode(self, frames):
        """Encoder memory for enc-dec archs (public — no private
        ``model._run_encoder`` reach-through)."""
        self.build()
        if self.cfg.family != "encdec":
            raise ValueError(
                f"encode() is for encdec archs, not {self.cfg.family!r}")
        return self._encode_fn(self.params, frames)

    def prefill(self, prompts, *, extras: Optional[Dict[str, Any]] = None):
        """Fill the decode cache from ``prompts`` [B, S] in one fused pass.

        ``extras`` carries the family side-inputs (``frames`` for enc-dec,
        ``patches`` for VLM); missing ones are synthesised as zeros so every
        arch serves out of the box. Returns the last-position logits and
        records prefill timing."""
        import jax
        import jax.numpy as jnp
        from repro.models import init_cache

        self.build()
        B, S = prompts.shape
        vlm_prefix = self.cfg.vlm.num_patches if self.cfg.vlm else 0
        cache = init_cache(self.cfg, B, self.cache_len + vlm_prefix)
        batch = {"tokens": jnp.asarray(prompts)}
        batch.update(extras or {})
        if self.cfg.family == "encdec" and "frames" not in batch:
            e = self.cfg.encdec
            batch["frames"] = jnp.zeros(
                (B, max(1, S // e.frame_rate_divisor), e.frontend_dim),
                jnp.dtype(self.cfg.dtype))
        if self.cfg.family == "vlm" and "patches" not in batch:
            v = self.cfg.vlm
            batch["patches"] = jnp.zeros((B, v.num_patches, v.vision_dim),
                                         jnp.dtype(self.cfg.dtype))

        # warm the jit cache first so the timed call measures execution,
        # not trace+compile (same methodology as benchmarks/decode_bench)
        self._warmup("prefill", self._prefill_fn, self.params, batch, cache)
        t0 = time.time()
        logits, self.cache = jax.block_until_ready(
            self._prefill_fn(self.params, batch, cache))
        self.prefill_s = time.time() - t0
        self.prefill_tok_s = B * S / max(self.prefill_s, 1e-9)
        return logits

    def decode(self, logits, n: Optional[int] = None):
        """Batched sampling loop from the prefilled cache. Greedy when
        temperature == 0, categorical otherwise. Returns tokens [B, n]."""
        import jax
        import jax.numpy as jnp
        import numpy as np

        if self.cache is None:
            raise RuntimeError("call prefill() before decode()")
        n = self.gen if n is None else n
        key = jax.random.PRNGKey(self.spec.seed + 1)
        tok = jnp.argmax(logits, -1)
        # warm the decode compile outside the timed loop (decode_step is
        # pure — discarding the outputs leaves self.cache untouched)
        self._warmup("decode", self._decode_fn, self.params, {"token": tok},
                     self.cache)
        out = []
        t0 = time.time()
        for _ in range(n):
            out.append(np.asarray(tok))
            logits, self.cache = self._decode_fn(
                self.params, {"token": tok}, self.cache)
            if self.temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(
                    sub, logits.astype(jnp.float32) / self.temperature, -1)
            else:
                tok = jnp.argmax(logits, -1)
        jax.block_until_ready(logits)
        self.decode_s = time.time() - t0
        self.decode_tok_s = len(out) * logits.shape[0] / max(self.decode_s, 1e-9)
        return np.stack(out, 1)

    def generate(self, prompts=None,
                 extras: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        """End-to-end: (synthetic) prompts -> fused prefill -> batched
        decode. ``extras`` forwards family side-inputs (frames/patches) to
        prefill. Returns tokens and both serving throughput metrics."""
        import jax.numpy as jnp
        from repro.data.synthetic import make_lm_data

        self.build()
        if prompts is None:
            toks = make_lm_data(self.cfg.vocab_size,
                                self.batch * self.prompt_len + 1,
                                seed=self.spec.seed)
            prompts = jnp.asarray(
                toks[:self.batch * self.prompt_len]
                .reshape(self.batch, self.prompt_len) % self.cfg.vocab_size)
        logits = self.prefill(prompts, extras=extras)
        tokens = self.decode(logits)
        B, S = prompts.shape
        self._log(
            f"prefill: {S} tokens x batch {B} in {self.prefill_s:.2f}s "
            f"({self.prefill_tok_s:.1f} tok/s); "
            f"decode: {tokens.shape[1]} tokens x batch {B} in "
            f"{self.decode_s:.2f}s ({self.decode_tok_s:.1f} tok/s)")
        return {"tokens": tokens, "prompts": prompts,
                "prefill_s": self.prefill_s,
                "prefill_tok_s": self.prefill_tok_s,
                "decode_s": self.decode_s,
                "decode_tok_s": self.decode_tok_s}
