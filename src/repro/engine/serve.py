"""ServeEngine: fused-prefill cache fill + batched decode with sampling.

The one serving code path: ``launch/serve.py`` is an argparse shim over
this class. Prefill is ONE full-sequence ``prefill_with_cache`` pass (the
blockwise/flash `prefill_attn` kernel op) that writes every layer's decode
state — not the old per-token teacher-forcing loop — and is timed so
prefill tok/s is a first-class serving metric alongside decode tok/s.

    spec = RunSpec(arch="stablelm-1.6b", reduced=True, host_devices=4)
    engine = ServeEngine(spec, batch=4, prompt_len=64, gen=32)
    result = engine.generate()
    print(result["prefill_tok_s"], result["decode_tok_s"])

Continuous batching (:meth:`serve`): a request queue plus an
iteration-level scheduler over ``max_slots`` fixed decode slots. Ragged
prompts prefill LEFT-ALIGNED with per-row cache lengths
(``batch["lengths"]`` through ``models.prefill_with_cache``), decode runs
ONE jitted ``decode_step(..., ragged=True)`` whose per-row slot writes let
every row sit at its own position, and a finished row's slot is re-filled
by splicing a freshly prefilled cache row into the live cache
(``engine.batching.merge_caches`` — no retrace). Per-row generation state
(step count, done bookkeeping, sampling key) lives in
``engine.batching.SlotScheduler`` + a [B] sampling-key batch.

For enc-dec archs the encoder runs through the public ``models.encode``
and the memory cache is the EXACT encoder output (shape follows the
encoder; no zeros-padded splice for cross-attention to leak onto).

Graceful degradation (:meth:`serve`): every request leaves with a terminal
``status`` ("ok" | "timeout" | "rejected" | "failed") and its partial
tokens — malformed requests are REJECTED at enqueue time, per-request
step-budget deadlines expire waiting or live requests as ``timeout``,
``queue_limit`` bounds the admission queue with explicit rejection, a
request whose cache rows go non-finite is QUARANTINED (evicted, status
"failed") without perturbing its co-residents, and an exhausted
``max_steps`` budget times the stragglers out instead of raising. The
``resilience=`` fault injector (``engine.resilience``) can poison a
request's cache rows to drive the quarantine path deterministically.

Paged serving (``paged=True``): the dense per-slot cache reservation is
replaced by ``engine.paging`` — a fixed pool of ``kv_pool_blocks`` KV
blocks of ``kv_block_size`` tokens with a per-slot block table. Admission
prefills into a TRANSIENT dense cache and block-scatters it through the
table (bitwise-identical numerics to the dense engine), prompts sharing a
cached prefix skip re-prefilling it (``prefix_cache``, copy-on-write on
divergence), and pool exhaustion preempts the newest request to host RAM
(``sleep_level`` 1: offload + bitwise wake; 2: discard + re-prefill).
Pool/prefix state PERSISTS across serve() calls, so a warmed engine serves
repeat prompts at a high prefix hit rate. Every terminal status — ok,
timeout, rejected, failed — releases the slot's blocks through one choke
point, so the pool can never leak from an eviction path.

Wall-clock serving (``serve(policy=batching.ServePolicy(...))``): the
nine historical serve() kwargs are deprecated aliases of ONE policy
dataclass, which additionally configures

* chunked prefill (``prefill_chunk=N``): each admitted prompt is cut into
  N-token chunks prefilled one per scheduler iteration, interleaved with
  the co-residents' decode steps — a long prompt no longer stalls every
  live stream for its whole prefill, and the emitted tokens stay BITWISE
  identical to whole-prompt admission (dense and paged);
* a clock mode ("step" | "wall" | "virtual"): seconds-denominated
  arrivals/deadlines (``Request.arrival_time``/``deadline_s``) with a
  deterministic virtual clock for tests and a StepWatchdog for slow-step
  reporting;
* pluggable admission ("fcfs" | "slo"): SLO admission is
  earliest-deadline-first with feasibility culling — doomed requests are
  left to expire in the queue instead of burning slots;
* streaming: ``Request.on_token`` / :meth:`ServeEngine.serve_stream`
  observe each emitted token from the SAME fused per-iteration host sync
  that serves the eos check and the quarantine health pass (one [B]-sized
  transfer per iteration, never one per concern).
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

from repro.engine import batching
from repro.engine import resilience as rsl
from repro.engine.spec import RunSpec

PyTree = Any


def _sampler():
    """Per-row sampling closure shared by the dense and paged serving fns.
    ``temps``/``topks`` are [B] RUNTIME data (per-request overrides with
    the engine-wide default filled in host-side), so ONE jitted step serves
    a heterogeneous batch — rollout groups get per-request diversity
    without a retrace. A row with temp <= 0 takes argmax (same tokens the
    old engine-wide greedy path produced); temp > 0 samples categorically
    over the row's top-k logits (k <= 0 disables the truncation) with one
    key per row, so a request's stream never depends on its co-residents."""
    import jax
    import jax.numpy as jnp

    def sample(logits, keys, temps, topks):
        def one(k, lg, temp, tk):
            nk, sub = jax.random.split(k)
            lg32 = lg.astype(jnp.float32)
            vocab = lg32.shape[-1]
            kth = jnp.sort(lg32)[::-1][jnp.clip(tk, 1, vocab) - 1]
            masked = jnp.where((tk <= 0) | (lg32 >= kth), lg32, -jnp.inf)
            samp = jax.random.categorical(
                sub, masked / jnp.maximum(temp, 1e-6), -1)
            t = jnp.where(temp > 0, samp, jnp.argmax(lg, -1))
            return nk, t
        keys, toks = jax.vmap(one)(keys, logits, temps, topks)
        return toks.astype(jnp.int32), keys
    return sample


def _sid(req: "batching.Request") -> int:
    """The fold-in id for a request's sampling key stream: ``seed`` when
    set, else ``rid`` (the historical behaviour)."""
    return req.seed if req.seed is not None else req.rid


class ServeEngine:
    def __init__(self, spec: RunSpec, *,
                 batch: int = 4,
                 prompt_len: int = 64,
                 gen: int = 32,
                 cache_len: Optional[int] = None,
                 temperature: float = 0.0,
                 resilience=None,         # FaultInjector | spec str | None
                 paged: bool = False,
                 kv_block_size: int = 16,
                 kv_pool_blocks: Optional[int] = None,
                 prefix_cache: bool = True,
                 sleep_level: int = 1,
                 verbose: bool = True):
        spec.ensure_host_devices()
        self.spec = spec
        self.batch = batch
        self.prompt_len = prompt_len
        self.gen = gen
        self.temperature = temperature
        self.injector = rsl.FaultInjector.from_spec(resilience,
                                                    seed=spec.seed)
        self.events = rsl.EventLog()
        self.verbose = verbose
        self.paged = paged
        self.kv_block_size = kv_block_size
        self.kv_pool_blocks = kv_pool_blocks
        self.prefix_cache = prefix_cache
        if sleep_level not in (1, 2):
            raise ValueError(f"sleep_level={sleep_level}; expected 1 "
                             "(offload to host RAM) or 2 (discard + "
                             "re-prefill on wake)")
        self.sleep_level = sleep_level
        if paged and kv_block_size < 1:
            raise ValueError(f"kv_block_size={kv_block_size} must be >= 1")

        self.cfg = spec.resolve_config()
        self.cache_len = cache_len or (prompt_len + gen)
        self.mesh = None
        self.params = None
        self.cache = None
        self._built = False
        self._warm = set()                # traced (fn, shapes) signatures
        self._serving = {}                # slot-count -> jitted serving fns
        self._cache_axes = None           # dense merge axes, once per build
        self._paged_state = None          # pool + device cache, persistent
        self._stream_cb = None            # serve_stream's per-token hook

    def _log(self, msg: str) -> None:
        if self.verbose:
            print(msg, flush=True)

    # -- lifecycle ---------------------------------------------------------

    def build(self) -> "ServeEngine":
        if self._built:
            return self
        import jax
        from repro.models import init_params
        from repro.models import model as model_mod

        self.mesh = self.spec.build_mesh()
        self.params = init_params(self.cfg,
                                  jax.random.PRNGKey(self.spec.seed))
        cfg = self.cfg
        self._prefill_fn = jax.jit(
            lambda p, b, c: model_mod.prefill_with_cache(cfg, p, b, c))
        self._decode_fn = jax.jit(
            lambda p, b, c: model_mod.decode_step(cfg, p, b, c))
        if cfg.family == "encdec":
            self._encode_fn = jax.jit(
                lambda p, f: model_mod.encode(cfg, p, f))
        if self.paged:
            reason = model_mod.paged_unsupported_reason(cfg)
            if reason is not None:
                raise NotImplementedError(
                    f"paged KV cache unsupported: {reason}. Serve this "
                    "family with the dense merge_caches engine "
                    "(ServeEngine(..., paged=False)) instead.")
        self._built = True
        return self

    def _batch_axes(self, init_fn):
        """Per-leaf cache batch axes for ``batching.merge_caches``,
        discovered ONCE per engine build (eval_shape traces the whole cache
        pytree twice; re-running it for every slot count repaid that on
        every ``_serving_fns`` build). Fails fast naming both admission
        paths so an axis-ambiguous cache layout points at its options."""
        if self._cache_axes is None:
            try:
                self._cache_axes = batching.cache_batch_axes(init_fn)
            except ValueError as e:
                raise ValueError(
                    "cache batch-axis discovery failed for family "
                    f"{self.cfg.family!r}: {e}. The DENSE engine admits by "
                    "row-splicing with batching.merge_caches and needs "
                    "these axes; the PAGED engine (ServeEngine(..., "
                    "paged=True)) admits through the block table instead "
                    "and never calls merge_caches — but it only supports "
                    "families where models.paged_unsupported_reason(cfg) "
                    "is None.") from e
        return self._cache_axes

    def _warmup(self, tag, fn, *args):
        """Compile outside the timed region, once per argument-shape
        signature (the fns are pure — discarding outputs is side-effect
        free). Steady-state calls pay exactly one execution."""
        import jax
        sig = (tag, str(jax.tree.map(lambda x: getattr(x, "shape", None),
                                     args)))
        if sig not in self._warm:
            jax.block_until_ready(fn(*args))
            self._warm.add(sig)

    # -- public API --------------------------------------------------------

    def encode(self, frames):
        """Encoder memory for enc-dec archs (public — no private
        ``model._run_encoder`` reach-through)."""
        self.build()
        if self.cfg.family != "encdec":
            raise ValueError(
                f"encode() is for encdec archs, not {self.cfg.family!r}")
        return self._encode_fn(self.params, frames)

    def prefill(self, prompts, *, extras: Optional[Dict[str, Any]] = None):
        """Fill the decode cache from ``prompts`` [B, S] in one fused pass.

        ``extras`` carries the family side-inputs (``frames`` for enc-dec,
        ``patches`` for VLM); missing ones are synthesised as zeros so every
        arch serves out of the box. Returns the last-position logits and
        records prefill timing."""
        import jax
        import jax.numpy as jnp
        from repro.models import init_cache

        self.build()
        B, S = prompts.shape
        vlm_prefix = self.cfg.vlm.num_patches if self.cfg.vlm else 0
        cache = init_cache(self.cfg, B, self.cache_len + vlm_prefix)
        batch = {"tokens": jnp.asarray(prompts)}
        batch.update(extras or {})
        if self.cfg.family == "encdec" and "frames" not in batch:
            e = self.cfg.encdec
            batch["frames"] = jnp.zeros(
                (B, max(1, S // e.frame_rate_divisor), e.frontend_dim),
                jnp.dtype(self.cfg.dtype))
        if self.cfg.family == "vlm" and "patches" not in batch:
            v = self.cfg.vlm
            batch["patches"] = jnp.zeros((B, v.num_patches, v.vision_dim),
                                         jnp.dtype(self.cfg.dtype))

        # warm the jit cache first so the timed call measures execution,
        # not trace+compile (same methodology as benchmarks/decode_bench)
        self._warmup("prefill", self._prefill_fn, self.params, batch, cache)
        t0 = time.time()
        logits, self.cache = jax.block_until_ready(
            self._prefill_fn(self.params, batch, cache))
        self.prefill_s = time.time() - t0
        self.prefill_tok_s = B * S / max(self.prefill_s, 1e-9)
        return logits

    def decode(self, logits, n: Optional[int] = None):
        """Batched sampling loop from the prefilled cache. Greedy when
        temperature == 0, categorical otherwise. Returns tokens [B, n]."""
        import jax
        import jax.numpy as jnp
        import numpy as np

        if self.cache is None:
            raise RuntimeError("call prefill() before decode()")
        n = self.gen if n is None else n
        key = jax.random.PRNGKey(self.spec.seed + 1)
        tok = jnp.argmax(logits, -1)
        # warm the decode compile outside the timed loop (decode_step is
        # pure — discarding the outputs leaves self.cache untouched)
        self._warmup("decode", self._decode_fn, self.params, {"token": tok},
                     self.cache)
        out = []
        t0 = time.time()
        for _ in range(n):
            # buffer DEVICE-side: np.asarray(tok) here would force a host
            # sync per token inside the timed loop
            out.append(tok)
            logits, self.cache = self._decode_fn(
                self.params, {"token": tok}, self.cache)
            if self.temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(
                    sub, logits.astype(jnp.float32) / self.temperature, -1)
            else:
                tok = jnp.argmax(logits, -1)
        jax.block_until_ready(logits)
        self.decode_s = time.time() - t0
        self.decode_tok_s = len(out) * logits.shape[0] / max(self.decode_s, 1e-9)
        return np.asarray(jnp.stack(out, 1))     # ONE transfer, post-timing

    def generate(self, prompts=None,
                 extras: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        """End-to-end: (synthetic) prompts -> fused prefill -> batched
        decode. ``extras`` forwards family side-inputs (frames/patches) to
        prefill. Returns tokens and both serving throughput metrics."""
        import jax.numpy as jnp
        from repro.data.synthetic import make_lm_data

        self.build()
        if prompts is None:
            toks = make_lm_data(self.cfg.vocab_size,
                                self.batch * self.prompt_len + 1,
                                seed=self.spec.seed)
            prompts = jnp.asarray(
                toks[:self.batch * self.prompt_len]
                .reshape(self.batch, self.prompt_len) % self.cfg.vocab_size)
        logits = self.prefill(prompts, extras=extras)
        tokens = self.decode(logits)
        B, S = prompts.shape
        self._log(
            f"prefill: {S} tokens x batch {B} in {self.prefill_s:.2f}s "
            f"({self.prefill_tok_s:.1f} tok/s); "
            f"decode: {tokens.shape[1]} tokens x batch {B} in "
            f"{self.decode_s:.2f}s ({self.decode_tok_s:.1f} tok/s)")
        return {"tokens": tokens, "prompts": prompts,
                "prefill_s": self.prefill_s,
                "prefill_tok_s": self.prefill_tok_s,
                "decode_s": self.decode_s,
                "decode_tok_s": self.decode_tok_s}

    # -- continuous batching ------------------------------------------------

    _SLOT_FAMILIES = ("dense", "moe", "vlm")

    def _serving_fns(self, n_slots: int):
        """Build (once per slot count) the two jitted serving functions:

        ``admit``  — ragged prefill of the admission batch into a FRESH
                     cache, per-row spliced into the live cache
                     (``merge_caches``), first token sampled per admitted
                     row, sampling keys re-seeded from the request id (so a
                     request's stream never depends on its co-residents);
        ``step``   — one ``decode_step(..., ragged=True)`` + per-row
                     sampling.

        Both are shape-static: every serve() call with the same slot count
        reuses the same executables — admission never retraces (sampling
        temperature / top-k are runtime [B] data, not trace constants)."""
        key = (n_slots, self.prompt_len, self.gen)
        if key in self._serving:
            return self._serving[key]
        import jax
        import jax.numpy as jnp
        from repro.models import init_cache
        from repro.models import model as model_mod

        cfg = self.cfg
        B, S_pad = n_slots, self.prompt_len
        cache_len = self.cache_len           # honor the constructor override
        if cache_len < S_pad + self.gen:
            raise ValueError(
                f"cache_len={cache_len} cannot hold prompt_len={S_pad} + "
                f"gen={self.gen} (a row would overflow its slot)")
        vlm_prefix = cfg.vlm.num_patches if cfg.vlm else 0
        init_fn = lambda b: init_cache(cfg, b, cache_len + vlm_prefix)
        axes = self._batch_axes(init_fn)
        base_key = jax.random.PRNGKey(self.spec.seed + 1)
        sample = _sampler()

        def admit(params, prompts, lengths, mask, rids, tok, cache, keys,
                  temps, topks):
            b = {"tokens": prompts, "lengths": lengths}
            if cfg.family == "vlm":
                v = cfg.vlm
                b["patches"] = jnp.zeros((B, v.num_patches, v.vision_dim),
                                         jnp.dtype(cfg.dtype))
            logits, filled = model_mod.prefill_with_cache(cfg, params, b,
                                                          init_fn(B))
            cache = batching.merge_caches(cache, filled, mask, axes)
            fresh_keys = jax.vmap(
                lambda r: jax.random.fold_in(base_key, r))(rids)
            keys = jnp.where(mask[:, None], fresh_keys, keys)
            tok0, keys2 = sample(logits, keys, temps, topks)
            keys = jnp.where(mask[:, None], keys2, keys)
            tok = jnp.where(mask, tok0, tok)
            return tok, cache, keys

        def admit_chunk(params, tails, lengths, hist, mask, rids, tok,
                        cache, keys, temps, topks):
            # chunked prefill directly on the LIVE dense cache: each row
            # advances its tail (absolute positions hist..lengths); rows
            # with (lengths, hist) = (len, len) carry an empty tail — no
            # writes, length preserved. ``mask`` marks rows landing their
            # FINAL chunk: only those re-seed their key stream and sample
            # their first token.
            b = {"tokens": tails, "lengths": lengths, "hist": hist}
            logits, cache = model_mod.prefill_with_cache(cfg, params, b,
                                                         cache)
            fresh_keys = jax.vmap(
                lambda r: jax.random.fold_in(base_key, r))(rids)
            keys = jnp.where(mask[:, None], fresh_keys, keys)
            tok0, keys2 = sample(logits, keys, temps, topks)
            keys = jnp.where(mask[:, None], keys2, keys)
            tok = jnp.where(mask, tok0, tok)
            return tok, cache, keys

        def step(params, tok, cache, keys, temps, topks):
            logits, cache = model_mod.decode_step(cfg, params, {"token": tok},
                                                  cache, ragged=True)
            tok, keys = sample(logits, keys, temps, topks)
            return tok, cache, keys

        def step_active(params, tok, cache, keys, temps, topks, active):
            # chunked-mode decode: rows with active=False (mid-prefill)
            # drop their cache write and keep their length frozen
            logits, cache = model_mod.decode_step(
                cfg, params, {"token": tok, "active": active}, cache,
                ragged=True)
            tok, keys = sample(logits, keys, temps, topks)
            return tok, cache, keys

        health_fn = rsl.row_health_fn(axes)

        def sync(tok, cache):
            # the fused per-iteration host readback: sampled tokens (eos /
            # streaming) and row health (quarantine) in ONE [2, B] transfer
            return jnp.stack([tok, health_fn(cache).astype(jnp.int32)])

        fns = {"admit": jax.jit(admit), "step": jax.jit(step),
               "admit_chunk": jax.jit(admit_chunk),
               "step_active": jax.jit(step_active),
               "sync": jax.jit(sync),
               "init": init_fn, "base_key": base_key, "axes": axes,
               # resilience pair: [B] row health + NaN row poisoning (the
               # quarantine detector and its chaos-test driver)
               "health": jax.jit(health_fn),
               "poison": jax.jit(rsl.poison_rows_fn(axes))}
        self._serving[key] = fns
        return fns

    def _paged_setup(self, n_slots: int) -> Dict[str, Any]:
        """The persistent paged-serving state: the BlockPool allocator, the
        device block-pool cache, and the host mirrors of the table and
        per-row lengths. Persisting it across serve() calls is what keeps
        the prefix cache warm; a changed slot count / pool geometry rebuilds
        it (and drops the cached prefixes)."""
        import numpy as np
        from repro.engine import paging
        from repro.models import model as model_mod

        bs = self.kv_block_size
        cache_len_p = paging.round_up(self.cache_len, bs)
        nb_max = cache_len_p // bs
        pool_blocks = self.kv_pool_blocks or n_slots * nb_max
        st = self._paged_state
        if st is not None and (st["B"], st["bs"], st["pool_blocks"]) == \
                (n_slots, bs, pool_blocks):
            if st["cache"] is None:     # woken from pool_sleep(level=2)
                st["cache"] = model_mod.init_paged_cache(
                    self.cfg, n_slots, pool_blocks, bs, cache_len_p)
            return st
        if st is not None:
            self._log("paged: pool geometry changed — rebuilding the block "
                      "pool (cached prefixes dropped)")
        pool = paging.BlockPool(pool_blocks, bs,
                                prefix_cache=self.prefix_cache)
        cache = model_mod.init_paged_cache(self.cfg, n_slots, pool_blocks,
                                           bs, cache_len_p)
        st = {"B": n_slots, "bs": bs, "nb_max": nb_max,
              "pool_blocks": pool_blocks, "pool": pool, "cache": cache,
              "table": np.full((n_slots, nb_max), pool_blocks, np.int32),
              "row_len": np.zeros((n_slots,), np.int64)}
        self._paged_state = st
        return st

    def pool_sleep(self, level: int = 2) -> None:
        """Put the persistent paged-serving state to sleep between serve()
        calls. Level 1 drops the prefix registry (occupancy goes to zero;
        the device KV arrays stay allocated); level 2 additionally FREES
        the device pool cache, so during a rollout train phase KV memory
        and optimizer state never coexist at peak — the next serve() call
        re-allocates the pool and re-prefills. Either level invalidates
        every cached prefix, which is also a correctness requirement after
        a weight push: registered blocks hold KV activations of the OLD
        parameters. No-op when no paged state exists yet."""
        if level not in (1, 2):
            raise ValueError(f"pool_sleep level={level}; expected 1 or 2")
        st = self._paged_state
        if st is None:
            return
        st["pool"].sleep()
        st["table"][:] = st["pool_blocks"]
        st["row_len"][:] = 0
        if level == 2:
            st["cache"] = None
        self.events.append("pool_sleep", 0, level=level,
                           pool_blocks=st["pool_blocks"])

    def _serving_fns_paged(self, n_slots: int, nb_max: int):
        """Paged twins of ``_serving_fns`` (built once per slot count):

        ``admit_fresh``  — ragged prefill of admissions with NO cached
                           prefix into a TRANSIENT dense cache of
                           round_up(S, block) positions, block-scattered
                           into the pool through the table
                           (``paging.scatter_prefill``). The prefill
                           numerics are the dense engine's — this path is
                           bitwise-identical to dense serving. Retraces
                           once per prompt width (normal admissions at
                           prompt_len; sleep-level-2 wakes at
                           prompt_len + gen).
        ``admit_shared`` — prefix-cache hits: prefill only the ragged TAIL
                           (positions hist..len) through the model's paged
                           prefill; the shared prefix is read from already
                           written (refcounted) blocks.
        ``step``         — one decode step; the block table rides inside
                           the cache pytree.
        ``gather/wake/copy`` — offload payload readout, sleep-level-1
                           restore, and the CoW block copy.
        ``health/poison``  — paged twins of the resilience pair (pool
                           leaves have no batch axis, so the dense
                           axes-based fns cannot see rows)."""
        key = ("paged", n_slots, self.prompt_len, self.gen,
               self.kv_block_size, nb_max)
        if key in self._serving:
            return self._serving[key]
        import jax
        import jax.numpy as jnp
        from repro.engine import paging
        from repro.models import init_cache
        from repro.models import model as model_mod

        cfg = self.cfg
        B, bs = n_slots, self.kv_block_size
        base_key = jax.random.PRNGKey(self.spec.seed + 1)
        sample = _sampler()

        def resample(logits, mask, rids, tok, keys, temps, topks):
            fresh_keys = jax.vmap(
                lambda r: jax.random.fold_in(base_key, r))(rids)
            keys = jnp.where(mask[:, None], fresh_keys, keys)
            tok0, keys2 = sample(logits, keys, temps, topks)
            keys = jnp.where(mask[:, None], keys2, keys)
            tok = jnp.where(mask, tok0, tok)
            return tok, keys

        def admit_fresh(params, prompts, lengths, mask, rids, tok, cache,
                        keys, temps, topks):
            S = prompts.shape[1]
            dense = init_cache(cfg, B, paging.round_up(S, bs))
            b = {"tokens": prompts, "lengths": lengths}
            logits, filled = model_mod.prefill_with_cache(cfg, params, b,
                                                          dense)
            cache = paging.scatter_prefill(cache, filled, mask)
            tok, keys = resample(logits, mask, rids, tok, keys, temps, topks)
            return tok, cache, keys

        def admit_shared(params, tails, lengths, hist, mask, rids, tok,
                         cache, keys, temps, topks):
            # non-admitted rows carry (lengths, hist) = (cur_len, cur_len)
            # — empty tail, every write trash-redirected, length preserved
            b = {"tokens": tails, "lengths": lengths, "hist": hist}
            logits, cache = model_mod.prefill_with_cache(cfg, params, b,
                                                         cache)
            tok, keys = resample(logits, mask, rids, tok, keys, temps, topks)
            return tok, cache, keys

        def step(params, tok, cache, keys, temps, topks):
            logits, cache = model_mod.decode_step(cfg, params,
                                                  {"token": tok}, cache,
                                                  ragged=True)
            tok, keys = sample(logits, keys, temps, topks)
            return tok, cache, keys

        def step_active(params, tok, cache, keys, temps, topks, active):
            # chunked-mode decode: inactive (mid-prefill) rows write to the
            # trash block and keep their length frozen
            logits, cache = model_mod.decode_step(
                cfg, params, {"token": tok, "active": active}, cache,
                ragged=True)
            tok, keys = sample(logits, keys, temps, topks)
            return tok, cache, keys

        def sync(tok, cache):
            # fused host readback: tokens + row health in ONE [2, B] pull
            return jnp.stack(
                [tok, paging.paged_row_health(cache).astype(jnp.int32)])

        def wake(cache, payload, idx, slot_mask, new_len, tok, last_tok,
                 keys, key_row):
            cache = paging.upload_slot(cache, payload, idx, slot_mask,
                                       new_len)
            tok = jnp.where(slot_mask, last_tok, tok)
            keys = jnp.where(slot_mask[:, None], key_row[None, :], keys)
            return cache, tok, keys

        fns = {"admit_fresh": jax.jit(admit_fresh),
               "admit_shared": jax.jit(admit_shared),
               "step": jax.jit(step),
               "step_active": jax.jit(step_active),
               "sync": jax.jit(sync),
               "gather": jax.jit(paging.gather_slot),
               "wake": jax.jit(wake),
               "copy": jax.jit(paging.copy_blocks),
               "health": jax.jit(paging.paged_row_health),
               "poison": jax.jit(paging.paged_poison_rows),
               "base_key": base_key}
        self._serving[key] = fns
        return fns

    def _reject(self, req: batching.Request, why: str) -> None:
        import numpy as np
        req.status = "rejected"
        req.error = why
        req.tokens = np.zeros((0,), np.int32)
        self.events.append("reject", req.arrival_step, rid=req.rid,
                           reason=why)
        self._log(f"request {req.rid} rejected: {why}")

    def _validate_requests(self, requests, S_pad):
        """Enqueue-time validation: a malformed request is REJECTED with a
        per-request error instead of failing the whole batch mid-loop.
        Returns the accepted requests."""
        accepted, seen = [], set()
        for r in requests:
            if r.rid in seen:
                self._reject(r, f"duplicate rid {r.rid}")
                continue
            seen.add(r.rid)
            if len(r.prompt) > S_pad or len(r.prompt) < 1:
                self._reject(r, f"prompt length {len(r.prompt)} not in "
                                f"[1, prompt_len={S_pad}]")
                continue
            if r.max_gen > self.gen or r.max_gen < 1:
                self._reject(r, f"max_gen {r.max_gen} not in "
                                f"[1, gen={self.gen}]")
                continue
            if r.deadline_steps is not None and r.deadline_steps < 1:
                self._reject(r, f"deadline_steps {r.deadline_steps} < 1")
                continue
            r.status = "queued"
            accepted.append(r)
        return accepted

    def serve(self, requests: Optional[List[batching.Request]] = None, *,
              policy: Any = None, **legacy) -> Dict[str, Any]:
        """Serve a request queue with iteration-level (continuous) batching.

        Configuration is ONE object: ``serve(policy=batching.ServePolicy(
        ...))``. The nine historical kwargs (``max_slots`` /
        ``num_requests`` / ``arrival`` / ``rate`` / ``eos_id`` /
        ``policy`` (str) / ``deadline_steps`` / ``queue_limit`` /
        ``max_steps``) remain as deprecated aliases: passing any of them
        resolves through ``batching.serve_policy_from_legacy_kwargs`` with
        ONE DeprecationWarning naming the kwargs to migrate.

        ``requests``: list of ``batching.Request``; None synthesises a
        staggered workload of ``policy.num_requests`` with the given
        ``policy.arrival`` trace ("none" | "poisson" at ``policy.rate``
        requests per decode step).

        ``ServePolicy.policy="continuous"`` admits into any freed slot the
        moment a row finishes; ``"static"`` is the fixed-batch baseline (a
        new batch is admitted only when EVERY slot is free) — same jitted
        functions, so the two are directly comparable. Beyond the
        historical step-clock behaviour, the policy adds:

        * ``prefill_chunk=N`` — chunked prefill: each admitted prompt is
          cut into N-token chunks prefilled one per scheduler iteration,
          interleaved with the co-residents' decode steps. A mid-prefill
          request has status "prefilling" and emits nothing; its token
          stream is BITWISE identical to whole-prompt admission (dense and
          paged — the paged path scatters each chunk into its blocks as it
          lands, and prefix-cache hits skip straight to the first cold
          chunk).
        * ``clock`` — "step" (the historical unit clock), "wall"
          (``time.monotonic`` seconds) or "virtual" (deterministic
          seconds, ``t * step_dt``). Seconds clocks honor
          ``Request.arrival_time`` / ``Request.deadline_s`` and
          ``ServePolicy.deadline_s``; ``watchdog_s`` arms a resilience
          ``StepWatchdog`` around each decode step and logs "slow_step"
          events (it blocks on the step's results, trading async dispatch
          for a truthful per-step latency verdict).
        * ``admission`` — "fcfs" (historical) | "slo" (earliest-deadline-
          first with feasibility culling) | any
          ``batching.AdmissionPolicy`` instance, reading queue depth and
          the run's timeout/reject counts from the admission context.
        * streaming — ``Request.on_token(rid, token, step, wall_t)`` fires
          per emitted token from the fused per-iteration host sync (the
          same single transfer that serves the eos check and the
          quarantine health pass); see :meth:`serve_stream`.

        Degradation contract (unchanged): serve() NEVER raises for a
        per-request failure. A malformed request is rejected at enqueue
        time (``status="rejected"``); deadlines expire a request — waiting
        or live — as ``status="timeout"`` with its partial tokens;
        ``queue_limit`` bounds the admission queue with explicit rejection
        at arrival; a request whose cache rows go non-finite is
        quarantined (``status="failed"``) with its co-residents bitwise
        unaffected; an exhausted ``max_steps`` budget times out every
        unfinished request instead of discarding them. Everything that
        completes normally returns ``status="ok"``.

        Returns the requests (``tokens`` + ``status`` filled), the
        scheduler event log, and throughput/latency/TTFT/goodput metrics
        (p50/p99 over requests that produced tokens)."""
        if isinstance(policy, batching.ServePolicy):
            if legacy:
                raise TypeError(
                    "serve(policy=ServePolicy(...)) does not combine with "
                    f"the deprecated kwargs {sorted(legacy)} — set those "
                    "fields on the ServePolicy instead")
            sp = policy
        else:
            if policy is not None:
                legacy["policy"] = policy
            sp = batching.serve_policy_from_legacy_kwargs(**legacy)
        return self._serve_impl(requests, sp)

    def serve_stream(self, requests: Optional[List[batching.Request]] = None,
                     *, policy: Any = None, **legacy):
        """Run :meth:`serve` on a background thread and yield ``(rid,
        token)`` pairs live, in emission order (the launcher's
        ``--stream`` path). The generator's return value
        (``StopIteration.value``) is serve()'s full result dict.

        Greedy rows are bitwise identical with or without streaming: the
        hook only OBSERVES the fused per-iteration host copy of the
        sampled tokens — it adds no device transfer and feeds nothing back
        into the jitted fns."""
        import queue
        import threading

        q: "queue.Queue" = queue.Queue()
        sentinel = object()
        box: Dict[str, Any] = {}

        def run():
            prev = self._stream_cb
            try:
                self._stream_cb = lambda rid, tok, step, wt: q.put((rid,
                                                                    tok))
                box["result"] = self.serve(requests, policy=policy,
                                           **legacy)
            except BaseException as e:       # surfaced to the consumer
                box["error"] = e
            finally:
                self._stream_cb = prev
                q.put(sentinel)

        th = threading.Thread(target=run, daemon=True)
        th.start()
        while True:
            item = q.get()
            if item is sentinel:
                break
            yield item
        th.join()
        if "error" in box:
            raise box["error"]
        return box["result"]

    def _serve_impl(self, requests: Optional[List[batching.Request]],
                    sp: "batching.ServePolicy") -> Dict[str, Any]:
        import jax
        import jax.numpy as jnp
        import numpy as np

        self.build()
        policy, eos_id = sp.policy, sp.eos_id
        deadline_steps, queue_limit = sp.deadline_steps, sp.queue_limit
        max_steps, prefill_chunk = sp.max_steps, sp.prefill_chunk
        clock, step_dt = sp.clock, sp.step_dt
        if self.cfg.family not in self._SLOT_FAMILIES:
            raise NotImplementedError(
                f"continuous batching serves attention-cache families "
                f"{self._SLOT_FAMILIES}, not {self.cfg.family!r} (a "
                f"recurrent prefill state would absorb ragged pad tails)")
        if eos_id is not None and not (0 <= eos_id < self.cfg.vocab_size):
            raise ValueError(
                f"eos_id={eos_id} outside the vocab [0, "
                f"{self.cfg.vocab_size}) — no request could ever emit it")
        if prefill_chunk:
            if self.cfg.family not in ("dense", "moe"):
                raise NotImplementedError(
                    f"chunked prefill supports dense/moe decoder stacks, "
                    f"not {self.cfg.family!r} (the VLM patch prefix is "
                    "prefilled in one piece)")
            if self.cfg.attn_window:
                raise NotImplementedError(
                    f"chunked prefill with attn_window="
                    f"{self.cfg.attn_window}: ring-buffer windows prefill "
                    "whole-prompt")
        B = sp.max_slots or self.batch
        S_pad = self.prompt_len
        if requests is None:
            requests = batching.synthetic_requests(
                sp.num_requests, self.cfg.vocab_size, S_pad, self.gen,
                arrival=sp.arrival, rate=sp.rate, seed=self.spec.seed)
        if not requests:
            raise ValueError("serve() needs at least one request")
        accepted = self._validate_requests(requests, S_pad)
        # the health/quarantine pass costs one [B]-bool transfer per step,
        # so it only runs when chaos is possible (an injector is armed);
        # the machinery itself is always compiled in
        guard = self.injector is not None

        from repro.engine import paging

        paged = self.paged
        sched = batching.SlotScheduler(B)
        if paged:
            if self.cache_len < S_pad + self.gen:
                raise ValueError(
                    f"cache_len={self.cache_len} cannot hold "
                    f"prompt_len={S_pad} + gen={self.gen} (a row would "
                    f"overflow its block table)")
            st = self._paged_setup(B)
            pool, bs = st["pool"], st["bs"]
            nb_max, trash = st["nb_max"], st["pool_blocks"]
            pool.events = sched.events    # allocator log -> event replay
            pool.reset_stats()
            fns = self._serving_fns_paged(B, nb_max)
            cache = st["cache"]
            # NB: every host->device transfer of st["table"] goes through a
            # .copy() — jnp.asarray's transfer is ASYNC, and the scheduler
            # mutates st["table"] in place; handing jax the live buffer
            # races the copy against the next mutation (reads of a FUTURE
            # table: wrong/unallocated blocks, nondeterministic tokens)
            cache["table"] = jnp.asarray(st["table"].copy())
            row_len = st["row_len"]
            # a request that could never fit the pool even ALONE must be
            # rejected up front — admission would otherwise retry forever
            fits = []
            for r in accepted:
                need = -(-(len(r.prompt) + r.max_gen) // bs)
                if need > pool.num_blocks:
                    self._reject(r, f"needs {need} KV blocks > pool of "
                                    f"{pool.num_blocks}")
                else:
                    fits.append(r)
            accepted = fits
        else:
            fns = self._serving_fns(B)

        # -- clock machinery -------------------------------------------------
        # "step" counts scheduler iterations (the historical unit clock —
        # bitwise-stable for every existing test); "virtual" is the SAME
        # deterministic schedule denominated in seconds (t * step_dt);
        # "wall" reads time.monotonic. All arrival/deadline comparisons go
        # through these three helpers, so the step-clock arithmetic is
        # numerically identical to the historical integer comparisons.
        adm = batching.resolve_admission(sp.admission)
        scale = 1.0 if clock == "step" else step_dt
        unit = "steps" if clock == "step" else "s"
        _mono0 = time.monotonic()

        def clock_now():
            if clock == "wall":
                return time.monotonic() - _mono0
            return t * scale

        def arr_of(r):
            if clock != "step" and r.arrival_time is not None:
                return r.arrival_time
            return r.arrival_step * scale

        def ddl_of(r):
            """Relative deadline of ``r`` in clock units (None = none)."""
            if clock != "step" and r.deadline_s is not None:
                return r.deadline_s
            if r.deadline_steps is not None:
                return r.deadline_steps * scale
            if clock != "step" and sp.deadline_s is not None:
                return sp.deadline_s
            if deadline_steps is not None:
                return deadline_steps * scale
            return None

        timeouts_ct = rejects_ct = 0

        def admission_order(free_ct):
            """The waiting queue as the admission policy orders (and
            possibly culls) it; FCFS short-circuits to the queue itself —
            the historical behaviour, no context construction per step."""
            if type(adm) is batching.FCFSAdmission:
                return list(waiting)
            ctx = batching.AdmissionContext(
                step=t, now=cnow, free_slots=free_ct,
                queue_depth=len(waiting), prefill_chunk=prefill_chunk,
                default_deadline=(deadline_steps * scale
                                  if deadline_steps is not None else None),
                timeouts=timeouts_ct, rejects=rejects_ct, step_dt=scale,
                deadline_fn=lambda r: (
                    None if ddl_of(r) is None else arr_of(r) + ddl_of(r)))
            return adm.select(list(waiting), ctx)

        wd = rsl.StepWatchdog(sp.watchdog_s) if sp.watchdog_s else None
        # streaming hooks observe the fused host sync — their presence (or
        # eos / an armed injector) is what turns that sync on at all
        stream_hooks = (self._stream_cb is not None or
                        any(r.on_token is not None for r in accepted))
        need_sync = guard or stream_hooks or eos_id is not None

        pending = sorted(accepted, key=lambda r: (arr_of(r), r.rid))
        waiting: List[batching.Request] = []
        parked: Dict[int, paging.Parked] = {}
        tok = jnp.zeros((B,), jnp.int32)
        if not paged:
            cache = fns["init"](B)
        keys = jax.vmap(lambda i: jax.random.fold_in(fns["base_key"], i))(
            jnp.arange(B))
        # per-slot sampling controls (Request.temperature / Request.top_k
        # overrides with the engine-wide defaults) — RUNTIME [B] data fed
        # to the jitted fns, so a heterogeneous batch never retraces. The
        # .copy() before upload mirrors the table convention: jnp.asarray
        # transfers asynchronously and the host rows mutate in place.
        temp_row = np.full((B,), self.temperature, np.float32)
        topk_row = np.zeros((B,), np.int32)

        def samp():
            return jnp.asarray(temp_row.copy()), jnp.asarray(topk_row.copy())

        def set_sampling(slot, req):
            temp_row[slot] = (self.temperature if req.temperature is None
                              else req.temperature)
            topk_row[slot] = req.top_k or 0

        # compile the serving fns outside the timed loop
        zp = jnp.zeros((B, S_pad), jnp.int32)
        zl = jnp.ones((B,), jnp.int32)
        zm = jnp.zeros((B,), bool)
        zr = jnp.zeros((B,), jnp.int32)
        if paged:
            self._warmup(("serve_admit_fresh", B), fns["admit_fresh"],
                         self.params, zp, zl, zm, zr, tok, cache, keys,
                         *samp())
            if self.prefix_cache:
                self._warmup(("serve_admit_shared", B), fns["admit_shared"],
                             self.params, zp, jnp.zeros((B,), jnp.int32),
                             jnp.zeros((B,), jnp.int32), zm, zr, tok, cache,
                             keys, *samp())
        else:
            self._warmup(("serve_admit", B), fns["admit"], self.params, zp,
                         zl, zm, zr, tok, cache, keys, *samp())
        if prefill_chunk:
            ztail = jnp.zeros((B, prefill_chunk), jnp.int32)
            zi = jnp.zeros((B,), jnp.int32)
            chunk_fn = fns["admit_shared"] if paged else fns["admit_chunk"]
            self._warmup(("serve_chunk", B, prefill_chunk), chunk_fn,
                         self.params, ztail, zi, zi, zm, zr, tok, cache,
                         keys, *samp())
            self._warmup(("serve_step_active", B), fns["step_active"],
                         self.params, tok, cache, keys, *samp(),
                         jnp.ones((B,), bool))
        else:
            self._warmup(("serve_step", B), fns["step"], self.params, tok,
                         cache, keys, *samp())
        if guard:
            self._warmup(("serve_sync", B), fns["sync"], tok, cache)
        preemptions = offloads = wakes = 0
        host_syncs = emission_iters = 0
        first_emit: Dict[int, float] = {}   # rid -> clock time of 1st token
        # chunked-prefill jobs: slot -> {req, prompt, off, hist0, blocks,
        # poison}; one chunk per job advances per scheduler iteration
        prefill_jobs: Dict[int, Dict[str, Any]] = {}
        # dense chunked mode tracks every row's device cache length on the
        # host (chunk calls must pass passenger rows their EXACT length);
        # decode increments all active rows, chunks set their row
        dense_len = np.zeros((B,), np.int64)

        def release_slot_resources(slot, upload=True):
            """THE terminal choke point: every path that frees a slot —
            completion, deadline eviction, quarantine, truncation,
            preemption — funnels through here, so the paged pool can never
            leak blocks from an exit path. Dense mode has no per-slot
            resources beyond the scheduler's own bookkeeping.
            ``upload=False`` defers the host->device table refresh so a
            loop releasing several slots can upload once afterwards."""
            temp_row[slot] = self.temperature
            topk_row[slot] = 0
            prefill_jobs.pop(slot, None)
            if paged:
                pool.release_slot(slot)
                st["table"][slot] = trash
                row_len[slot] = 0
                if upload:
                    cache["table"] = jnp.asarray(st["table"].copy())

        def refresh_row(slot):
            blocks = pool.slot_blocks.get(slot, [])
            st["table"][slot] = trash
            st["table"][slot, :len(blocks)] = blocks

        def do_cow(pairs):
            nonlocal cache
            if pairs:
                src = np.full((B,), trash, np.int32)
                dst = np.full((B,), trash, np.int32)
                for i, (s, d) in enumerate(pairs):
                    src[i], dst[i] = s, d
                cache = fns["copy"](cache, jnp.asarray(src),
                                    jnp.asarray(dst))

        def park(slot, why):
            """Preempt the slot's request to host RAM. Sleep level 1 keeps
            a bitwise payload of its blocks (wake = upload + resume); level
            2 keeps only the generated token values (wake = re-prefill).
            The pending sampled token is NOT yet in the history, so on wake
            it is re-injected (level 1) or re-derived (level 2)."""
            nonlocal preemptions, offloads
            if slot in sched.prefilling:
                # a mid-prefill victim has no sampled token to re-inject
                # and its cache row is half-filled — park at level-2
                # semantics regardless of sleep_level: keep only the
                # prompt, re-chunk from scratch on wake
                prefill_jobs.pop(slot, None)
                rid = sched.preempt(slot, t)
                parked[rid] = paging.Parked(rid=rid, level=2, n_tokens=0,
                                            generated=[])
                preemptions += 1
                pool._log("page_drop", slot, rid)
                release_slot_resources(slot)
                self.events.append("preempt", t, rid=rid, slot=slot,
                                   level=2, reason=why)
                self._log(f"step {t}: mid-prefill request {rid} preempted "
                          f"from slot {slot} (level 2: {why})")
                return
            rid = sched.preempt(slot, t)
            p = paging.Parked(rid=rid, level=self.sleep_level,
                              n_tokens=int(row_len[slot]), generated=[])
            if self.sleep_level == 1:
                payload = fns["gather"](cache,
                                        jnp.asarray(st["table"][slot].copy()))
                p.payload = jax.tree.map(np.asarray, payload)
                p.last_token = int(np.asarray(tok)[slot])
                p.key_row = np.asarray(keys)[slot]
                offloads += 1
                pool._log("page_offload", slot, rid)
            else:
                # the wake re-prefills prompt + generated, so only the
                # token VALUES survive; this is the rare path, so the host
                # sync of the slot's history rows is acceptable
                for h, s, c in sched.token_segments(rid):
                    if c:
                        seg = np.asarray(jnp.stack(history[h:h + c]))[:, s]
                        p.generated.extend(int(x) for x in seg)
                pool._log("page_drop", slot, rid)
            parked[rid] = p
            preemptions += 1
            release_slot_resources(slot)
            self.events.append("preempt", t, rid=rid, slot=slot,
                               level=self.sleep_level, reason=why)
            self._log(f"step {t}: request {rid} preempted from slot {slot} "
                      f"to host RAM (sleep level {self.sleep_level}: {why})")

        def try_wake_level1(p) -> bool:
            nonlocal cache, tok, keys, wakes
            free_now = sched.free_slots()
            if not free_now:
                return False
            slot = free_now[0]
            try:
                pool.prepare_write(slot, max(p.n_tokens - 1, 0))
            except paging.PoolExhausted:
                pool.release_slot(slot)   # roll back the partial grab
                return False
            sched.admit(slot, sched.requests[p.rid], t, len(history),
                        resume=True)
            set_sampling(slot, sched.requests[p.rid])
            refresh_row(slot)
            row_len[slot] = p.n_tokens
            cache["table"] = jnp.asarray(st["table"].copy())
            nblk = -(-p.n_tokens // bs)
            idx = np.full((nb_max,), trash + 1, np.int32)   # OOB -> drop
            idx[:nblk] = st["table"][slot, :nblk]
            mask1 = np.zeros((B,), bool)
            mask1[slot] = True
            cache, tok, keys = fns["wake"](
                cache, jax.tree.map(jnp.asarray, p.payload),
                jnp.asarray(idx), jnp.asarray(mask1),
                jnp.int32(p.n_tokens), tok, jnp.int32(p.last_token), keys,
                jnp.asarray(p.key_row))
            wakes += 1
            pool._log("page_wake", slot, p.rid)
            self.events.append("wake", t, rid=p.rid, slot=slot, level=1)
            self._log(f"step {t}: request {p.rid} woken into slot {slot} "
                      f"(level 1: {p.n_tokens} cached tokens restored)")
            return True

        def lifo_victim():
            live = sched.live_slots()
            if not live:
                return None
            # prefer a victim with tokens to park over a mid-prefill row
            # (whose park drops all its prefill work)
            pool_ = [s for s in live if s not in sched.prefilling] or live
            return max(pool_,
                       key=lambda s: (sched.admit_step[sched.owner[s]], s))

        def quarantine(health, now):
            """Evict live rows whose cache went non-finite (``health`` is
            this iteration's fused host sync verdict). Rows are
            independent across the batch axis, so a NaN row cannot perturb
            its co-residents — the quarantine just frees the slot and
            reports the failure instead of serving garbage."""
            for slot in sched.live_slots():
                if not health[slot]:
                    rid = sched.evict(slot, t, now, "failed")
                    sched.requests[rid].status = "failed"
                    sched.requests[rid].error = ("non-finite cache rows "
                                                 "(quarantined)")
                    release_slot_resources(slot)
                    self.events.append("quarantine", t, rid=rid, slot=slot)
                    self._log(f"step {t}: request {rid} quarantined "
                              f"(non-finite cache rows)")

        def paged_poison(slots):
            """Quarantine isolation for the paged injector: give each
            poisoned row a PRIVATE copy of every block it shares (or has
            registered for future sharing) before the NaN fill — the whole
            block is NaN'd anyway, so the CoW needs no device copy — and
            fill only blocks the row exclusively owns. Co-resident rows
            and the prefix registry never see the poison. If the pool
            cannot supply a private copy, the shared block is left intact
            (un-poisoned) rather than corrupting its other readers."""
            nonlocal cache
            idx = np.full((B, nb_max), trash + 1, np.int32)
            for slot in slots:
                nblk = len(pool.slot_blocks.get(slot, []))
                for lb in range(nblk):
                    try:
                        pool.prepare_write(slot, lb * bs)
                    except paging.PoolExhausted:
                        break
                for lb, b in enumerate(pool.slot_blocks.get(slot, [])):
                    if pool.ref[b] == 1 and b not in pool.registered:
                        idx[slot, lb] = b
                refresh_row(slot)
            cache["table"] = jnp.asarray(st["table"].copy())
            cache = fns["poison"](cache, jnp.asarray(idx))

        history: List[Any] = []          # device [B] token vectors
        owners_log: List[np.ndarray] = []
        arrival_wall: Dict[int, float] = {}
        t = 0
        decode_steps = prefill_calls = admitted_mid_decode = 0
        truncated = False
        t_start = time.perf_counter()
        while pending or waiting or parked or sched.live_slots():
            if t >= max_steps:
                truncated = True         # graceful: time the stragglers
                break                    # out below instead of raising
            now = time.perf_counter()
            cnow = clock_now()
            if paged:
                pool.step = t            # stamp allocator events
            # -- arrivals (bounded admission queue) --------------------------
            n_arrived = 0
            for r in pending:
                if arr_of(r) > cnow:
                    break                # pending is sorted by arrival
                n_arrived += 1
                arrival_wall.setdefault(r.rid, now)
                if queue_limit is not None and len(waiting) >= queue_limit:
                    self._reject(r, f"admission queue full "
                                    f"(queue_limit={queue_limit})")
                    rejects_ct += 1
                else:
                    waiting.append(r)
            pending = pending[n_arrived:]
            # -- deadline expiry (waiting, then live) ------------------------
            still = []
            for r in waiting:
                d = ddl_of(r)
                if d is not None and cnow - arr_of(r) >= d:
                    r.status = "timeout"
                    r.error = f"deadline of {d:g} {unit} expired in queue"
                    r.tokens = np.zeros((0,), np.int32)
                    self.events.append("timeout", t, rid=r.rid,
                                       where="queue")
                    timeouts_ct += 1
                else:
                    still.append(r)
            waiting = still
            for slot in sched.live_slots():
                r = sched.requests[sched.owner[slot]]
                d = ddl_of(r)
                if d is not None and cnow - arr_of(r) >= d:
                    rid = sched.evict(slot, t, now, "timeout")
                    sched.requests[rid].status = "timeout"
                    sched.requests[rid].error = (f"deadline of {d:g} {unit} "
                                                 f"expired mid-decode")
                    release_slot_resources(slot)
                    self.events.append("timeout", t, rid=rid, where="slot")
                    timeouts_ct += 1
            for rid in list(parked):
                r = sched.requests[rid]
                d = ddl_of(r)
                if d is not None and cnow - arr_of(r) >= d:
                    parked.pop(rid)      # payload dropped with it
                    r.status = "timeout"
                    r.error = (f"deadline of {d:g} {unit} expired while "
                               f"parked")
                    sched.close(rid, t, now, "timeout")
                    self.events.append("timeout", t, rid=rid,
                                       where="parked")
                    timeouts_ct += 1
            # -- admissions --------------------------------------------------
            elig_ok = not (policy == "static" and sched.live_slots())
            if paged:
                was_live = bool(sched.live_slots())
                # parked level-1 wakes first: bitwise restore, no prefill
                if elig_ok:
                    for rid in list(parked):
                        if parked[rid].level == 1 and \
                                try_wake_level1(parked[rid]):
                            parked.pop(rid)
                # then level-2 resumes (re-prefill at prompt_len + gen
                # width) and fresh admissions, one block-pool plan each
                cands = []
                if elig_ok:
                    cands = [(sched.requests[rid], parked[rid])
                             for rid in list(parked)
                             if parked[rid].level == 2]
                    cands += [(r, None) for r in
                              admission_order(len(sched.free_slots()))]
                S_res = S_pad + self.gen
                plans = {}                  # (kind, width) -> [admission]
                cow_pairs, cow_pins, poison_slots = [], [], []
                admitted_rids = set()
                for req, p in cands:
                    free_now = sched.free_slots()
                    if not free_now:
                        break
                    slot = free_now[0]
                    prompt = np.asarray(req.prompt, np.int64)
                    if p is not None and p.generated:
                        prompt = np.concatenate(
                            [prompt, np.asarray(p.generated, np.int64)])
                    try:
                        hist_n, cow = pool.admit(
                            slot, prompt, pending_all=bool(prefill_chunk))
                    except paging.PoolExhausted:
                        break       # completions will free blocks; wait
                    sched.admit(slot, req, t, len(history),
                                resume=p is not None,
                                prefilling=bool(prefill_chunk))
                    set_sampling(slot, req)
                    refresh_row(slot)
                    poisoned = (self.injector is not None and
                                self.injector.fires("poison_request",
                                                    req.rid))
                    if prefill_chunk:
                        # chunked admission bypasses the plans machinery:
                        # the chunk phase below prefills positions
                        # hist_n.. one chunk per iteration (a prefix-cache
                        # hit skips straight to the first cold chunk). The
                        # full-hit CoW copy runs NOW — its source blocks
                        # already hold written content.
                        req.status = "prefilling"
                        row_len[slot] = hist_n
                        if cow:
                            do_cow([cow[:2]])
                        prefill_jobs[slot] = {
                            "req": req, "prompt": prompt, "off": hist_n,
                            "hist0": hist_n,
                            "blocks": [b for b in pool.slot_blocks[slot]
                                       if b in pool.pending],
                            "poison": poisoned}
                    else:
                        row_len[slot] = len(prompt)
                        if cow:
                            # the device copy is deferred until the
                            # source's content is valid — pin it so a
                            # later admission in this round cannot reclaim
                            # + overwrite it first
                            cow_pairs.append(cow[:2])
                            cow_pins.append(cow[0])
                            pool.pin(cow[0])
                        key2 = ("shared" if hist_n else "fresh",
                                S_pad if p is None else S_res)
                        plans.setdefault(key2, []).append(
                            (slot, req, prompt, hist_n))
                    if was_live and t > 0:
                        admitted_mid_decode += 1
                    if p is not None:
                        parked.pop(req.rid)
                        wakes += 1
                        pool._log("page_wake", slot, req.rid)
                        self.events.append("wake", t, rid=req.rid,
                                           slot=slot, level=2)
                    else:
                        admitted_rids.add(req.rid)
                    if poisoned:
                        if not prefill_chunk:
                            poison_slots.append(slot)
                        self.events.append("inject", t,
                                           site="poison_request",
                                           rid=req.rid, slot=slot)
                waiting = [r for r in waiting if r.rid not in admitted_rids]
                if plans:
                    cache["table"] = jnp.asarray(st["table"].copy())
                    # fresh admissions prefill (and REGISTER their blocks)
                    # before shared ones read them — intra-batch sharing.
                    # CoW copies run BETWEEN the two: after the fresh
                    # prefills have written every source block, before any
                    # shared prefill reads its private copy.
                    order = sorted(plans, key=lambda k: k[0] != "fresh")
                    cow_done = False
                    for kind, width in order:
                        if kind == "shared" and not cow_done:
                            do_cow(cow_pairs)
                            for b in cow_pins:
                                pool.unpin(b)
                            cow_done = True
                        items = plans[(kind, width)]
                        prompts = np.zeros((B, width), np.int32)
                        lengths = np.zeros((B,), np.int32)
                        hist_a = np.zeros((B,), np.int32)
                        mask = np.zeros((B,), bool)
                        rids = np.zeros((B,), np.int32)
                        if kind == "shared":
                            # non-admitted rows: empty tail at their own
                            # length — no writes, lengths preserved
                            lengths[:] = row_len
                            hist_a[:] = row_len
                        for slot, req, prompt, hist_n in items:
                            mask[slot] = True
                            rids[slot] = _sid(req)
                            lengths[slot] = len(prompt)
                            hist_a[slot] = hist_n
                            tail = prompt[hist_n:] if kind == "shared" \
                                else prompt
                            prompts[slot, :len(tail)] = tail
                        if kind == "fresh":
                            tok, cache, keys = fns["admit_fresh"](
                                self.params, jnp.asarray(prompts),
                                jnp.asarray(np.maximum(lengths, 1)),
                                jnp.asarray(mask), jnp.asarray(rids), tok,
                                cache, keys, *samp())
                        else:
                            tok, cache, keys = fns["admit_shared"](
                                self.params, jnp.asarray(prompts),
                                jnp.asarray(lengths), jnp.asarray(hist_a),
                                jnp.asarray(mask), jnp.asarray(rids), tok,
                                cache, keys, *samp())
                        prefill_calls += 1
                    if not cow_done:        # defensive: cow without shared
                        do_cow(cow_pairs)
                        for b in cow_pins:
                            pool.unpin(b)
                    # every planned prefill has executed: blocks registered
                    # by this round's shared-tail admissions now hold real
                    # content and become prefix-matchable again
                    pool.mark_written()
                    if poison_slots:
                        paged_poison(poison_slots)
            else:
                free = sched.free_slots()
                elig = admission_order(len(free)) if elig_ok else []
                take = min(len(free), len(elig))
                if take and prefill_chunk:
                    # chunked admission: allocate the slot and open a
                    # prefill job — the chunk phase below pushes the first
                    # chunk THIS iteration, so scheduling is unchanged
                    was_live = bool(sched.live_slots())
                    admitted_rids = set()
                    for slot, req in zip(free[:take], elig[:take]):
                        sched.admit(slot, req, t, len(history),
                                    prefilling=True)
                        req.status = "prefilling"
                        set_sampling(slot, req)
                        poisoned = (self.injector is not None and
                                    self.injector.fires("poison_request",
                                                        req.rid))
                        if poisoned:
                            self.events.append("inject", t,
                                               site="poison_request",
                                               rid=req.rid, slot=slot)
                        prefill_jobs[slot] = {
                            "req": req,
                            "prompt": np.asarray(req.prompt, np.int64),
                            "off": 0, "hist0": 0, "blocks": [],
                            "poison": poisoned}
                        admitted_rids.add(req.rid)
                        if was_live and t > 0:
                            admitted_mid_decode += 1
                    waiting = [r for r in waiting
                               if r.rid not in admitted_rids]
                elif take:
                    was_live = bool(sched.live_slots())
                    prompts = np.zeros((B, S_pad), np.int32)
                    lengths = np.ones((B,), np.int32)
                    mask = np.zeros((B,), bool)
                    rids = np.zeros((B,), np.int32)
                    poison = np.zeros((B,), bool)
                    admitted_rids = set()
                    for slot, req in zip(free[:take], elig[:take]):
                        prompts[slot, :len(req.prompt)] = req.prompt
                        lengths[slot] = len(req.prompt)
                        mask[slot] = True
                        rids[slot] = _sid(req)
                        sched.admit(slot, req, t, len(history))
                        set_sampling(slot, req)
                        admitted_rids.add(req.rid)
                        if was_live and t > 0:
                            admitted_mid_decode += 1
                        if self.injector is not None and \
                                self.injector.fires("poison_request",
                                                    req.rid):
                            poison[slot] = True
                            self.events.append("inject", t,
                                               site="poison_request",
                                               rid=req.rid, slot=slot)
                    waiting = [r for r in waiting
                               if r.rid not in admitted_rids]
                    tok, cache, keys = fns["admit"](
                        self.params, jnp.asarray(prompts),
                        jnp.asarray(lengths), jnp.asarray(mask),
                        jnp.asarray(rids), tok, cache, keys, *samp())
                    prefill_calls += 1
                    if poison.any():
                        cache = fns["poison"](cache, jnp.asarray(poison))
            # -- chunked prefill: every prefilling slot advances ONE chunk --
            # (one batched call per iteration; rows on their FINAL chunk
            # sample their first token exactly like a legacy admission, so
            # it is logged as this iteration's emission)
            if prefill_jobs:
                C = prefill_chunk
                tails = np.zeros((B, C), np.int32)
                lengths = np.zeros((B,), np.int32)
                hist_a = np.zeros((B,), np.int32)
                mask = np.zeros((B,), bool)
                rids = np.zeros((B,), np.int32)
                # passenger rows: empty tail at their own EXACT length —
                # no writes, length preserved (the paged shared-tail
                # admission convention)
                mirror = row_len if paged else dense_len
                lengths[:] = mirror
                hist_a[:] = mirror
                finals = []
                for slot, job in prefill_jobs.items():
                    prompt, off = job["prompt"], job["off"]
                    end = min(off + C, len(prompt))
                    tails[slot, :end - off] = prompt[off:end]
                    lengths[slot] = end
                    hist_a[slot] = off
                    last = end >= len(prompt)
                    mask[slot] = last
                    rids[slot] = _sid(job["req"])
                    job["off"] = end
                    if last:
                        finals.append((slot, job))
                chunk_fn = fns["admit_shared"] if paged \
                    else fns["admit_chunk"]
                if paged:
                    cache["table"] = jnp.asarray(st["table"].copy())
                tok, cache, keys = chunk_fn(
                    self.params, jnp.asarray(tails), jnp.asarray(lengths),
                    jnp.asarray(hist_a), jnp.asarray(mask),
                    jnp.asarray(rids), tok, cache, keys, *samp())
                prefill_calls += 1
                for slot, job in prefill_jobs.items():
                    mirror[slot] = job["off"]
                pmask = np.zeros((B,), bool)
                for slot, job in finals:
                    prefill_jobs.pop(slot)
                    req = job["req"]
                    sched.prefill_done(slot, t, len(history))
                    req.status = "queued"
                    if paged:
                        pool.mark_written(job["blocks"])
                    pmask[slot] = job["poison"]
                    self.events.append(
                        "prefill_done", t, rid=req.rid, slot=slot,
                        hist=job["hist0"],
                        chunks=-(-(len(job["prompt"]) - job["hist0"]) // C))
                if pmask.any():
                    if paged:
                        paged_poison([s for s, j in finals if j["poison"]])
                    else:
                        cache = fns["poison"](cache, jnp.asarray(pmask))
            # -- paged: make every live row's next write position resident --
            # (BEFORE the emission is logged: a preempted row's pending
            # token stays pending, so its wake re-injects it exactly once)
            if paged and sched.live_slots():
                cow_pairs, dirty = [], False
                for slot in list(sched.live_slots()):
                    rid = sched.owner[slot]
                    if rid is None or slot in sched.prefilling:
                        continue    # parked victim, or still mid-prefill
                    # the block is allocated even for a request completing
                    # this step (released again at completion): the decode
                    # READS the position it just wrote, so the write must
                    # land in a real exclusive block — writes redirected to
                    # the write-off path are dropped, not read back
                    while sched.owner[slot] is not None:
                        try:
                            new, cow = pool.prepare_write(
                                slot, int(row_len[slot]))
                        except paging.PoolExhausted:
                            park(lifo_victim(),   # LIFO victim — maybe self
                                 f"pool exhausted growing slot {slot}")
                            continue
                        for lb, phys in new:
                            st["table"][slot, lb] = phys
                            dirty = True
                        if cow:
                            cow_pairs.append(cow[:2])
                            st["table"][slot, cow[2]] = cow[1]
                            dirty = True
                        break
                do_cow(cow_pairs)
                if dirty:
                    cache["table"] = jnp.asarray(st["table"].copy())
            live = sched.live_slots()
            if not live:
                if not pending and not waiting and not parked:
                    break                # everything terminal: done
                if clock == "wall" and pending:
                    # real-time idle: sleep toward the next arrival instead
                    # of spinning the iteration counter
                    gap = arr_of(pending[0]) - clock_now()
                    if gap > 0:
                        time.sleep(min(gap, 0.05))
                t += 1                   # idle tick: clock runs to the next
                continue                 # arrival without touching devices
            # -- fused host sync: tokens (eos / streaming) + row health ------
            # (ONE [B]-sized transfer per iteration — never one per concern)
            host_tok = None
            if need_sync:
                if guard:
                    synced = np.asarray(fns["sync"](tok, cache))
                    host_tok, health = synced[0], synced[1].astype(bool)
                else:
                    host_tok = np.asarray(tok)
                host_syncs += 1
                if guard:
                    # quarantine at the emission point: a row poisoned at
                    # admission is evicted BEFORE its first token is logged
                    quarantine(health, time.perf_counter())
                    live = sched.live_slots()
                    if not live:
                        t += 1
                        continue
            emitting = [(s, sched.owner[s]) for s in live
                        if s not in sched.prefilling]
            if emitting:
                # -- log this iteration's emission for every emitting slot --
                history.append(tok)
                emission_iters += 1
                owners = np.full((B,), -1, np.int64)
                for s, rid in emitting:
                    owners[s] = rid
                    first_emit.setdefault(rid, cnow)
                owners_log.append(owners)
                eos_hit = None
                if eos_id is not None:
                    eos_hit = [bool(host_tok[s] == eos_id)
                               for s in range(B)]
                done_now = sched.log_emissions(t, time.perf_counter(),
                                               eos_hit)
                if host_tok is not None and stream_hooks:
                    # streaming observes the host copy only — nothing
                    # feeds back into the jitted fns
                    for s, rid in emitting:
                        req = sched.requests[rid]
                        tkn = int(host_tok[s])
                        if req.on_token is not None:
                            req.on_token(rid, tkn, t, cnow)
                        if self._stream_cb is not None:
                            self._stream_cb(rid, tkn, t, cnow)
                for s in done_now:           # completion frees the blocks;
                    release_slot_resources(s, upload=False)
                if paged and done_now:       # ONE table upload per step,
                    cache["table"] = jnp.asarray(st["table"].copy())
            # -- one ragged decode step for the whole slot batch -------------
            # (only when an emitting row still needs it: a freshly admitted
            # request's first token comes from the prefill, not step; a
            # mid-prefill row neither emits nor decodes)
            live_now = [s for s in sched.live_slots()
                        if s not in sched.prefilling]
            if live_now:
                if wd is not None:
                    wd.arm(t)
                if prefill_chunk:
                    # mid-prefill rows are INACTIVE: their cache writes are
                    # dropped and their lengths stay frozen
                    act = np.ones((B,), bool)
                    for s in prefill_jobs:
                        act[s] = False
                    tok, cache, keys = fns["step_active"](
                        self.params, tok, cache, keys, *samp(),
                        jnp.asarray(act))
                    if not paged:
                        dense_len[act] += 1
                else:
                    tok, cache, keys = fns["step"](self.params, tok, cache,
                                                   keys, *samp())
                decode_steps += 1
                if wd is not None:
                    # the watchdog's verdict needs the step's results on
                    # the host — opting in trades async dispatch for a
                    # truthful per-step latency reading
                    jax.block_until_ready(tok)
                    over = wd.expired()
                    if over is not None:
                        self.events.append("slow_step", t,
                                           elapsed_s=round(over, 6),
                                           timeout_s=wd.timeout_s)
                    wd.disarm()
                if paged:
                    for s in live_now:
                        row_len[s] += 1
            t += 1
        jax.block_until_ready(tok)
        wall = time.perf_counter() - t_start

        if truncated:
            now = time.perf_counter()
            for slot in sched.live_slots():
                rid = sched.evict(slot, t, now, "timeout")
                sched.requests[rid].status = "timeout"
                sched.requests[rid].error = f"max_steps={max_steps} exhausted"
                release_slot_resources(slot)
                self.events.append("timeout", t, rid=rid, where="max_steps")
            for rid in list(parked):
                parked.pop(rid)          # payload dropped with it
                r = sched.requests[rid]
                r.status = "timeout"
                r.error = f"max_steps={max_steps} exhausted"
                sched.close(rid, t, now, "timeout")
                self.events.append("timeout", t, rid=rid, where="max_steps")
            for r in waiting + pending:
                r.status = "timeout"
                r.error = f"max_steps={max_steps} exhausted"
                r.tokens = np.zeros((0,), np.int32)
                self.events.append("timeout", t, rid=r.rid,
                                   where="max_steps")
            self._log(f"serve[{policy}]: max_steps={max_steps} exhausted — "
                      f"returning partial results")

        hist = (np.asarray(jnp.stack(history))
                if history else np.zeros((0, B), np.int32))   # ONE transfer
        for rid, req in sched.requests.items():
            # a request's stream may span several (history, slot) segments
            # when the paged pool preempted and resumed it
            parts = [hist[h:h + c, s]
                     for h, s, c in sched.token_segments(rid)]
            req.tokens = (np.concatenate(parts).astype(np.int32) if parts
                          else np.zeros((0,), np.int32))
            if req.status == "queued":   # untouched by evict/timeout paths
                req.status = "ok"

        done = [r for r in requests
                if r.rid in sched.complete_time and r.rid in arrival_wall]
        lat_s = np.array([sched.complete_time[r.rid] - arrival_wall[r.rid]
                          for r in done]) if done else np.zeros((0,))
        lat_steps = np.array([sched.complete_step[r.rid] - r.arrival_step
                              for r in done]) if done else np.zeros((0,))
        pct = lambda a, q: round(float(np.percentile(a, q)), 4) \
            if len(a) else 0.0
        status_counts: Dict[str, int] = {}
        for r in requests:
            status_counts[r.status] = status_counts.get(r.status, 0) + 1
        total = int(sum(sched.gen_done.values()))
        metrics = {
            "policy": policy, "n_requests": len(requests),
            "n_slots": B, "total_generated": total,
            "wall_s": round(wall, 4),
            "decode_tok_s": round(total / max(wall, 1e-9), 2),
            "decode_steps": decode_steps, "prefill_calls": prefill_calls,
            "admitted_mid_decode": admitted_mid_decode,
            "status_counts": status_counts,
            "truncated": truncated,
            "latency_s": {"p50": pct(lat_s, 50), "p99": pct(lat_s, 99),
                          "mean": round(float(lat_s.mean()), 4)
                          if len(lat_s) else 0.0},
            "latency_steps": {"p50": pct(lat_steps, 50),
                              "p99": pct(lat_steps, 99)},
        }
        # wall-clock serving metrics: TTFT is first-token latency in clock
        # units (steps on the step clock, seconds on wall/virtual);
        # goodput is the fraction of requests that finished "ok" — i.e.
        # inside their deadline, since expiry flips status to "timeout"
        ttft_vals = np.array([first_emit[r.rid] - arr_of(r)
                              for r in requests if r.rid in first_emit])
        metrics.update({
            "clock": clock,
            "admission": adm.name,
            "prefill_chunk": prefill_chunk,
            "host_syncs": host_syncs,
            "emission_iters": emission_iters,
            "goodput": round(status_counts.get("ok", 0) / len(requests), 4),
            "ttft": {"p50": pct(ttft_vals, 50), "p99": pct(ttft_vals, 99)},
        })
        if paged:
            st["cache"] = cache          # persist: the prefix cache stays
            lookup = pool.prefix_lookup_tokens
            metrics["paging"] = {
                "pool_blocks": pool.num_blocks,
                "block_size": bs,
                "blocks_in_use_peak": pool.in_use_peak,
                "prefix_hit_rate": round(
                    pool.prefix_hit_tokens / lookup, 4) if lookup else 0.0,
                "prefill_tokens_requested": lookup,
                "marginal_prefill_tokens": lookup - pool.prefix_hit_tokens,
                "preemptions": preemptions,
                "offloads": offloads,
                "wakes": wakes,
                "cow_copies": pool.cow_copies,
                "sleep_level": self.sleep_level,
                "prefix_cache": self.prefix_cache,
            }
            pg = metrics["paging"]
            self._log(
                f"serve[paged]: {pg['pool_blocks']} blocks x "
                f"{pg['block_size']} tok, peak {pg['blocks_in_use_peak']} "
                f"in use, prefix hit rate {pg['prefix_hit_rate']}, "
                f"{pg['marginal_prefill_tokens']}/"
                f"{pg['prefill_tokens_requested']} prefill tokens computed, "
                f"{preemptions} preemptions ({offloads} offloads, "
                f"{wakes} wakes)")
        self._log(
            f"serve[{policy}]: {len(requests)} requests over {B} slots in "
            f"{wall:.2f}s — {metrics['decode_tok_s']} tok/s, "
            f"{decode_steps} decode steps, {prefill_calls} admission "
            f"prefills ({admitted_mid_decode} requests admitted mid-decode), "
            f"status {status_counts}, "
            f"latency p50/p99 {metrics['latency_s']['p50']}/"
            f"{metrics['latency_s']['p99']}s")
        return {"requests": sorted(requests, key=lambda r: r.rid),
                "events": sched.events, "owners_log": owners_log,
                "scheduler": sched, "metrics": metrics,
                "engine_events": self.events}
