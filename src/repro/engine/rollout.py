"""RolloutEngine: an RL rollout loop where train and serve time-share one
device.

The paper's core move (Sec. 4) is sharing accelerators by staggering
execution so peak working sets never coincide; this subsystem applies the
same discipline to the self-improvement workload: ONE process, ONE device
pool, alternating *generate -> score -> train -> push weights* phases.

  * **generate** — ``ServeEngine.serve()`` continuous batching over the
    paged KV cache. Each trajectory group samples the SAME prompt under
    per-request seeds/temperatures (``batching.Request`` sampling fields),
    so group members share their prompt's prefix blocks and diverge only
    in their sampled continuations.
  * **score** — the steerable synthetic reward (``data.synthetic``) plus
    one jitted forward on the BEHAVIOUR params filling per-token logprobs
    (the hook for importance-sampling corrections when training on stale
    weights); group-relative advantages come from ``engine.trajectory``.
  * **train** — one REINFORCE step through ``TrainEngine.step_external``
    under any registered ParallelPlan, including ``zero_cdp`` (the
    stage-sharded f32 masters stay sharded; the policy gradient flows
    through the same streamed ring as LM training). Before the step the
    serve pool drops to sleep level 2 (``ServeEngine.pool_sleep``): KV
    memory and optimizer state never coexist at peak.
  * **push** — the new params are handed to the serve engine DEVICE-SIDE:
    one compiled cast (stage-sharded plans all-gather via
    ``zero_cdp.unchunk_params`` inside the same program) whose destination
    donates the old serve params. The call runs under
    ``jax.transfer_guard("disallow")``, so a host round-trip of any
    parameter array is an error, not a slowdown.

Phase boundaries and durations land in ``engine.events`` (kind
``"phase"``, monotonic ``t`` timestamps) — auditable offline via
``EventLog.to_jsonl``.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional

from repro.engine import batching
from repro.engine import resilience as rsl
from repro.engine.spec import RunSpec
from repro.engine.trajectory import (Trajectory, TrajectoryGroup,
                                     reinforce_batch)

PyTree = Any

#: families the rollout loop serves (forward needs no side inputs)
ROLLOUT_FAMILIES = ("dense", "moe")


def reinforce_loss_fn(cfg):
    """The policy-gradient loss TrainEngine's jitted step runs: masked
    group-relative REINFORCE over a ``reinforce_batch``. The
    log-probability gather uses the same one-hot contraction as
    ``models.model._xent`` (tensor-parallel friendly: no gather along a
    vocab-sharded dim), the mask confines credit to generated-token
    targets, and the MoE aux loss rides along so load balancing survives
    RL fine-tuning."""
    import jax
    import jax.numpy as jnp
    from repro.models import model as model_mod

    def loss_fn(params, batch):
        logits, aux, _ = model_mod.forward(cfg, params,
                                           {"tokens": batch["tokens"]})
        lg = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        tgt = batch["targets"]
        onehot = (tgt[..., None] == jax.lax.broadcasted_iota(
            jnp.int32, tgt.shape + (lg.shape[-1],), tgt.ndim))
        ll = jnp.sum(jnp.where(onehot, lg, 0.0), axis=-1) - lse   # [B, T]
        mask = batch["mask"]
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        pg = -jnp.sum(batch["adv"][:, None] * ll * mask) / denom
        loss = pg + aux
        return loss, {"loss": loss, "pg": pg,
                      "logp_gen": jnp.sum(ll * mask) / denom}
    return loss_fn


class RolloutEngine:
    """One-process RL rollout loop over the existing engines.

        spec = RunSpec(arch="stablelm-1.6b", reduced=True)
        eng = RolloutEngine(spec, plan="dp", groups=2, group_size=4)
        history = eng.run(iters=3)     # mean reward rises on the way

    ``reward_fn(prompt, tokens) -> float`` scores one trajectory; the
    default is the steerable ``data.synthetic.token_range_reward`` whose
    optimum is known, so reward MUST rise under a correct policy-gradient
    step. ``groups * group_size`` is the train batch B and must divide the
    data mesh axis evenly (the jitted step shards the batch over it)."""

    def __init__(self, spec: RunSpec, *,
                 plan=None,                    # ParallelPlan | name | None
                 reward_fn: Optional[Callable] = None,
                 groups: int = 2,
                 group_size: int = 4,
                 prompt_len: int = 8,
                 gen: int = 8,
                 iters: int = 4,
                 temperature: float = 1.0,
                 top_k: int = 0,
                 lr: float = 0.5,
                 momentum: float = 0.0,
                 weight_decay: float = 0.0,
                 kv_block_size: int = 4,
                 normalize_adv: bool = True,
                 reward_target: Optional[int] = None,
                 reward_width: Optional[int] = None,
                 resilience=None,              # FaultInjector | spec | None
                 guard: Optional[bool] = None,  # None = on iff resilience
                 guard_spike_factor: float = 10.0,
                 max_events: Optional[int] = None,
                 verbose: bool = True):
        spec.ensure_host_devices()
        self.spec = spec
        self.cfg = spec.resolve_config()
        if self.cfg.family not in ROLLOUT_FAMILIES:
            raise NotImplementedError(
                f"rollout serves token-only families {ROLLOUT_FAMILIES}, "
                f"not {self.cfg.family!r} (forward would need side inputs "
                f"the trajectory batch does not carry)")
        from repro.parallel import resolve_plan
        self.plan = resolve_plan(plan if plan is not None else spec.plan)
        if groups < 1 or group_size < 2:
            raise ValueError(
                f"groups={groups} must be >= 1 and group_size={group_size} "
                ">= 2 (a singleton group has zero group-relative advantage)")
        self.groups = groups
        self.group_size = group_size
        self.B = groups * group_size
        n_data = spec.mesh_data or 1
        if self.B % n_data:
            raise ValueError(
                f"batch groups*group_size={self.B} must be divisible by "
                f"mesh_data={n_data} (the train step shards the batch)")
        self.prompt_len = prompt_len
        self.gen = gen
        self.iters = iters
        self.temperature = temperature
        self.top_k = top_k
        self.lr = lr
        self.momentum = momentum
        self.weight_decay = weight_decay
        self.kv_block_size = kv_block_size
        self.normalize_adv = normalize_adv
        vocab = self.cfg.vocab_size
        self._reward_target = (vocab // 2 if reward_target is None
                               else reward_target)
        self._reward_width = (max(1, vocab // 8) if reward_width is None
                              else reward_width)
        self.reward_fn = reward_fn
        self.verbose = verbose
        # chaos wiring (mirrors TrainEngine): one injector shared with the
        # inner ServeEngine (same seed, same charge accounting), plus a
        # loop-level health guard — a NaN policy-gradient step must skip
        # its update WITHOUT pushing corrupted weights to serve
        self.injector = rsl.FaultInjector.from_spec(resilience,
                                                    seed=spec.seed)
        if guard is None:
            guard = self.injector is not None
        self.guard = rsl.HealthGuard(spike_factor=guard_spike_factor) \
            if guard else None
        self.events = rsl.EventLog(max_events=max_events)
        self.history: List[Dict[str, Any]] = []
        self.train = None
        self.serve = None
        self.prompts = None
        self._logprob_fn = None
        self._push_exec = None
        self._built = False

    def _log(self, msg: str) -> None:
        if self.verbose:
            print(msg, flush=True)

    # -- lifecycle ---------------------------------------------------------

    def build(self) -> "RolloutEngine":
        if self._built:
            return self
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.data.synthetic import rollout_prompts, token_range_reward
        from repro.engine.serve import ServeEngine
        from repro.engine.train import TrainEngine

        if self.reward_fn is None:
            self.reward_fn = token_range_reward(self._reward_target,
                                                self._reward_width)
        T = self.prompt_len + self.gen - 1
        self.train = TrainEngine(
            self.spec, plan=self.plan, steps=max(self.iters, 1),
            batch=self.B, seq=T, lr=self.lr, momentum=self.momentum,
            weight_decay=self.weight_decay,
            lr_schedule=lambda s: self.lr,    # no warmup: every rollout
            loss_fn=reinforce_loss_fn(self.cfg),  # iteration trains at lr
            data_tokens=max(4096, 2 * self.B * (T + 2)),
            log_every=10 ** 9,
            # the guard-skip reuses the pre-step state, so its buffers
            # must survive the step (TrainEngine defaults donate=True)
            donate=self.guard is None, verbose=False)
        self.serve = ServeEngine(
            self.spec, batch=self.B, prompt_len=self.prompt_len,
            gen=self.gen, temperature=self.temperature, paged=True,
            kv_block_size=self.kv_block_size,
            resilience=self.injector, verbose=False)
        self.train.build()
        self.serve.build()
        # commit the serve params replicated over the TRAIN mesh once, so
        # the weight-push cast (whose source is mesh-sharded train state)
        # and every serve fn run on one device set — without this the
        # push would mix device assignments and need a host round-trip
        self.serve.params = jax.device_put(
            self.serve.params, NamedSharding(self.train.mesh, P()))
        self.prompts = rollout_prompts(self.groups, self.cfg.vocab_size,
                                       self.prompt_len, seed=self.spec.seed)
        self._built = True
        return self

    # -- phase helpers -----------------------------------------------------

    def pool_occupancy(self) -> int:
        """Blocks the serve pool currently holds references to (0 when the
        pool is asleep or was never built)."""
        st = self.serve._paged_state if self.serve else None
        return 0 if st is None else st["pool"].blocks_in_use()

    def _make_requests(self, it: int) -> List[batching.Request]:
        """B requests for iteration ``it``: group g's members share
        prompt g and differ only in ``seed`` (distinct across members AND
        iterations, so exploration never replays a key stream)."""
        reqs = []
        for g in range(self.groups):
            for m in range(self.group_size):
                rid = g * self.group_size + m
                reqs.append(batching.Request(
                    rid=rid, prompt=self.prompts[g], max_gen=self.gen,
                    temperature=self.temperature,
                    top_k=self.top_k or None,
                    seed=1 + it * self.B + rid))
        return reqs

    def _collect_groups(self, requests) -> List[TrajectoryGroup]:
        import numpy as np
        by_rid = {r.rid: r for r in requests}
        out = []
        for g in range(self.groups):
            trajs = []
            for m in range(self.group_size):
                r = by_rid[g * self.group_size + m]
                if r.status != "ok":
                    raise RuntimeError(
                        f"rollout generation failed: request {r.rid} "
                        f"finished {r.status!r} ({r.error})")
                trajs.append(Trajectory(
                    rid=r.rid, prompt=np.asarray(self.prompts[g]),
                    tokens=np.asarray(r.tokens, np.int32),
                    reward=self.reward_fn(self.prompts[g], r.tokens)))
            grp = TrajectoryGroup(trajs)
            grp.compute_advantages(normalize=self.normalize_adv)
            out.append(grp)
        return out

    def _score_logprobs(self, batch) -> "Any":
        """Per-token behaviour logprobs [B, T] from the CURRENT serve
        params (the policy that actually sampled the tokens)."""
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.models import model as model_mod
        if self._logprob_fn is None:
            cfg = self.cfg

            def logprob(params, tokens, targets, mask):
                logits, _, _ = model_mod.forward(cfg, params,
                                                 {"tokens": tokens})
                lg = logits.astype(jnp.float32)
                lse = jax.nn.logsumexp(lg, axis=-1)
                onehot = (targets[..., None] == jax.lax.broadcasted_iota(
                    jnp.int32, targets.shape + (lg.shape[-1],),
                    targets.ndim))
                ll = jnp.sum(jnp.where(onehot, lg, 0.0), axis=-1) - lse
                return ll * mask
            self._logprob_fn = jax.jit(logprob)
        return np.asarray(self._logprob_fn(
            self.serve.params, jnp.asarray(batch["tokens"]),
            jnp.asarray(batch["targets"]), jnp.asarray(batch["mask"])))

    def push_weights(self) -> None:
        """Hand the train state's params to the serve engine device-side.

        ONE compiled program: stage-sharded plans reconstruct the full
        tree from their [N, chunk] masters (``unchunk_params`` under jit —
        the masters themselves stay sharded), tree plans are a pure per-
        leaf dtype cast; either way the OLD serve params are donated as
        the destination, so the hand-off allocates nothing it does not
        immediately reuse. ``jax.transfer_guard("disallow")`` turns any
        host round-trip of a parameter array into an error (compilation
        happens outside the guard, on the first push)."""
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.parallel import PLACE_STAGE_SHARDED
        staged = self.plan.placement == PLACE_STAGE_SHARDED
        state = self.train.state
        src = state["params"]["stages"] if staged else state["params"]
        if self._push_exec is None:
            mesh = self.train.mesh
            if staged:
                from repro.parallel import zero_cdp as zcdp
                n = mesh.shape[self.train.trainer.data_axis]
                layout = zcdp.build_stage_layout(self.cfg, n)

                def cast(stages, dst):
                    full = zcdp.unchunk_params(layout, stages)
                    return jax.tree.map(
                        lambda x, d: x.astype(d.dtype), full, dst)
            else:
                def cast(p, dst):
                    return jax.tree.map(
                        lambda x, d: x.astype(d.dtype), p, dst)
            fn = jax.jit(cast, out_shardings=NamedSharding(mesh, P()),
                         donate_argnums=(1,))
            self._push_exec = fn.lower(src, self.serve.params).compile()
        with jax.transfer_guard("disallow"):
            self.serve.params = self._push_exec(src, self.serve.params)

    # -- chaos (train-phase faults + guard) ----------------------------------

    def _inject_train_faults(self, it: int, metrics):
        """Train-phase fault injection, keyed by ITERATION index: like
        TrainEngine's nan_loss site, a fired fault poisons the landed
        update AND the reported loss — an unguarded loop would push NaN
        weights to serve."""
        if self.injector is None:
            return metrics
        f = self.injector.fires("nan_loss", it)
        if f is not None:
            import jax
            import jax.numpy as jnp
            poison = lambda x: x * jnp.nan \
                if jnp.issubdtype(x.dtype, jnp.inexact) else x
            st = dict(self.train.state)
            st["params"] = jax.tree.map(poison, st["params"])
            self.train.state = st
            metrics = dict(metrics)
            metrics["loss"] = float("nan")
            self.events.append("inject", it, site="nan_loss")
        return metrics

    def _guard_verdict(self, it: int, metrics, prev_state) -> bool:
        """Health-check the train step; on a bad verdict restore the
        pre-step state (step counter still advances — the same legal
        bounded delay as TrainEngine's skip) and report True so the push
        phase leaves serve's weights untouched."""
        if self.guard is None:
            return False
        verdict = self.guard.check(float(metrics["loss"]))
        if verdict == "ok":
            return False
        self.train.state = self.train._bump_step(prev_state)
        self.events.append("skip", it, reason=verdict,
                           loss=float(metrics["loss"]))
        self._log(f"rollout iter {it}: {verdict} loss "
                  f"({metrics['loss']}) — skipping update and push")
        return True

    # -- the loop ----------------------------------------------------------

    def iteration(self, it: int) -> Dict[str, Any]:
        """One generate -> score -> train -> push cycle; returns the
        iteration record (also appended to ``self.history``)."""
        import numpy as np
        self.build()
        phase_s: Dict[str, float] = {}

        t0 = time.monotonic()
        res = self.serve.serve(self._make_requests(it),
                               policy=batching.ServePolicy(max_slots=self.B))
        groups = self._collect_groups(res["requests"])
        phase_s["generate"] = time.monotonic() - t0
        gen_tokens = int(sum(len(t.tokens) for g in groups for t in g))
        self.events.append("phase", it, phase="generate",
                           dur_s=phase_s["generate"], tokens=gen_tokens)

        t0 = time.monotonic()
        batch = reinforce_batch(groups, pad_to=self.prompt_len + self.gen)
        logp = self._score_logprobs(batch)
        for i, traj in enumerate(t for g in groups for t in g):
            lo = len(traj.prompt) - 1
            traj.logprobs = logp[i, lo:lo + len(traj.tokens)].copy()
        phase_s["score"] = time.monotonic() - t0
        self.events.append("phase", it, phase="score",
                           dur_s=phase_s["score"])

        # train phase: the serve pool sleeps first, so KV memory and
        # optimizer state never coexist at peak (the paper's staggered
        # peak-resource argument, applied across the two engines)
        t0 = time.monotonic()
        self.serve.pool_sleep(level=2)
        occ = self.pool_occupancy()
        assert occ == 0, f"pool still holds {occ} blocks during train"
        prev_state = self.train.state if self.guard is not None else None
        metrics = self.train.step_external(batch)
        metrics = self._inject_train_faults(it, metrics)
        skipped = self._guard_verdict(it, metrics, prev_state)
        phase_s["train"] = time.monotonic() - t0
        self.events.append("phase", it, phase="train",
                           dur_s=phase_s["train"], loss=metrics["loss"])

        # push phase: a skipped train step pushes NOTHING — serve keeps the
        # last healthy params; the pool still wakes on the next generate
        t0 = time.monotonic()
        if not skipped:
            self.push_weights()
        phase_s["push"] = time.monotonic() - t0
        self.events.append("phase", it, phase="push",
                           dur_s=phase_s["push"], skipped=skipped)

        rewards = np.asarray([g.mean_reward for g in groups])
        rec = {"iter": it,
               "mean_reward": float(rewards.mean()),
               "group_rewards": [float(r) for r in rewards],
               "skipped": skipped,
               "loss": float(metrics["loss"]),
               "pg": float(metrics.get("pg", metrics["loss"])),
               "gen_tokens": gen_tokens,
               "gen_tok_s": round(gen_tokens /
                                  max(phase_s["generate"], 1e-9), 2),
               "phase_s": {k: round(v, 4) for k, v in phase_s.items()}}
        self.history.append(rec)
        self._log(
            f"rollout iter {it}: reward {rec['mean_reward']:.3f} "
            f"loss {rec['loss']:.4f}  gen {rec['gen_tok_s']} tok/s  "
            f"phases g/s/t/p = "
            + "/".join(f"{phase_s[k]:.2f}s"
                       for k in ("generate", "score", "train", "push")))
        return rec

    def run(self, iters: Optional[int] = None) -> List[Dict[str, Any]]:
        """Run the loop; returns ``self.history`` (one record per
        iteration: mean reward, loss, tokens/s, per-phase seconds)."""
        self.build()
        n = self.iters if iters is None else int(iters)
        for it in range(len(self.history), len(self.history) + n):
            self.iteration(it)
        return self.history
