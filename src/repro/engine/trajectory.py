"""Trajectory containers for the RL rollout subsystem.

A *trajectory* is one sampled continuation of a prompt plus its scalar
reward; a *group* is several trajectories of the SAME prompt sampled with
different seeds (per-request sampling keys through ``ServeEngine.serve``),
which is what makes a group-relative advantage meaningful: the group mean
is a zero-parameter baseline, so REINFORCE needs no learned value head.

This module is host-side and jax-free (like ``engine.batching``):
:class:`RolloutEngine` fills the dataclasses from serve() results and
:func:`reinforce_batch` packs a list of scored groups into the fixed-shape
``{"tokens", "targets", "mask", "adv"}`` batch TrainEngine's jitted step
consumes — sequences are right-padded to one static width so the policy
gradient step compiles once, and ``mask`` confines the loss to positions
whose TARGET is a generated (sampled) token: the prompt is conditioning,
not behaviour, so it carries no gradient.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Trajectory:
    """One sampled continuation. ``tokens`` are the GENERATED tokens only
    (the prompt is kept separately); ``logprobs`` are the behaviour
    policy's per-generated-token log-probabilities (filled by the score
    phase — the hook for the importance-sampling correction when training
    on stale weights); ``advantage`` is group-relative, filled by
    :meth:`TrajectoryGroup.compute_advantages`."""
    rid: int
    prompt: np.ndarray                      # [S] int32
    tokens: np.ndarray                      # [G] int32, generated
    logprobs: Optional[np.ndarray] = None   # [G] float32, behaviour policy
    reward: float = 0.0
    advantage: float = 0.0

    @property
    def length(self) -> int:
        """Full sequence length (prompt + generated)."""
        return len(self.prompt) + len(self.tokens)

    def sequence(self) -> np.ndarray:
        return np.concatenate([np.asarray(self.prompt, np.int64),
                               np.asarray(self.tokens, np.int64)])


@dataclasses.dataclass
class TrajectoryGroup:
    """Trajectories of one shared prompt. The group IS the baseline:
    ``advantage_i = reward_i - mean(rewards)`` (optionally divided by the
    group's reward std), so a group whose members all earned the same
    reward contributes zero gradient — exactly the degenerate case a
    learned baseline would have to fit."""
    trajectories: List[Trajectory]

    def __post_init__(self):
        if not self.trajectories:
            raise ValueError("a TrajectoryGroup needs >= 1 trajectory")

    def __len__(self) -> int:
        return len(self.trajectories)

    def __iter__(self):
        return iter(self.trajectories)

    @property
    def rewards(self) -> np.ndarray:
        return np.asarray([t.reward for t in self.trajectories], np.float32)

    @property
    def mean_reward(self) -> float:
        return float(self.rewards.mean())

    def compute_advantages(self, *, normalize: bool = True,
                           eps: float = 1e-6) -> np.ndarray:
        """Fill each member's ``advantage`` with its group-relative value
        and return the [len(group)] array. ``normalize`` divides by the
        group reward std (GRPO-style); the ``eps`` floor keeps an
        all-equal-reward group at exactly zero advantage instead of 0/0."""
        r = self.rewards
        adv = r - r.mean()
        if normalize:
            adv = adv / (r.std() + eps)
        for t, a in zip(self.trajectories, adv):
            t.advantage = float(a)
        return adv.astype(np.float32)


def reinforce_batch(groups: List[TrajectoryGroup],
                    pad_to: Optional[int] = None) -> Dict[str, np.ndarray]:
    """Pack scored groups into the policy-gradient training batch:

        tokens  [B, T] int32   — sequence[:-1] (model input)
        targets [B, T] int32   — sequence[1:]  (next-token labels)
        mask    [B, T] float32 — 1 where the TARGET is a generated token
        adv     [B]    float32 — the trajectory's group-relative advantage

    ``T = pad_to - 1`` when given (a fixed prompt_len + gen keeps the
    jitted step's shapes static across iterations), else the batch's max
    sequence length - 1. Short rows are right-padded with zeros and
    masked out, so padding never contributes loss."""
    trajs = [t for g in groups for t in g]
    if not trajs:
        raise ValueError("reinforce_batch needs >= 1 trajectory")
    width = max(t.length for t in trajs)
    if pad_to is not None:
        if pad_to < width:
            raise ValueError(f"pad_to={pad_to} < longest sequence {width}")
        width = pad_to
    T = width - 1
    B = len(trajs)
    tokens = np.zeros((B, T), np.int32)
    targets = np.zeros((B, T), np.int32)
    mask = np.zeros((B, T), np.float32)
    adv = np.zeros((B,), np.float32)
    for i, t in enumerate(trajs):
        seq = t.sequence()
        n = len(seq)
        tokens[i, :n - 1] = seq[:-1]
        targets[i, :n - 1] = seq[1:]
        # target position j predicts seq[j + 1]: generated targets start
        # where the prompt ends (position len(prompt) - 1 predicts the
        # first sampled token) and stop at the end of the real sequence
        mask[i, len(t.prompt) - 1:n - 1] = 1.0
        adv[i] = t.advantage
    return {"tokens": tokens, "targets": targets, "mask": mask, "adv": adv}
