"""RunSpec: the execution-plan half of a run, shared by both engines.

One object owns the things every launcher used to re-implement:

  * config resolution  — arch-id lookup (full or reduced) or an explicit
    ``ModelConfig``, plus the kernel-backend registry (``kernels=``) with the
    deprecated ``attn_backend`` alias mapped onto it;
  * plan resolution    — the parallelism strategy (``plan=``, a
    ``repro.parallel`` registry name or ParallelPlan), validated fail-fast;
  * host-device forcing — the CPU-container ``--xla_force_host_platform_
    device_count`` dance, applied to the environment BEFORE jax initialises
    its backend;
  * mesh construction  — (data, model[, pod]) over whatever devices exist.

This module deliberately imports no jax at module scope so a launcher can
build a RunSpec and call :meth:`ensure_host_devices` before anything touches
device state.
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from dataclasses import dataclass
from typing import Any, Optional, Union

from repro.kernels.registry import KernelSpec, coerce_ops


@dataclass(frozen=True)
class RunSpec:
    """What to run and where — but not the train/serve loop parameters
    (those belong to :class:`TrainEngine` / :class:`ServeEngine`)."""
    arch: str = ""
    reduced: bool = False
    config: Optional[Any] = None          # explicit ModelConfig overrides arch
    # kernel backend registry: KernelSpec | dict | CLI string ("pallas" or
    # "decode_attn=pallas,ssm_scan=jnp") | None (keep the config's choice)
    kernels: Union[KernelSpec, dict, str, None] = None
    attn_backend: Optional[str] = None    # DEPRECATED alias (train+prefill)
    # parallelism strategy: a registered plan name ("dp", "cdp_v1", "cdp_v2",
    # "cdp_random", "zero1_ring", "zero_cdp") or a repro.parallel.ParallelPlan
    # object; None -> the engine default (cdp_v2). Resolved fail-fast by
    # resolve_plan() exactly like kernels resolve through the kernel registry.
    plan: Optional[Any] = None
    mesh_data: int = 2
    mesh_model: int = 2
    mesh_pod: int = 0
    host_devices: int = 0                 # force N host CPU devices (0 = off)
    seed: int = 0

    def with_(self, **kw) -> "RunSpec":
        return dataclasses.replace(self, **kw)

    # -- config ------------------------------------------------------------

    def resolve_config(self):
        """The effective ModelConfig: explicit > arch lookup, with the
        kernel registry and the deprecated attn_backend alias applied and
        validated (fail fast, not mid-trace)."""
        from repro.configs import get_config, get_reduced
        from repro.kernels import registry

        if self.config is not None:
            cfg = self.config
        elif self.arch:
            cfg = get_reduced(self.arch) if self.reduced else get_config(self.arch)
        else:
            raise ValueError("RunSpec needs an arch id or an explicit config")
        ops = coerce_ops(self.kernels)
        if self.attn_backend is not None:
            warnings.warn(
                "RunSpec.attn_backend / --attn-backend is deprecated; use "
                "kernels=\"train_attn=...,prefill_attn=...\" (or a single "
                "backend for all ops)", DeprecationWarning, stacklevel=2)
            cfg = cfg.with_(attn_backend=self.attn_backend)
            if ops is not None:
                # the alias fills attention ops the explicit --kernels value
                # did not name (never silently dropped, never overriding an
                # explicitly named op)
                for op in ("train_attn", "prefill_attn"):
                    ops.setdefault(op, self.attn_backend)
        if ops is not None:
            cfg = cfg.with_(kernels=KernelSpec(**ops).validate())
        registry.resolve(cfg)             # validates, incl. the alias path
        return cfg

    # -- parallelism plan --------------------------------------------------

    def resolve_plan(self, default: str = "cdp_v2"):
        """The effective ParallelPlan (validated fail-fast: an unknown plan
        name raises here, not mid-build). Jax-free, like the rest of
        RunSpec resolution."""
        from repro.parallel import resolve_plan
        return resolve_plan(self.plan, default=default)

    # -- devices / mesh ----------------------------------------------------

    def auto_host_devices(self) -> "RunSpec":
        """``host_devices`` defaulted to the mesh size when unset and >1.
        The XLA flag only multiplies CPU devices, so this is inert on an
        accelerator machine while making any multi-rank mesh work out of
        the box on the CPU container. Launch shims call this; explicit
        ``host_devices`` always wins."""
        if self.host_devices:
            return self
        need = self.mesh_data * self.mesh_model * max(self.mesh_pod, 1)
        return self.with_(host_devices=need) if need > 1 else self

    def ensure_host_devices(self) -> None:
        """Force ``host_devices`` CPU devices via XLA_FLAGS. Must run before
        jax initialises its backend — call it first thing in a launcher."""
        if not self.host_devices:
            return
        flag = f"--xla_force_host_platform_device_count={self.host_devices}"
        flags = os.environ.get("XLA_FLAGS", "")
        if flag not in flags:
            os.environ["XLA_FLAGS"] = (flags + " " + flag).strip()

    def build_mesh(self):
        from repro.launch.mesh import make_host_mesh
        return make_host_mesh(self.mesh_data, self.mesh_model, self.mesh_pod)


def shrink_mesh(mesh, dead_rank: int, data_axis: str = "data"):
    """The survivor mesh after data-rank ``dead_rank`` dies: its row of
    model devices is deleted from the device grid, every surviving rank
    keeps its devices (their resident shards stay valid), and ranks above
    the dead one renumber down by one — exactly how the stage ring re-forms
    at N-1. A pod axis does not compose with elastic membership yet (the
    stage ring spans exactly the data axis)."""
    import numpy as np

    from repro import compat

    names = tuple(mesh.axis_names)
    if "pod" in names:
        raise ValueError(
            "elastic shrink does not compose with a pod axis yet")
    if data_axis not in names:
        raise ValueError(f"mesh has no {data_axis!r} axis (axes: {names})")
    ax = names.index(data_axis)
    n = mesh.devices.shape[ax]
    if n <= 1:
        raise ValueError("cannot shrink a mesh with a single data rank")
    if not 0 <= dead_rank < n:
        raise ValueError(
            f"dead rank {dead_rank} outside the {data_axis!r} axis "
            f"(size {n})")
    survivors = np.delete(np.asarray(mesh.devices), dead_rank, axis=ax)
    return compat.mesh_from_devices(survivors, names)
