"""Resilience primitives: deterministic fault injection + the guards that
survive the faults.

Three framework-light pieces shared by :class:`TrainEngine`,
:class:`ServeEngine`, ``checkpoint.io`` and ``data.ShardedLoader``:

  * :class:`FaultInjector` — a seedable, deterministic chaos source. Each
    :class:`Fault` names an injection SITE (where the failure happens) and
    fires either at an exact step (``site@step``) or with a seeded
    per-query probability (``site%prob``); every decision is recorded in
    ``injector.log`` so two runs with the same spec + seed inject the
    exact same faults at the exact same steps (the chaos tests' replay
    contract).
  * :class:`HealthGuard` — per-step ``isfinite(loss)`` + EMA loss-spike
    detection. The guard never mutates engine state; it returns a verdict
    and the engine decides (skip the update / roll back). Skipping an
    update is legal under CDP's uniform-staleness rules: the paper's own
    update machinery already tolerates one-step-stale parameters, so a
    skipped micro-batch step is just another bounded delay (PipeDream's
    weight stashing makes the same observation for rollback).
  * :class:`EventLog` — the structured ``engine.events`` record of every
    inject / skip / rollback / retry / quarantine, queryable by kind and
    optionally bounded (``max_events`` ring buffer). This is the audit
    trail SLO-aware admission (ROADMAP direction 2) will consume.
  * :class:`StepWatchdog` — a wall-clock per-step deadline that classifies
    a step exceeding it as a hung collective (a presumed-dead ring peer)
    and lets the engine route it into the elastic rank-down recovery path.

This module imports no jax at module scope (like ``engine.spec`` and
``engine.batching``) so launchers can parse ``--resilience`` specs before
device state exists.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import time
from typing import Any, Deque, Dict, List, Optional, Tuple

import numpy as np

# Injection sites. Sites are queried with a STEP-LIKE key: the training
# step for train-side sites, the request id for poison_request, the save
# step for checkpoint sites.
SITES = (
    "loader",          # host-iterator raises (dead loader worker)
    "nan_loss",        # non-finite loss + poisoned update at a step
    "loss_spike",      # loss multiplied by `arg` (default 1e3) at a step
    "slow_step",       # time.sleep(arg) before a step (preemption stall)
    "ckpt_truncate",   # newest checkpoint file truncated after save
    "ckpt_io",         # save's write raises OSError for `arg` attempts
    "poison_request",  # serve request `rid` poisons its cache rows to NaN
    "rank_down",       # data-rank `arg` dies before a step (elastic CDP)
    "step_hang",       # step stalls `arg` seconds: a hung collective, as
                       # seen by the StepWatchdog (presumed-dead peer)
)


@dataclasses.dataclass(frozen=True)
class Fault:
    """One injection rule. Either ``step`` (exact fire point) or ``prob``
    (seeded per-query coin) must be set. ``count`` bounds total fires —
    exactly-once by default, so a retried site (a rebuilt loader, a save
    retry loop) observes the fault cleared on the second attempt.
    ``arg`` is site-specific: spike factor, sleep seconds, number of
    failing IO attempts."""
    site: str
    step: Optional[int] = None
    prob: float = 0.0
    count: int = 1
    arg: float = 0.0

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(
                f"unknown fault site {self.site!r} (expected one of {SITES})")
        if self.step is None and self.prob <= 0.0:
            raise ValueError(
                f"fault {self.site!r} needs step= (exact) or prob= (seeded)")


def parse_faults(spec: str) -> List[Fault]:
    """Parse a CLI fault spec: comma-separated ``site@step[:arg]`` /
    ``site%prob[:arg]`` clauses; ``"on"``/``""`` means guards-only (no
    injected faults).

        "nan_loss@3,loader@5,ckpt_io@4:2"   # nan at step 3, loader crash
                                            # at batch 5, 2 failed write
                                            # attempts at save step 4
    """
    faults: List[Fault] = []
    for clause in spec.split(","):
        clause = clause.strip()
        if not clause or clause == "on":
            continue
        arg = 0.0
        if ":" in clause:
            clause, arg_s = clause.rsplit(":", 1)
            arg = float(arg_s)
        if "@" in clause:
            site, step_s = clause.split("@", 1)
            faults.append(Fault(site=site, step=int(step_s), arg=arg,
                                count=max(1, int(arg) if site == "ckpt_io"
                                          else 1)))
        elif "%" in clause:
            site, prob_s = clause.split("%", 1)
            faults.append(Fault(site=site, prob=float(prob_s), arg=arg))
        else:
            raise ValueError(
                f"bad fault clause {clause!r}: expected site@step[:arg] or "
                f"site%prob[:arg]")
    return faults


class FaultInjector:
    """Deterministic fault source. ``fires(site, step)`` returns the
    matching :class:`Fault` (and burns one of its ``count`` charges) or
    None. Probabilistic faults draw from a per-fault ``default_rng(seed +
    index)`` stream, so with a fixed seed AND the same query sequence the
    fire pattern is exactly reproducible — which is what makes chaos runs
    replayable (same seed -> same skip steps -> same final params)."""

    def __init__(self, faults=(), seed: int = 0):
        if isinstance(faults, str):
            faults = parse_faults(faults)
        self.faults: List[Fault] = list(faults)
        self.seed = seed
        self._rngs = [np.random.default_rng(seed + 7919 * i)
                      for i in range(len(self.faults))]
        self._fired = [0] * len(self.faults)
        self.log: List[Tuple[str, int]] = []   # (site, step) of every fire

    @classmethod
    def from_spec(cls, spec, seed: int = 0) -> Optional["FaultInjector"]:
        """None | "off" -> None; FaultInjector passes through; a spec
        string ("on" or a fault list) builds a fresh injector."""
        if spec is None or spec == "off":
            return None
        if isinstance(spec, FaultInjector):
            return spec
        return cls(spec, seed=seed)

    def fires(self, site: str, step: int) -> Optional[Fault]:
        for i, f in enumerate(self.faults):
            if f.site != site or self._fired[i] >= f.count:
                continue
            hit = (step == f.step) if f.step is not None \
                else bool(self._rngs[i].random() < f.prob)
            if hit:
                self._fired[i] += 1
                self.log.append((site, step))
                return f
        return None


class EventLog:
    """Structured log: every skip / rollback / retry / quarantine the
    resilience layer performs is one dict with at least ``kind``, ``step``
    and a monotonic timestamp ``t`` (``time.monotonic`` seconds — ordering
    and phase durations are meaningful within one process; absolute values
    are not wall-clock). Engines expose it as ``engine.events``;
    :meth:`to_jsonl` exports the log for offline audit (rollout phase
    boundaries, chaos replays).

    ``max_events`` bounds memory for long serve/rollout runs: the log
    becomes a ring buffer keeping the NEWEST ``max_events`` records and
    counting evictions in ``dropped``. The default (None) is unbounded —
    the historical append-only behavior."""

    def __init__(self, max_events: Optional[int] = None):
        if max_events is not None and max_events < 1:
            raise ValueError(f"max_events must be >= 1, got {max_events}")
        self.max_events = max_events
        self.records: Deque[Dict[str, Any]] = collections.deque(
            maxlen=max_events)
        self.dropped = 0

    def append(self, kind: str, step: int, **detail) -> Dict[str, Any]:
        rec = {"kind": kind, "step": int(step), "t": time.monotonic(),
               **detail}
        if self.max_events is not None and \
                len(self.records) == self.max_events:
            self.dropped += 1             # deque evicts the oldest record
        self.records.append(rec)
        return rec

    def of(self, kind: str) -> List[Dict[str, Any]]:
        return [r for r in self.records if r["kind"] == kind]

    def to_jsonl(self, path) -> int:
        """Write one JSON object per record to ``path`` (non-JSON detail
        values are stringified rather than dropped). When the ring buffer
        has evicted records, the FIRST line is a ``events_dropped`` header
        carrying the drop count, so a reader can tell a short run from a
        truncated one; an un-dropped log exports exactly ``len(self)``
        lines. Returns the number of lines written."""
        def _default(o):
            if isinstance(o, (np.integer,)):
                return int(o)
            if isinstance(o, (np.floating,)):
                return float(o)
            if isinstance(o, np.ndarray):
                return o.tolist()
            return str(o)

        lines = 0
        with open(path, "w") as f:
            if self.dropped > 0:
                header = {"kind": "events_dropped", "step": -1,
                          "dropped": self.dropped,
                          "kept": len(self.records),
                          "max_events": self.max_events}
                f.write(json.dumps(header) + "\n")
                lines += 1
            for rec in self.records:
                f.write(json.dumps(rec, default=_default) + "\n")
                lines += 1
        return lines

    def __len__(self):
        return len(self.records)

    def __iter__(self):
        return iter(self.records)

    def __repr__(self):
        kinds: Dict[str, int] = {}
        for r in self.records:
            kinds[r["kind"]] = kinds.get(r["kind"], 0) + 1
        return f"EventLog({kinds})"


class HealthGuard:
    """Per-step loss health: non-finite detection + EMA spike detection.

    ``check(loss)`` returns "ok" | "nonfinite" | "spike" and only folds
    HEALTHY losses into the EMA (a spike must not drag the baseline up and
    mask the next spike). The first ``warmup`` healthy steps never flag a
    spike — early-training loss is legitimately jumpy. The guard is pure
    bookkeeping; the engine owns the skip/rollback policy."""

    def __init__(self, spike_factor: float = 10.0, ema_decay: float = 0.9,
                 warmup: int = 5):
        self.spike_factor = spike_factor
        self.ema_decay = ema_decay
        self.warmup = warmup
        self.ema: Optional[float] = None
        self.healthy_steps = 0

    def check(self, loss: float) -> str:
        if not np.isfinite(loss):
            return "nonfinite"
        if (self.ema is not None and self.healthy_steps >= self.warmup
                and loss > self.spike_factor * max(self.ema, 1e-12)):
            return "spike"
        self.ema = loss if self.ema is None else \
            self.ema_decay * self.ema + (1 - self.ema_decay) * loss
        self.healthy_steps += 1
        return "ok"

    def reset(self) -> None:
        """Forget the baseline (after a rollback: the restored params'
        loss is the new normal)."""
        self.ema = None
        self.healthy_steps = 0


class StepWatchdog:
    """Wall-clock deadline per training step. A step that blows past its
    deadline is, on a ring topology, indistinguishable from a peer that
    died mid-collective — the permute never completes, every survivor
    blocks. ``arm(step)`` starts the clock before dispatch; ``expired()``
    after the step's results materialize returns the elapsed seconds when
    the deadline was exceeded (else None), and the engine routes that
    verdict into the same rank-down recovery path as an explicit
    ``rank_down`` fault. Pure host-side bookkeeping (no jax, no threads):
    the engine decides when to block on device results and when to check.
    """

    def __init__(self, timeout_s: float):
        if timeout_s <= 0:
            raise ValueError(f"watchdog timeout must be > 0, got {timeout_s}")
        self.timeout_s = float(timeout_s)
        self.step: Optional[int] = None
        self._armed_at: Optional[float] = None

    def arm(self, step: int) -> None:
        self.step = int(step)
        self._armed_at = time.monotonic()

    def expired(self) -> Optional[float]:
        """Elapsed seconds since :meth:`arm` if over the deadline, else
        None. Disarmed (never armed / after :meth:`disarm`) is never
        expired."""
        if self._armed_at is None:
            return None
        elapsed = time.monotonic() - self._armed_at
        return elapsed if elapsed > self.timeout_s else None

    def disarm(self) -> None:
        self._armed_at = None


# ---------------------------------------------------------------------------
# Serve-side cache health (lazy jax import: host-side modules above stay
# jax-free)
# ---------------------------------------------------------------------------

def row_health_fn(axes):
    """A jit-ready ``cache -> [B] bool`` (True = every float leaf of the
    row is finite). ``axes`` is the per-leaf batch-axis pytree from
    ``batching.cache_batch_axes`` — the health reduction collapses every
    OTHER axis, so one call covers all layers/leaves of a slot row. Used
    by ServeEngine's quarantine pass."""
    import jax
    import jax.numpy as jnp

    def health(cache):
        flags = []

        def leaf(x, ax):
            if not jnp.issubdtype(x.dtype, jnp.inexact):
                return
            red = tuple(i for i in range(x.ndim) if i != ax)
            flags.append(jnp.all(jnp.isfinite(x), axis=red))

        jax.tree.map(leaf, cache, axes)
        if not flags:
            raise ValueError("cache has no float leaves to health-check")
        out = flags[0]
        for f in flags[1:]:
            out = out & f
        return out

    return health


def poison_rows_fn(axes):
    """A jit-ready ``(cache, mask) -> cache`` that fills the masked rows'
    FLOAT leaves with NaN (int leaves — per-row cache lengths — are kept:
    a poisoned row is numerically dead, not structurally dead). This is
    the injection half of quarantine: it simulates a request whose prompt
    blows up the numerics of its own cache rows."""
    import jax
    import jax.numpy as jnp

    def poison(cache, mask):
        def leaf(x, ax):
            if not jnp.issubdtype(x.dtype, jnp.inexact):
                return x
            m = mask.reshape((1,) * ax + (-1,) + (1,) * (x.ndim - ax - 1))
            return jnp.where(m, jnp.nan, x)

        return jax.tree.map(leaf, cache, axes)

    return poison
