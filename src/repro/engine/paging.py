"""Paged KV-cache subsystem: block pool, prefix sharing, host-RAM offload.

The serving engine's dense decode cache reserves ``[max_slots, max_len]`` KV
rows per layer — one long-context request inflates every slot's reservation,
and identical system prompts are prefilled and stored once per request. This
module replaces that reservation with a vLLM-style paged cache:

* **BlockPool** — the host-side allocator. KV lives in ``num_blocks`` fixed
  ``block_size``-token blocks shared by all slots (one physical block id
  spans every layer); each slot owns an ordered list of blocks, mirrored
  into the device block table ``cache["table"] [B, nb_max]``. Freed blocks
  return to a free list; **prefix sharing** registers every full prompt
  block under a chained content hash, so a later request whose prompt starts
  with the same blocks just bumps their refcounts and skips prefilling them
  (``hist`` tokens served from cache). Shared blocks are immutable;
  **copy-on-write** (`ensure written blocks are exclusive`) allocates a
  private copy before any write would touch a block another slot (or the
  prefix registry) can still see.

* **Sleep levels** — vLLM-style memory release for idle/preempted requests:
  level 1 offloads a slot's blocks to host RAM (``gather_slot`` → numpy) and
  frees them; wake re-allocates and uploads (bitwise round-trip). Level 2
  discards the blocks entirely; wake re-prefills prompt + generated tokens.

* **Device helpers** — pure jax functions the engine jits once per shape:
  ``scatter_prefill`` (splice a dense ragged-prefilled cache into the pool —
  the bitwise-exact admission path), ``gather_slot`` / ``upload_slot``
  (offload/wake), ``copy_blocks`` (CoW), and paged twins of the resilience
  layer's row-health/poison functions (pool leaves have no batch axis, so
  the dense ``cache_batch_axes`` machinery cannot see rows — these go
  through the table instead).

Every allocator transition is appended to the engine's event log
(``page_alloc | page_share | page_cow | page_free | page_offload |
page_wake``), so tests can replay allocator invariants (no double-free, no
aliased writable blocks) from ``engine.events`` alone.

Trash-block convention: pool arrays have ``num_blocks + 1`` physical slots;
the last one backs unallocated table entries on the READ side and is never
written — masked or invalid scatter writes are dropped with an
out-of-bounds index instead (duplicate scatter indices have no defined
winner, so funnelling many rows' dead writes into one shared block would
be racy). The trash block therefore stays all-zero, and read paths in
``models.attention`` / ``kernels.paged_attention`` additionally zero V
outside validity, so whatever a freed or quarantined row left in its own
blocks (even NaN) cannot leak into live rows.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

PyTree = Any

# layer-group keys a paged cache may carry (matching models.model)
PAGED_GROUPS = ("dense", "moe")


def round_up(n: int, mult: int) -> int:
    return -(-n // mult) * mult


class PoolExhausted(RuntimeError):
    """No free block and nothing reclaimable: the engine must preempt."""


@dataclass
class Parked:
    """A preempted request's saved state (sleep level 1 keeps the payload)."""
    rid: Any
    level: int
    n_tokens: int                      # valid cache length at preemption
    generated: List[int]               # tokens emitted so far
    payload: Optional[dict] = None     # level 1: host copies of k/v blocks
    last_token: Optional[int] = None   # level 1: resume decode input
    key_row: Optional[np.ndarray] = None  # level 1: sampling key row


class BlockPool:
    """Host-side block allocator + prefix registry (no jax — pure Python).

    ``events`` is a list shared with the engine; every transition appends
    ``(kind, step, slot, block)`` tuples (``self.step`` is advanced by the
    engine loop). Refcounts count *slot* references; a registered block with
    refcount 0 stays cached (reclaimable LRU) until the free list runs dry.
    """

    def __init__(self, num_blocks: int, block_size: int,
                 events: Optional[list] = None, prefix_cache: bool = True):
        if num_blocks < 1:
            raise ValueError("num_blocks must be >= 1")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.trash = num_blocks
        self.prefix_cache = prefix_cache
        self.events = events if events is not None else []
        self.step = 0
        self.free: List[int] = list(range(num_blocks - 1, -1, -1))
        self.ref = np.zeros(num_blocks, np.int64)
        self.slot_blocks: Dict[int, List[int]] = {}
        self.registered: Dict[int, int] = {}      # block -> chained hash
        self.by_hash: Dict[int, int] = {}         # chained hash -> block
        self.lru: Dict[int, int] = {}             # reclaimable cached blocks
        # registered blocks whose content-producing prefill has NOT run yet
        # (a shared-tail admission registers at allocation; the engine calls
        # mark_written() once the round's prefills execute). They must not
        # be prefix-matched or used as a CoW source until then.
        self.pending: set = set()
        self._tick = 0
        # stats
        self.in_use_peak = 0
        self.prefix_lookup_tokens = 0
        self.prefix_hit_tokens = 0
        self.cow_copies = 0

    # -- bookkeeping --------------------------------------------------------

    def _log(self, kind: str, slot, block):
        self.events.append((kind, self.step, slot, block))

    def blocks_in_use(self) -> int:
        return int(np.count_nonzero(self.ref))

    def _bump_peak(self):
        self.in_use_peak = max(self.in_use_peak, self.blocks_in_use())

    def reset_stats(self):
        self.in_use_peak = self.blocks_in_use()
        self.prefix_lookup_tokens = 0
        self.prefix_hit_tokens = 0
        self.cow_copies = 0

    # -- allocation core ----------------------------------------------------

    def _deregister(self, b: int):
        h = self.registered.pop(b, None)
        if h is not None and self.by_hash.get(h) == b:
            del self.by_hash[h]
        self.lru.pop(b, None)
        self.pending.discard(b)

    def _alloc_raw(self) -> int:
        if self.free:
            return self.free.pop()
        if self.lru:   # reclaim the least-recently-cached prefix block
            b = min(self.lru, key=self.lru.get)
            self._deregister(b)
            return b
        raise PoolExhausted(
            f"block pool exhausted ({self.num_blocks} blocks of "
            f"{self.block_size} tokens, {self.blocks_in_use()} in use)")

    def _take(self, slot: int, b: int):
        self.ref[b] += 1
        self.slot_blocks.setdefault(slot, []).append(b)

    def _drop(self, slot: int, b: int):
        assert self.ref[b] > 0, f"double free of block {b}"
        self.ref[b] -= 1
        self._log("page_free", slot, b)
        if self.ref[b] == 0:
            if b in self.registered:
                self._tick += 1
                self.lru[b] = self._tick
            else:
                self.free.append(b)

    # -- public API ---------------------------------------------------------

    def prefix_hashes(self, prompt) -> List[int]:
        """Chained hash per FULL block of the prompt (partial tail excluded)."""
        bs = self.block_size
        hashes, h = [], 0
        for j in range(len(prompt) // bs):
            h = hash((h, tuple(int(t) for t in prompt[j * bs:(j + 1) * bs])))
            hashes.append(h)
        return hashes

    def admit(self, slot: int, prompt, pending_all: bool = False
              ) -> Tuple[int, Optional[Tuple[int, int, int]]]:
        """Allocate the slot's block list for ``prompt``; returns ``(hist,
        cow)``. ``hist`` is the number of leading tokens already present in
        shared prefix blocks — a multiple of block_size, EXCEPT when the
        whole prompt is cached: then hist is capped at ``len(prompt) - 1``
        (every admission must compute at least one position for its first
        logits) and the block holding that last position is copy-on-write
        swapped for a private copy (``cow = (src, dst, logical)``; the
        caller must device-copy src -> dst before prefilling into it). Full
        blocks this request prefills are registered for future sharing at
        ALLOCATION time, so two identical prompts in one admission batch
        share within the batch — but a block registered by a SHARED-tail
        admission is ``pending`` (its prefill runs after the round's fresh
        prefills and after CoW copies) and is not matchable until the
        engine calls :meth:`mark_written`; matching stops at the first
        pending block so nothing reads or CoW-copies unwritten content.
        ``pending_all=True`` (chunked prefill) marks EVERY block this
        admission registered as pending regardless of a prefix hit — the
        content lands one chunk at a time over several engine iterations,
        so nothing may match these blocks until the final chunk's
        :meth:`mark_written`. Raises PoolExhausted with no state change
        (blocks this admission registered are deregistered again — their
        content was never written, so a retry must not see them as prefix
        hits)."""
        if slot in self.slot_blocks:
            raise RuntimeError(f"slot {slot} already holds blocks")
        plen = len(prompt)
        bs = self.block_size
        hashes = self.prefix_hashes(prompt) if self.prefix_cache else []
        matched: List[int] = []
        for h in hashes:
            b = self.by_hash.get(h)
            if b is None or b in self.pending:
                break
            matched.append(b)
        full = bool(matched) and len(matched) * bs >= plen
        hist = plen - 1 if full else len(matched) * bs
        self.prefix_lookup_tokens += plen
        self.prefix_hit_tokens += hist

        n_total = -(-plen // bs)
        cow = None
        newly_registered: List[int] = []
        try:
            for b in matched:
                self._take(slot, b)
                self.lru.pop(b, None)
                self._log("page_share", slot, b)
            for j in range(len(matched), n_total):
                b = self._alloc_raw()
                self._take(slot, b)
                self._log("page_alloc", slot, b)
                if self.prefix_cache and j < plen // bs:
                    h = hashes[j]
                    self.registered[b] = h
                    self.by_hash[h] = b
                    newly_registered.append(b)
            if full:
                # the tail re-computation will WRITE position plen - 1,
                # which lives inside a shared block — un-share it now
                _, cow = self.prepare_write(slot, plen - 1)
        except PoolExhausted:
            for b in newly_registered:
                self._deregister(b)
            self.release_slot(slot)   # roll back; the engine may preempt
            raise
        if hist > 0 or pending_all:
            # a prefix hit means the engine prefills only the TAIL (the
            # "shared" plan, which runs after fresh prefills and CoW) —
            # until that prefill executes these blocks hold no content.
            # Chunked admissions (pending_all) fill even hist-0 blocks
            # incrementally, so the same discipline applies to all of them.
            self.pending.update(newly_registered)
        self._bump_peak()
        return hist, cow

    def release_slot(self, slot: int):
        """Drop every block reference the slot holds (idempotent)."""
        for b in self.slot_blocks.pop(slot, []):
            self._drop(slot, b)

    def prepare_write(self, slot: int, pos: int
                      ) -> Tuple[List[Tuple[int, int]],
                                 Optional[Tuple[int, int, int]]]:
        """Make logical position ``pos`` of ``slot`` writable. Returns
        (new_allocs [(logical, phys), ...], cow (src, dst, logical) | None).
        Allocates missing blocks up to pos // bs; if the target block is
        shared or registered, copy-on-write swaps in a private copy (the
        caller must device-copy src -> dst)."""
        blocks = self.slot_blocks.setdefault(slot, [])
        lb = pos // self.block_size
        new: List[Tuple[int, int]] = []
        while len(blocks) <= lb:
            b = self._alloc_raw()
            self._take(slot, b)
            # _take appended; record the logical index it landed on
            new.append((len(blocks) - 1, b))
            self._log("page_alloc", slot, b)
        cow = None
        tgt = blocks[lb]
        if self.ref[tgt] > 1 or tgt in self.registered:
            dst = self._alloc_raw()
            self.ref[dst] += 1
            blocks[lb] = dst
            # drop the old reference WITHOUT the list append of _take
            self.ref[tgt] -= 1
            if self.ref[tgt] == 0 and tgt not in self.registered:
                self.free.append(tgt)
            elif self.ref[tgt] == 0:
                self._tick += 1
                self.lru[tgt] = self._tick
            cow = (tgt, dst, lb)
            self.cow_copies += 1
            self._log("page_cow", slot, (tgt, dst))
        self._bump_peak()
        return new, cow

    def pin(self, b: int):
        """Take a slot-less reference keeping ``b`` off the reclaim path —
        used for a pending copy-on-write SOURCE whose device copy is
        deferred to later in the same engine round (a same-round admission
        must not reclaim and overwrite it first). Logged as a share so
        event-replay refcounts stay balanced; ``audit`` must not run while
        pins are outstanding."""
        self.lru.pop(b, None)
        self.ref[b] += 1
        self._log("page_share", -1, b)

    def unpin(self, b: int):
        self._drop(-1, b)

    def mark_written(self, blocks=None):
        """The engine finished an admission round: every planned prefill
        (fresh and shared-tail) has executed, so blocks registered this
        round now hold real content and become prefix-matchable.
        ``blocks`` restricts the clear to one request's blocks (a chunked
        admission finishing its LAST chunk must not unblock other slots'
        still-unwritten pending blocks)."""
        if blocks is None:
            self.pending.clear()
        else:
            for b in blocks:
                self.pending.discard(b)

    def sleep(self):
        """Pool-wide sleep between serve() calls: drop the prefix registry
        and return every retained (refcount-0, LRU-cached) block to the
        free list, leaving occupancy at exactly zero. Only legal when no
        slot holds blocks — a live or leaked reference is a bug, not a
        cache to retain — and required before a weight push, since
        registered blocks hold KV activations of the OLD parameters."""
        n = self.blocks_in_use()
        if n:
            raise RuntimeError(
                f"pool sleep with {n} blocks still referenced "
                "(live or leaked slot state)")
        for b in list(self.registered):
            self._deregister(b)
            self.free.append(b)
        self.lru.clear()
        self.pending.clear()
        assert len(self.free) == self.num_blocks, \
            "pool sleep left blocks unaccounted for"
        self._log("pool_sleep", -1, None)

    def audit(self):
        """Allocator invariants; raises AssertionError on violation."""
        counts = np.zeros(self.num_blocks, np.int64)
        for slot, blocks in self.slot_blocks.items():
            for b in blocks:
                assert 0 <= b < self.num_blocks, (slot, b)
                counts[b] += 1
        assert (counts == self.ref).all(), "refcounts out of sync"
        free = set(self.free)
        assert len(free) == len(self.free), "duplicate blocks on free list"
        assert all(self.ref[b] == 0 for b in free), "free block still referenced"
        assert not (free & set(self.lru)), "block both free and cached"
        assert all(self.ref[b] == 0 for b in self.lru), "cached block referenced"
        # no aliased writable blocks: a block seen by >1 slot must be a
        # registered (immutable prefix) block — writes go through
        # prepare_write, which would have CoW'd it
        for b in np.nonzero(counts > 1)[0]:
            assert int(b) in self.registered, f"block {b} aliased but writable"


# ---------------------------------------------------------------------------
# Device helpers (pure jax; the engine jits them once per shape)
# ---------------------------------------------------------------------------

def _groups(cache) -> List[str]:
    return [g for g in PAGED_GROUPS if g in cache]


def scatter_prefill(paged_cache: PyTree, dense_cache: PyTree, admit_mask):
    """Splice a dense ragged-prefilled cache [L,B,T,KV,hd] into the pool
    through the table (rows with admit_mask False write to the trash block —
    their live blocks and lengths are untouched). T may cover fewer logical
    blocks than nb_max; the rest stay decode-writable. This is the
    bitwise-exact admission path: the values written are the DENSE prefill's
    values, so a subsequent paged decode reads exactly what the dense engine
    would."""
    import jax.numpy as jnp
    table = paged_cache["table"]
    out = dict(paged_cache)
    for g in _groups(paged_cache):
        pool_k = paged_cache[g]["k"]
        trash = pool_k.shape[1] - 1
        bs = pool_k.shape[2]
        kd, vd = dense_cache[g]["k"], dense_cache[g]["v"]
        L, Bv, T, KV, hd = kd.shape
        nbp = T // bs
        # non-admitted rows (and an admitted row's unallocated tail
        # entries) DROP their writes out of bounds — scattering them into
        # the shared trash block would race between rows (duplicate scatter
        # indices have no defined winner) and the trash block must stay
        # all-zero for every read path that is masked against it
        tbl = jnp.where(admit_mask[:, None] & (table[:, :nbp] != trash),
                        table[:, :nbp], trash + 1)
        out[g] = {
            "k": pool_k.at[:, tbl].set(kd.reshape(L, Bv, nbp, bs, KV, hd),
                                       mode="drop"),
            "v": paged_cache[g]["v"].at[:, tbl].set(
                vd.reshape(L, Bv, nbp, bs, KV, hd), mode="drop"),
            "len": jnp.where(admit_mask[None, :], dense_cache[g]["len"],
                             paged_cache[g]["len"]),
        }
    return out


def copy_blocks(paged_cache: PyTree, src, dst):
    """Copy pool block src[i] -> dst[i] in every layer of every group
    (copy-on-write). Pad unused lanes with the trash index on both sides."""
    out = dict(paged_cache)
    for g in _groups(paged_cache):
        leaf = dict(paged_cache[g])
        for kv in ("k", "v"):
            pool = leaf[kv]
            leaf[kv] = pool.at[:, dst].set(pool[:, src])
        out[g] = leaf
    return out


def gather_slot(paged_cache: PyTree, row_table):
    """One slot's blocks, gathered to [L, nb, bs, KV, hd] per group (the
    sleep-level-1 offload payload; unallocated entries carry trash garbage
    that ``upload_slot`` never writes back)."""
    return {g: {"k": paged_cache[g]["k"][:, row_table],
                "v": paged_cache[g]["v"][:, row_table]}
            for g in _groups(paged_cache)}


def upload_slot(paged_cache: PyTree, payload: PyTree, idx, slot_mask,
                new_len):
    """Wake from sleep level 1: write payload blocks back at the freshly
    allocated physical slots ``idx`` [nb] (out-of-range = skip, used for the
    unallocated tail) and set the slot's per-layer length."""
    out = dict(paged_cache)
    for g in _groups(paged_cache):
        leaf = dict(paged_cache[g])
        for kv in ("k", "v"):
            leaf[kv] = leaf[kv].at[:, idx].set(payload[g][kv], mode="drop")
        ln = leaf["len"]
        leaf["len"] = jnp_where(slot_mask[None, :], new_len, ln)
        out[g] = leaf
    return out


def jnp_where(c, a, b):
    import jax.numpy as jnp
    return jnp.where(c, a, b)


def paged_row_health(cache: PyTree):
    """[B] bool — per-row finiteness of the row's OWN blocks (masked by the
    row's valid length; trash-backed and pad positions are ignored). The
    paged twin of resilience.row_health_fn — pool leaves have no batch axis,
    so health must be read through the table."""
    import jax.numpy as jnp
    table = cache["table"]
    B, nb = table.shape
    ok = jnp.ones((B,), bool)
    for g in _groups(cache):
        bs = cache[g]["k"].shape[2]
        ln = cache[g]["len"][0]                          # [B] (equal per layer)
        pos = jnp.arange(nb * bs).reshape(nb, bs)
        valid = pos[None] < ln[:, None, None]            # [B, nb, bs]
        m = valid[None, :, :, :, None, None]
        for kv in ("k", "v"):
            gathered = cache[g][kv][:, table]            # [L,B,nb,bs,KV,hd]
            fin = jnp.isfinite(gathered) | ~m
            ok &= jnp.all(fin, axis=(0, 2, 3, 4, 5))
    return ok


def paged_poison_rows(cache: PyTree, idx):
    """NaN-fill the physical pool blocks named by ``idx`` [B, nb] int32
    (the paged twin of resilience.poison_rows_fn; out-of-range entries
    drop). The engine passes only blocks EXCLUSIVELY owned by the poisoned
    rows — shared or registered blocks are copy-on-write swapped for
    private copies and dropped from the prefix registry first — so a
    poison_request fault can never corrupt a co-resident row sharing the
    prefix, and no NaN block ever lingers in ``by_hash``/``lru`` to serve
    a future prefix hit."""
    import jax.numpy as jnp
    out = dict(cache)
    for g in _groups(cache):
        leaf = dict(cache[g])
        pool_k = leaf["k"]
        nan_blk = jnp.full((pool_k.shape[0],) + idx.shape + pool_k.shape[2:],
                           jnp.nan, pool_k.dtype)
        for kv in ("k", "v"):
            leaf[kv] = leaf[kv].at[:, idx].set(nan_blk, mode="drop")
        out[g] = leaf
    return out
