"""Unified engine API: RunSpec + TrainEngine + ServeEngine.

``RunSpec`` (jax-free import) owns config/registry resolution, host-device
forcing, and mesh construction; the engines own the train and serve loops.
``TrainEngine``/``ServeEngine`` are re-exported lazily so that importing
``repro.engine`` to build a RunSpec never initialises jax before
``ensure_host_devices`` can act.
"""
from repro.engine.spec import RunSpec

__all__ = ["RunSpec", "TrainEngine", "ServeEngine", "RolloutEngine",
           "Trajectory", "TrajectoryGroup", "reinforce_batch", "Request",
           "poisson_trace", "Fault", "FaultInjector", "EventLog",
           "HealthGuard", "StepWatchdog", "parse_faults", "BlockPool",
           "PoolExhausted", "Parked", "BuddySnapshotStore",
           "SnapshotUnusable", "ServePolicy"]


def __getattr__(name):
    if name == "TrainEngine":
        from repro.engine.train import TrainEngine
        return TrainEngine
    if name == "ServeEngine":
        from repro.engine.serve import ServeEngine
        return ServeEngine
    if name == "RolloutEngine":
        from repro.engine.rollout import RolloutEngine
        return RolloutEngine
    if name in ("Trajectory", "TrajectoryGroup", "reinforce_batch"):
        # trajectory containers (jax-free import, like RunSpec)
        from repro.engine import trajectory
        return getattr(trajectory, name)
    if name in ("Request", "poisson_trace", "ServePolicy"):
        # continuous-batching workload types (jax-free import, like RunSpec)
        from repro.engine import batching
        return getattr(batching, name)
    if name in ("BlockPool", "PoolExhausted", "Parked"):
        # paged KV-cache allocator (jax-free import, like RunSpec)
        from repro.engine import paging
        return getattr(paging, name)
    if name in ("Fault", "FaultInjector", "EventLog", "HealthGuard",
                "StepWatchdog", "parse_faults"):
        # resilience layer (jax-free import, like RunSpec)
        from repro.engine import resilience
        return getattr(resilience, name)
    if name in ("BuddySnapshotStore", "SnapshotUnusable"):
        # elastic membership's buddy snapshot store
        from repro.engine import elastic
        return getattr(elastic, name)
    raise AttributeError(f"module 'repro.engine' has no attribute {name!r}")
