"""TrainEngine: build -> jitted CDP step -> log/checkpoint/resume loop.

The one training code path: ``launch/train.py`` is an argparse shim over
this class, the examples drive it directly, and tests exercise
checkpoint/resume equality through it.

    spec = RunSpec(arch="stablelm-1.6b", reduced=True, host_devices=4)
    engine = TrainEngine(spec, plan="zero_cdp", steps=100, ckpt_dir="ckpts/")
    engine.run()                       # resumes automatically from ckpt_dir

The parallelism strategy is a ``repro.parallel`` plan (``plan=`` here or on
the RunSpec): ``dp`` | ``cdp_v1`` | ``cdp_v2`` | ``cdp_random`` |
``zero1_ring`` | ``zero_cdp``. ``rule=`` survives as an alias for the plan
of the same name.

Determinism contract: with a fixed RunSpec.seed the data stream is a pure
function of the step index — on restore the engine fast-forwards the host
iterator to the restored step, so an interrupted+resumed run produces
exactly the same state as an uninterrupted one (tested in
tests/test_engine.py).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional

from repro.engine.spec import RunSpec

PyTree = Any


class TrainEngine:
    def __init__(self, spec: RunSpec, *,
                 plan=None,                    # ParallelPlan | name | None
                 rule: Optional[str] = None,   # alias: plan of the same name
                 steps: int = 100,
                 batch: int = 8,
                 seq: int = 128,
                 lr: float = 0.05,
                 momentum: float = 0.9,
                 weight_decay: float = 1e-4,
                 lr_schedule: Optional[Callable] = None,
                 optimizer=None,
                 trainer=None,                 # full TrainerConfig override
                 loss_fn: Optional[Callable] = None,
                 ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 50,
                 log_every: int = 10,
                 data_tokens: int = 200_000,
                 donate: bool = True,
                 verbose: bool = True):
        spec.ensure_host_devices()
        self.spec = spec
        if plan is not None and rule is not None:
            raise ValueError("pass plan= or rule= (alias), not both")
        # precedence: trainer= override's plan > explicit plan > rule alias
        # > spec.plan > cdp_v2; a bad name fails fast here, before any jax
        # work (repro.parallel is jax-free, like RunSpec resolution)
        if trainer is not None:
            if plan is not None or rule is not None:
                raise ValueError(
                    "a trainer= override carries its own plan; do not also "
                    "pass plan=/rule=")
            self.plan = trainer.resolved_plan()
        else:
            from repro.parallel import resolve_plan
            self.plan = resolve_plan(
                plan if plan is not None else
                (rule if rule is not None else spec.plan))
        self.rule = self.plan.name            # back-compat: engine.rule
        self.steps = steps
        self.batch = batch
        self.seq = seq
        self.lr = lr
        self.momentum = momentum
        self.weight_decay = weight_decay
        self.lr_schedule = lr_schedule
        self.optimizer = optimizer
        self.trainer_override = trainer
        self.custom_loss_fn = loss_fn
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.data_tokens = data_tokens
        self.donate = donate
        self.verbose = verbose

        self.cfg = spec.resolve_config()
        self.mesh = None
        self.state = None
        self.start_step = 0
        self.history: List[Dict[str, float]] = []
        self._built = False
        self._loader = None
        self._extras = None
        self._hlo_text = None
        self._step_exec = None        # AOT executable (set by hlo_text)

    # -- plumbing ----------------------------------------------------------

    def _log(self, msg: str) -> None:
        if self.verbose:
            print(msg, flush=True)

    def _make_trainer_config(self):
        from repro.core.trainer import TrainerConfig
        from repro.optim import cosine_warmup
        if self.trainer_override is not None:
            return self.trainer_override
        sched = self.lr_schedule or cosine_warmup(
            self.lr, max(1, self.steps // 10), self.steps)
        return TrainerConfig(
            plan=self.plan,
            pod_axis="pod" if self.spec.mesh_pod else None,
            lr_schedule=sched, donate=self.donate)

    def _proto_extras(self):
        """Family side-inputs (patches/frames protos) — constant across
        steps, so allocated once, not per batch in the loader hot path."""
        if self._extras is None:
            from repro.data.synthetic import synthetic_batch
            proto = synthetic_batch(self.cfg, type("S", (), {
                "global_batch": self.batch, "seq_len": self.seq})())
            self._extras = {k: proto[k] for k in ("patches", "frames")
                            if k in proto}
        return self._extras

    def _to_batch(self, host_batch):
        import jax.numpy as jnp
        b = {"tokens": jnp.asarray(host_batch["tokens"]),
             "targets": jnp.asarray(host_batch["targets"])}
        b.update(self._proto_extras())
        return b

    # -- lifecycle ---------------------------------------------------------

    def build(self) -> "TrainEngine":
        """Materialise params/optimizer/mesh, jit the step, restore the
        latest checkpoint when ckpt_dir has one. Idempotent."""
        if self._built:
            return self
        import jax
        import numpy as np
        from repro import checkpoint as ckpt
        from repro.core.trainer import init_state, jit_train_step
        from repro.data import lm_batch_iterator, make_lm_data
        from repro.models import init_params
        from repro.optim import sgd_momentum

        self.mesh = self.spec.build_mesh()
        self._log(f"mesh: {dict(self.mesh.shape)}  arch: {self.cfg.name}  "
                  f"plan: {self.plan.name} (rule={self.plan.rule}, "
                  f"sync={self.plan.sync}, placement={self.plan.placement})")

        params = init_params(self.cfg, jax.random.PRNGKey(self.spec.seed))
        n_params = sum(int(np.prod(p.shape))
                       for p in jax.tree.leaves(params))
        self._log(f"params: {n_params/1e6:.2f}M")

        self.opt = self.optimizer or sgd_momentum(self.momentum,
                                                  self.weight_decay)
        self.trainer = self._make_trainer_config()
        self.state = init_state(self.cfg, self.trainer, params, self.opt,
                                mesh=self.mesh)

        tokens = make_lm_data(self.cfg.vocab_size, self.data_tokens,
                              seed=self.spec.seed)
        self._host_it = lm_batch_iterator(tokens, self.batch, self.seq,
                                          seed=self.spec.seed)
        batch0 = self._to_batch(next(self._host_it))
        self._batch0 = batch0
        self.step_fn, self.state_sh, self.batch_sh = jit_train_step(
            self.cfg, self.trainer, self.mesh, self.opt, self.state, batch0,
            self.custom_loss_fn)

        self.start_step = 0
        if self.ckpt_dir and ckpt.latest_step(self.ckpt_dir) is not None:
            self.state, self.start_step = ckpt.restore(self.ckpt_dir,
                                                       self.state)
            # the synthetic stream is a pure function of the step index:
            # skip what the interrupted run already consumed so resumed ==
            # uninterrupted
            for _ in range(self.start_step):
                next(self._host_it)
            self._log(f"restored step {self.start_step}")
        self._built = True
        return self

    def _get_loader(self):
        """ONE persistent loader per engine: partial ``run()`` calls share
        it, so prefetched-but-untrained batches are consumed by the next
        call instead of silently dropped (the determinism contract holds
        for in-process continuation, not just checkpoint resume)."""
        from repro.data import ShardedLoader
        if self._loader is None:
            self._loader = ShardedLoader(
                (self._to_batch(b) for b in self._host_it), self.batch_sh)
        return self._loader

    def hlo_text(self) -> str:
        """Optimized HLO of the compiled train step (builds if needed) —
        feed to ``launch.roofline.parse_collectives`` to read the plan's
        communication signature (all-reduce burst vs collective-permute
        ring vs streamed stages) off the real program. The AOT executable
        is kept and ``run()`` steps with it — call this BEFORE run() (the
        demo/benchmark order) and the whole engine compiles exactly once;
        after run() it costs one extra compile (the jit cache is not
        shared), cached for repeat calls."""
        if self._hlo_text is None:
            import jax
            self.build()
            compiled = self.step_fn.lower(self.state, self._batch0).compile()
            self._hlo_text = compiled.as_text()
            # unlike jit dispatch, the AOT executable does not auto-place
            # its inputs — commit the state to its shardings once
            self.state = jax.device_put(self.state, self.state_sh)
            self._step_exec = compiled
        return self._hlo_text

    def close(self) -> None:
        if self._loader is not None:
            self._loader.close()
            self._loader = None

    def run(self, steps: Optional[int] = None) -> PyTree:
        """Train to ``steps`` (default: the configured total), checkpointing
        and logging on the way. Returns the final state. Stopping early
        (``steps < self.steps``) keeps the loader alive for continuation;
        reaching the configured total closes it."""
        from repro import checkpoint as ckpt
        self.build()
        total = self.steps if steps is None else steps
        loader = self._get_loader()
        t0 = time.time()
        try:
            step_fn = self._step_exec if self._step_exec is not None \
                else self.step_fn
            for step in range(self.start_step, total):
                batch = next(loader)
                self.state, metrics = step_fn(self.state, batch)
                if step % self.log_every == 0 or step == total - 1:
                    rec = {"step": step,
                           "loss": float(metrics["loss"]),
                           "lr": float(metrics["lr"])}
                    self.history.append(rec)
                    self._log(f"step {step:5d}  loss {rec['loss']:.4f}  "
                              f"lr {rec['lr']:.4f}  {time.time()-t0:.1f}s")
                if self.ckpt_dir and (step + 1) % self.ckpt_every == 0:
                    ckpt.save(self.ckpt_dir, step + 1, self.state)
        finally:
            if total >= self.steps:
                self.close()
        # never move the resume pointer backwards: a later run() with a
        # smaller target must not re-train completed steps
        self.start_step = max(self.start_step, total)
        return self.state
