"""TrainEngine: build -> jitted CDP step -> log/checkpoint/resume loop.

The one training code path: ``launch/train.py`` is an argparse shim over
this class, the examples drive it directly, and tests exercise
checkpoint/resume equality through it.

    spec = RunSpec(arch="stablelm-1.6b", reduced=True, host_devices=4)
    engine = TrainEngine(spec, plan="zero_cdp", steps=100, ckpt_dir="ckpts/")
    engine.run()                       # resumes automatically from ckpt_dir

The parallelism strategy is a ``repro.parallel`` plan (``plan=`` here or on
the RunSpec): ``dp`` | ``cdp_v1`` | ``cdp_v2`` | ``cdp_random`` |
``zero1_ring`` | ``zero_cdp``. ``rule=`` survives as an alias for the plan
of the same name.

Determinism contract: with a fixed RunSpec.seed the data stream is a pure
function of the step index — on restore the engine fast-forwards the host
iterator to the restored step, so an interrupted+resumed run produces
exactly the same state as an uninterrupted one (tested in
tests/test_engine.py).

Resilience (``resilience=`` / ``guard=`` / ``keep_last=``; see
``engine.resilience``): with the health guard on, every step's loss is
checked for finiteness and EMA spikes — a bad step's update is SKIPPED
(the pre-step params are reused, which is legal under CDP's
uniform-staleness rules: a dropped micro-batch update is just another
bounded delay) and ``guard_max_bad`` consecutive bad steps roll the engine
back to the newest intact checkpoint, replaying the data stream from
there. Loader-worker crashes are retried by rebuilding the stream at the
current step (the stream is a pure function of the step index, so the
retried batch is bit-identical). Every skip / rollback / retry / injected
fault lands in the structured ``engine.events`` log. The guard needs the
pre-step state alive, so it forces ``donate=False``.
"""
from __future__ import annotations

import os
import time
from typing import Any, Callable, Dict, List, Optional

from repro.engine import resilience as rsl
from repro.engine.spec import RunSpec

PyTree = Any


class TrainEngine:
    def __init__(self, spec: RunSpec, *,
                 plan=None,                    # ParallelPlan | name | None
                 rule: Optional[str] = None,   # alias: plan of the same name
                 steps: int = 100,
                 batch: int = 8,
                 seq: int = 128,
                 lr: float = 0.05,
                 momentum: float = 0.9,
                 weight_decay: float = 1e-4,
                 lr_schedule: Optional[Callable] = None,
                 optimizer=None,
                 trainer=None,                 # full TrainerConfig override
                 loss_fn: Optional[Callable] = None,
                 ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 50,
                 keep_last: Optional[int] = None,
                 log_every: int = 10,
                 data_tokens: int = 200_000,
                 donate: bool = True,
                 resilience=None,              # FaultInjector | spec str | None
                 guard: Optional[bool] = None,  # None = on iff resilience
                 guard_spike_factor: float = 10.0,
                 guard_max_bad: int = 3,
                 loader_retries: int = 2,
                 verbose: bool = True):
        spec.ensure_host_devices()
        self.spec = spec
        if plan is not None and rule is not None:
            raise ValueError("pass plan= or rule= (alias), not both")
        # precedence: trainer= override's plan > explicit plan > rule alias
        # > spec.plan > cdp_v2; a bad name fails fast here, before any jax
        # work (repro.parallel is jax-free, like RunSpec resolution)
        if trainer is not None:
            if plan is not None or rule is not None:
                raise ValueError(
                    "a trainer= override carries its own plan; do not also "
                    "pass plan=/rule=")
            self.plan = trainer.resolved_plan()
        else:
            from repro.parallel import resolve_plan
            self.plan = resolve_plan(
                plan if plan is not None else
                (rule if rule is not None else spec.plan))
        self.rule = self.plan.name            # back-compat: engine.rule
        self.steps = steps
        self.batch = batch
        self.seq = seq
        self.lr = lr
        self.momentum = momentum
        self.weight_decay = weight_decay
        self.lr_schedule = lr_schedule
        self.optimizer = optimizer
        self.trainer_override = trainer
        self.custom_loss_fn = loss_fn
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.keep_last = keep_last
        self.log_every = log_every
        self.data_tokens = data_tokens
        self.verbose = verbose

        # -- resilience layer ------------------------------------------------
        self.injector = rsl.FaultInjector.from_spec(resilience,
                                                    seed=spec.seed)
        if guard is None:
            guard = self.injector is not None
        self.guard = rsl.HealthGuard(spike_factor=guard_spike_factor) \
            if guard else None
        self.guard_max_bad = guard_max_bad
        self.loader_retries = loader_retries
        self.events = rsl.EventLog()
        self._bad_streak = 0
        if self.guard is not None:
            # skipping a bad update reuses the PRE-step state, so its
            # buffers must survive the step: donation is incompatible
            if trainer is not None and trainer.donate:
                raise ValueError(
                    "the health guard needs the pre-step state alive; pass "
                    "a TrainerConfig with donate=False (or guard=False)")
            donate = False
        self.donate = donate

        self.cfg = spec.resolve_config()
        self.mesh = None
        self.state = None
        self.start_step = 0
        self.history: List[Dict[str, float]] = []
        self._built = False
        self._loader = None
        self._extras = None
        self._hlo_text = None
        self._step_exec = None        # AOT executable (set by hlo_text)
        self._stream_step = 0         # step index of the next host batch
        self._ext_steps = {}          # batch-structure -> jitted ext step

    # -- plumbing ----------------------------------------------------------

    def _log(self, msg: str) -> None:
        if self.verbose:
            print(msg, flush=True)

    def _make_trainer_config(self):
        from repro.core.trainer import TrainerConfig
        from repro.optim import cosine_warmup
        if self.trainer_override is not None:
            return self.trainer_override
        sched = self.lr_schedule or cosine_warmup(
            self.lr, max(1, self.steps // 10), self.steps)
        return TrainerConfig(
            plan=self.plan,
            pod_axis="pod" if self.spec.mesh_pod else None,
            lr_schedule=sched, donate=self.donate)

    def _proto_extras(self):
        """Family side-inputs (patches/frames protos) — constant across
        steps, so allocated once, not per batch in the loader hot path."""
        if self._extras is None:
            from repro.data.synthetic import synthetic_batch
            proto = synthetic_batch(self.cfg, type("S", (), {
                "global_batch": self.batch, "seq_len": self.seq})())
            self._extras = {k: proto[k] for k in ("patches", "frames")
                            if k in proto}
        return self._extras

    def _to_batch(self, host_batch):
        import jax.numpy as jnp
        b = {"tokens": jnp.asarray(host_batch["tokens"]),
             "targets": jnp.asarray(host_batch["targets"])}
        b.update(self._proto_extras())
        return b

    # -- lifecycle ---------------------------------------------------------

    def build(self) -> "TrainEngine":
        """Materialise params/optimizer/mesh, jit the step, restore the
        newest INTACT checkpoint when ckpt_dir has one (broken files are
        skipped with a ``ckpt_fallback`` event). Idempotent."""
        if self._built:
            return self
        import jax
        import numpy as np
        from repro import checkpoint as ckpt
        from repro.core.trainer import init_state, jit_train_step
        from repro.data import lm_batch_iterator, make_lm_data
        from repro.models import init_params
        from repro.optim import sgd_momentum

        self.mesh = self.spec.build_mesh()
        self._log(f"mesh: {dict(self.mesh.shape)}  arch: {self.cfg.name}  "
                  f"plan: {self.plan.name} (rule={self.plan.rule}, "
                  f"sync={self.plan.sync}, placement={self.plan.placement})")

        params = init_params(self.cfg, jax.random.PRNGKey(self.spec.seed))
        n_params = sum(int(np.prod(p.shape))
                       for p in jax.tree.leaves(params))
        self._log(f"params: {n_params/1e6:.2f}M")

        self.opt = self.optimizer or sgd_momentum(self.momentum,
                                                  self.weight_decay)
        self.trainer = self._make_trainer_config()
        self.state = init_state(self.cfg, self.trainer, params, self.opt,
                                mesh=self.mesh)

        tokens = make_lm_data(self.cfg.vocab_size, self.data_tokens,
                              seed=self.spec.seed)
        self._host_it = lm_batch_iterator(tokens, self.batch, self.seq,
                                          seed=self.spec.seed)
        batch0 = self._to_batch(next(self._host_it))
        self._batch0 = batch0
        self.step_fn, self.state_sh, self.batch_sh = jit_train_step(
            self.cfg, self.trainer, self.mesh, self.opt, self.state, batch0,
            self.custom_loss_fn)

        self.start_step = 0
        if self.ckpt_dir and ckpt.latest_step(self.ckpt_dir) is not None:
            try:
                self.state, self.start_step = ckpt.restore(
                    self.ckpt_dir, self.state,
                    on_fallback=lambda s, r: self.events.append(
                        "ckpt_fallback", s, reason=r))
            except FileNotFoundError:
                # every on-disk step is broken: start fresh rather than die
                self.events.append("ckpt_unusable", 0,
                                   dir=self.ckpt_dir)
                self._log(f"no intact checkpoint in {self.ckpt_dir}; "
                          f"starting from step 0")
            else:
                # the synthetic stream is a pure function of the step
                # index: skip what the interrupted run already consumed so
                # resumed == uninterrupted
                for _ in range(self.start_step):
                    next(self._host_it)
                self._log(f"restored step {self.start_step}")
        self._stream_step = self.start_step
        self._built = True
        return self

    # -- data stream (resilient) -------------------------------------------

    def _rebuild_stream(self, step: int) -> None:
        """Fresh host iterator fast-forwarded so the next batch is step
        ``step``'s — bit-identical to the original stream (pure function
        of the step index): the recovery path for loader crashes and
        checkpoint rollback."""
        from repro.data import lm_batch_iterator, make_lm_data
        tokens = make_lm_data(self.cfg.vocab_size, self.data_tokens,
                              seed=self.spec.seed)
        it = lm_batch_iterator(tokens, self.batch, self.seq,
                               seed=self.spec.seed)
        next(it)                          # the build()-time trace batch
        for _ in range(step):
            next(it)
        self._host_it = it
        self._stream_step = step

    def _feed(self):
        """Host-batch generator for the loader worker; the loader-site
        fault hook lives here so an injected crash exercises the REAL
        worker-thread error path (exception raised on the prefetch thread,
        surfaced in ``__next__``)."""
        while True:
            try:
                b = next(self._host_it)
            except StopIteration:
                return
            s = self._stream_step
            self._stream_step = s + 1
            if self.injector is not None and self.injector.fires("loader", s):
                raise RuntimeError(
                    f"injected loader-worker fault at step {s}")
            yield self._to_batch(b)

    def _get_loader(self):
        """ONE persistent loader per engine: partial ``run()`` calls share
        it, so prefetched-but-untrained batches are consumed by the next
        call instead of silently dropped (the determinism contract holds
        for in-process continuation, not just checkpoint resume)."""
        from repro.data import ShardedLoader
        if self._loader is None:
            self._loader = ShardedLoader(self._feed(), self.batch_sh)
        return self._loader

    def _next_batch(self, step: int):
        """One batch for ``step``, surviving loader-worker crashes: a
        crashed worker's exception (re-raised by ``ShardedLoader.__next__``
        instead of hanging) is logged, the stream is rebuilt exactly at
        ``step``, and the batch is retried — up to ``loader_retries``
        rebuilds before giving up."""
        for attempt in range(self.loader_retries + 1):
            loader = self._get_loader()
            try:
                return next(loader)
            except StopIteration:
                raise
            except Exception as e:
                self.events.append("loader_error", step, error=repr(e),
                                   attempt=attempt)
                self._log(f"step {step}: loader worker died ({e!r}); "
                          f"rebuilding the stream (attempt {attempt + 1})")
                self.close()
                self._rebuild_stream(step)
        raise RuntimeError(
            f"loader failed {self.loader_retries + 1} times at step {step}")

    # -- checkpoint + rollback ---------------------------------------------

    def _save_checkpoint(self, step: int) -> None:
        from repro import checkpoint as ckpt
        path = ckpt.save(self.ckpt_dir, step, self.state,
                         keep_last=self.keep_last, injector=self.injector)
        self.events.append("ckpt_save", step)
        if self.injector is not None and \
                self.injector.fires("ckpt_truncate", step):
            # disk corruption / kill -9 straight after the commit: the
            # manifest checksum no longer matches, so restore() must skip
            # this step
            size = os.path.getsize(path)
            with open(path, "r+b") as fh:
                fh.truncate(max(1, size // 2))
            self.events.append("inject", step, site="ckpt_truncate")

    def _rollback(self, step: int) -> int:
        """Too many consecutive bad steps: restore the newest intact
        checkpoint, rewind the data stream to it, reset the guard.
        Returns the restored step (the new loop position)."""
        import jax
        from repro import checkpoint as ckpt
        restored = None
        if self.ckpt_dir:
            try:
                restored = ckpt.restore(
                    self.ckpt_dir, self.state,
                    on_fallback=lambda s, r: self.events.append(
                        "ckpt_fallback", s, reason=r))
            except FileNotFoundError:
                restored = None
        if restored is None:
            self.events.append("rollback_failed", step,
                               streak=self._bad_streak)
            raise RuntimeError(
                f"{self._bad_streak} consecutive bad steps at step {step} "
                f"and no intact checkpoint to roll back to "
                f"(ckpt_dir={self.ckpt_dir!r})")
        state, rstep = restored
        self.state = jax.device_put(state, self.state_sh)
        self.events.append("rollback", step, to_step=rstep,
                           streak=self._bad_streak)
        self._log(f"step {step}: {self._bad_streak} consecutive bad steps "
                  f"— rolling back to checkpoint step {rstep}")
        self.close()
        self._rebuild_stream(rstep)
        self.guard.reset()
        self._bad_streak = 0
        return rstep

    def _bump_step(self, state):
        """Advance ONLY the step counter (a skipped update keeps params and
        optimizer state): the loop position, LR schedule and CDP freshness
        stay in lockstep with the uninterrupted trajectory."""
        import jax
        import numpy as np
        new = dict(state)
        new["step"] = jax.device_put(np.int32(int(state["step"]) + 1),
                                     self.state_sh["step"])
        return new

    # -- external batches (the RL rollout path) ------------------------------

    def step_external(self, batch) -> Dict[str, float]:
        """Run ONE jitted train step on an externally built batch instead
        of the LM loader stream — the rollout loop's policy-gradient path.

        The batch may carry leaves the LM stream does not (``mask``,
        ``adv``), so the step is jitted once per batch STRUCTURE (sorted
        keys + shapes + dtypes) through the same ``jit_train_step`` the
        loader path uses — same plan, same donation, same shardings; pass
        a custom ``loss_fn=`` at construction to consume the extra leaves
        (it must return ``(loss, metrics)`` with a ``"loss"`` entry).
        Advances ``self.state`` and returns the metrics as host floats."""
        import jax.numpy as jnp
        from repro.core.trainer import jit_train_step
        self.build()
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        sig = tuple(sorted((k, v.shape, str(v.dtype))
                           for k, v in batch.items()))
        step_fn = self._ext_steps.get(sig)
        if step_fn is None:
            step_fn, _, _ = jit_train_step(
                self.cfg, self.trainer, self.mesh, self.opt, self.state,
                batch, self.custom_loss_fn)
            self._ext_steps[sig] = step_fn
        self.state, metrics = step_fn(self.state, batch)
        return {k: float(v) for k, v in metrics.items()}

    # -- compiled-step access ----------------------------------------------

    def hlo_text(self) -> str:
        """Optimized HLO of the compiled train step (builds if needed) —
        feed to ``launch.roofline.parse_collectives`` to read the plan's
        communication signature (all-reduce burst vs collective-permute
        ring vs streamed stages) off the real program. The AOT executable
        is kept and ``run()`` steps with it — call this BEFORE run() (the
        demo/benchmark order) and the whole engine compiles exactly once;
        after run() it costs one extra compile (the jit cache is not
        shared), cached for repeat calls."""
        if self._hlo_text is None:
            import jax
            self.build()
            compiled = self.step_fn.lower(self.state, self._batch0).compile()
            self._hlo_text = compiled.as_text()
            # unlike jit dispatch, the AOT executable does not auto-place
            # its inputs — commit the state to its shardings once
            self.state = jax.device_put(self.state, self.state_sh)
            self._step_exec = compiled
        return self._hlo_text

    def close(self) -> None:
        if self._loader is not None:
            self._loader.close()
            self._loader = None

    # -- the loop ----------------------------------------------------------

    def run(self, steps: Optional[int] = None) -> PyTree:
        """Train to ``steps`` (default: the configured total), checkpointing
        and logging on the way. Returns the final state. Stopping early
        (``steps < self.steps``) keeps the loader alive for continuation;
        reaching the configured total closes it."""
        self.build()
        total = self.steps if steps is None else steps
        t0 = time.time()
        try:
            step_fn = self._step_exec if self._step_exec is not None \
                else self.step_fn
            step = self.start_step
            while step < total:
                batch = self._next_batch(step)
                if self.injector is not None:
                    f = self.injector.fires("slow_step", step)
                    if f is not None:
                        # simulated preemption stall: the run survives it,
                        # the event log shows where the time went
                        dur = f.arg or 0.05
                        self.events.append("slow_step", step, sleep_s=dur)
                        time.sleep(dur)
                new_state, metrics = step_fn(self.state, batch)
                metrics = dict(metrics)
                if self.injector is not None:
                    new_state, metrics = self._inject_step_faults(
                        step, new_state, metrics)
                if self.guard is not None and \
                        not self._healthy(step, metrics):
                    if self._bad_streak >= self.guard_max_bad:
                        step = self._rollback(step)
                    else:
                        # skip the bad update: keep params/opt, advance the
                        # step counter — under CDP's uniform-staleness rules
                        # this is one more bounded delay, not a divergence
                        self.state = self._bump_step(self.state)
                        step += 1
                    continue
                self.state = new_state
                if step % self.log_every == 0 or step == total - 1:
                    rec = {"step": step,
                           "loss": float(metrics["loss"]),
                           "lr": float(metrics["lr"])}
                    self.history.append(rec)
                    self._log(f"step {step:5d}  loss {rec['loss']:.4f}  "
                              f"lr {rec['lr']:.4f}  {time.time()-t0:.1f}s")
                if self.ckpt_dir and (step + 1) % self.ckpt_every == 0:
                    self._save_checkpoint(step + 1)
                step += 1
        finally:
            if total >= self.steps:
                self.close()
        # never move the resume pointer backwards: a later run() with a
        # smaller target must not re-train completed steps
        self.start_step = max(self.start_step, total)
        return self.state

    def _inject_step_faults(self, step, new_state, metrics):
        import jax
        import jax.numpy as jnp
        f = self.injector.fires("nan_loss", step)
        if f is not None:
            # a real NaN gradient poisons the whole update, not just the
            # reported loss — corrupt both so an unguarded run genuinely
            # diverges
            poison = lambda x: x * jnp.nan \
                if jnp.issubdtype(x.dtype, jnp.inexact) else x
            new_state = dict(new_state)
            new_state["params"] = jax.tree.map(poison, new_state["params"])
            metrics["loss"] = float("nan")
            self.events.append("inject", step, site="nan_loss")
        f = self.injector.fires("loss_spike", step)
        if f is not None:
            factor = f.arg or 1e3
            metrics["loss"] = float(metrics["loss"]) * factor
            self.events.append("inject", step, site="loss_spike",
                               factor=factor)
        return new_state, metrics

    def _healthy(self, step, metrics) -> bool:
        loss = float(metrics["loss"])
        verdict = self.guard.check(loss)
        if verdict == "ok":
            self._bad_streak = 0
            return True
        self._bad_streak += 1
        self.events.append("skip", step, reason=verdict, loss=loss,
                           streak=self._bad_streak)
        self._log(f"step {step}: {verdict} loss ({loss}) — skipping the "
                  f"update (streak {self._bad_streak}/{self.guard_max_bad})")
        return False
