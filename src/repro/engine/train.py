"""TrainEngine: build -> jitted CDP step -> log/checkpoint/resume loop.

The one training code path: ``launch/train.py`` is an argparse shim over
this class, the examples drive it directly, and tests exercise
checkpoint/resume equality through it.

    spec = RunSpec(arch="stablelm-1.6b", reduced=True, host_devices=4)
    engine = TrainEngine(spec, plan="zero_cdp", steps=100, ckpt_dir="ckpts/")
    engine.run()                       # resumes automatically from ckpt_dir

The parallelism strategy is a ``repro.parallel`` plan (``plan=`` here or on
the RunSpec): ``dp`` | ``cdp_v1`` | ``cdp_v2`` | ``cdp_random`` |
``zero1_ring`` | ``zero_cdp``. ``rule=`` survives as an alias for the plan
of the same name.

Determinism contract: with a fixed RunSpec.seed the data stream is a pure
function of the step index — on restore the engine fast-forwards the host
iterator to the restored step, so an interrupted+resumed run produces
exactly the same state as an uninterrupted one (tested in
tests/test_engine.py).

Resilience (``resilience=`` / ``guard=`` / ``keep_last=``; see
``engine.resilience``): with the health guard on, every step's loss is
checked for finiteness and EMA spikes — a bad step's update is SKIPPED
(the pre-step params are reused, which is legal under CDP's
uniform-staleness rules: a dropped micro-batch update is just another
bounded delay) and ``guard_max_bad`` consecutive bad steps roll the engine
back to the newest intact checkpoint, replaying the data stream from
there. Loader-worker crashes are retried by rebuilding the stream at the
current step (the stream is a pure function of the step index, so the
retried batch is bit-identical). Every skip / rollback / retry / injected
fault lands in the structured ``engine.events`` log. The guard needs the
pre-step state alive, so it forces ``donate=False``.

Elastic membership (``elastic=True``; see ``engine.elastic``): the run
survives the LOSS OF A DATA RANK. ``snapshot_every`` arms buddy-replicated
host-RAM snapshots (each rank's ZeRO-CDP chunk mirrored to its ring
predecessor); on a ``rank_down`` fault — or a step blowing past
``watchdog_timeout`` seconds, which on a ring is a hung collective — the
engine restores the newest snapshot (disk checkpoint as fallback), drops
the dead device, re-forms the mesh at N-1, re-cuts the stage chunks via
``build_stage_layout(cfg, n-1)``, re-jits, and resumes with the data
stream fast-forwarded: at most ``snapshot_every`` steps lost, and the
post-recovery trajectory is bit-identical to an uninterrupted N-1 run
from the snapshot step. ``rejoin_after`` scales back up (N-1 -> N re-cut)
at a step boundary once the failed rank returns.
"""
from __future__ import annotations

import os
import time
from typing import Any, Callable, Dict, List, Optional

from repro.engine import resilience as rsl
from repro.engine.spec import RunSpec

PyTree = Any


class TrainEngine:
    def __init__(self, spec: RunSpec, *,
                 plan=None,                    # ParallelPlan | name | None
                 rule: Optional[str] = None,   # alias: plan of the same name
                 steps: int = 100,
                 batch: int = 8,
                 seq: int = 128,
                 lr: float = 0.05,
                 momentum: float = 0.9,
                 weight_decay: float = 1e-4,
                 lr_schedule: Optional[Callable] = None,
                 optimizer=None,
                 trainer=None,                 # full TrainerConfig override
                 loss_fn: Optional[Callable] = None,
                 ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 50,
                 keep_last: Optional[int] = None,
                 log_every: int = 10,
                 data_tokens: int = 200_000,
                 donate: bool = True,
                 resilience=None,              # FaultInjector | spec str | None
                 guard: Optional[bool] = None,  # None = on iff resilience
                 guard_spike_factor: float = 10.0,
                 guard_max_bad: int = 3,
                 loader_retries: int = 2,
                 elastic: bool = False,
                 snapshot_every: int = 0,       # buddy snapshots (0 = off)
                 watchdog_timeout: float = 0.0,  # step deadline s (0 = off)
                 rejoin_after: int = 0,  # steps after recovery to scale up
                 verbose: bool = True):
        spec.ensure_host_devices()
        self.spec = spec
        if plan is not None and rule is not None:
            raise ValueError("pass plan= or rule= (alias), not both")
        # precedence: trainer= override's plan > explicit plan > rule alias
        # > spec.plan > cdp_v2; a bad name fails fast here, before any jax
        # work (repro.parallel is jax-free, like RunSpec resolution)
        if trainer is not None:
            if plan is not None or rule is not None:
                raise ValueError(
                    "a trainer= override carries its own plan; do not also "
                    "pass plan=/rule=")
            self.plan = trainer.resolved_plan()
        else:
            from repro.parallel import resolve_plan
            self.plan = resolve_plan(
                plan if plan is not None else
                (rule if rule is not None else spec.plan))
        self.rule = self.plan.name            # back-compat: engine.rule
        self.steps = steps
        self.batch = batch
        self.seq = seq
        self.lr = lr
        self.momentum = momentum
        self.weight_decay = weight_decay
        self.lr_schedule = lr_schedule
        self.optimizer = optimizer
        self.trainer_override = trainer
        self.custom_loss_fn = loss_fn
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.keep_last = keep_last
        self.log_every = log_every
        self.data_tokens = data_tokens
        self.verbose = verbose

        # -- resilience layer ------------------------------------------------
        self.injector = rsl.FaultInjector.from_spec(resilience,
                                                    seed=spec.seed)
        if guard is None:
            guard = self.injector is not None
        self.guard = rsl.HealthGuard(spike_factor=guard_spike_factor) \
            if guard else None
        self.guard_max_bad = guard_max_bad
        self.loader_retries = loader_retries
        self.events = rsl.EventLog()
        self._bad_streak = 0

        # -- elastic membership ----------------------------------------------
        self.elastic = bool(elastic)
        self.snapshot_every = int(snapshot_every)
        self.rejoin_after = int(rejoin_after)
        self.watchdog = rsl.StepWatchdog(watchdog_timeout) \
            if watchdog_timeout else None
        self.recoveries: List[Dict[str, Any]] = []
        self._snapshots = None        # engine.elastic.BuddySnapshotStore
        self._snapshot_s: List[float] = []
        self._rejoin_at: Optional[int] = None
        self._n_data = 0              # current data-axis size (set by build)
        self._fresh_program = True    # first step after a (re)jit compiles;
                                      # the watchdog must not count that
        if self.guard is not None:
            # skipping a bad update reuses the PRE-step state, so its
            # buffers must survive the step: donation is incompatible
            if trainer is not None and trainer.donate:
                raise ValueError(
                    "the health guard needs the pre-step state alive; pass "
                    "a TrainerConfig with donate=False (or guard=False)")
            donate = False
        self.donate = donate

        self.cfg = spec.resolve_config()
        self.mesh = None
        self.state = None
        self.start_step = 0
        self.history: List[Dict[str, float]] = []
        self._built = False
        self._loader = None
        self._extras = None
        self._hlo_text = None
        self._step_exec = None        # AOT executable (set by hlo_text)
        self._stream_step = 0         # step index of the next host batch
        self._ext_steps = {}          # batch-structure -> jitted ext step

    # -- plumbing ----------------------------------------------------------

    def _log(self, msg: str) -> None:
        if self.verbose:
            print(msg, flush=True)

    def _make_trainer_config(self):
        from repro.core.trainer import TrainerConfig
        from repro.optim import cosine_warmup
        if self.trainer_override is not None:
            return self.trainer_override
        sched = self.lr_schedule or cosine_warmup(
            self.lr, max(1, self.steps // 10), self.steps)
        return TrainerConfig(
            plan=self.plan,
            pod_axis="pod" if self.spec.mesh_pod else None,
            lr_schedule=sched, donate=self.donate)

    def _proto_extras(self):
        """Family side-inputs (patches/frames protos) — constant across
        steps, so allocated once, not per batch in the loader hot path."""
        if self._extras is None:
            from repro.data.synthetic import synthetic_batch
            proto = synthetic_batch(self.cfg, type("S", (), {
                "global_batch": self.batch, "seq_len": self.seq})())
            self._extras = {k: proto[k] for k in ("patches", "frames")
                            if k in proto}
        return self._extras

    def _to_batch(self, host_batch):
        import jax.numpy as jnp
        b = {"tokens": jnp.asarray(host_batch["tokens"]),
             "targets": jnp.asarray(host_batch["targets"])}
        b.update(self._proto_extras())
        return b

    # -- lifecycle ---------------------------------------------------------

    def build(self) -> "TrainEngine":
        """Materialise params/optimizer/mesh, jit the step, restore the
        newest INTACT checkpoint when ckpt_dir has one (broken files are
        skipped with a ``ckpt_fallback`` event). Idempotent."""
        if self._built:
            return self
        import jax
        import numpy as np
        from repro import checkpoint as ckpt
        from repro.core.trainer import init_state, jit_train_step
        from repro.data import lm_batch_iterator, make_lm_data
        from repro.models import init_params
        from repro.optim import sgd_momentum

        self.mesh = self.spec.build_mesh()
        self._log(f"mesh: {dict(self.mesh.shape)}  arch: {self.cfg.name}  "
                  f"plan: {self.plan.name} (rule={self.plan.rule}, "
                  f"sync={self.plan.sync}, placement={self.plan.placement})")

        params = init_params(self.cfg, jax.random.PRNGKey(self.spec.seed))
        n_params = sum(int(np.prod(p.shape))
                       for p in jax.tree.leaves(params))
        self._log(f"params: {n_params/1e6:.2f}M")

        self.opt = self.optimizer or sgd_momentum(self.momentum,
                                                  self.weight_decay)
        self.trainer = self._make_trainer_config()
        self._n_data = self.mesh.shape[self.trainer.data_axis]
        self.state = init_state(self.cfg, self.trainer, params, self.opt,
                                mesh=self.mesh)

        tokens = make_lm_data(self.cfg.vocab_size, self.data_tokens,
                              seed=self.spec.seed)
        self._host_it = lm_batch_iterator(tokens, self.batch, self.seq,
                                          seed=self.spec.seed)
        batch0 = self._to_batch(next(self._host_it))
        self._batch0 = batch0
        self.step_fn, self.state_sh, self.batch_sh = jit_train_step(
            self.cfg, self.trainer, self.mesh, self.opt, self.state, batch0,
            self.custom_loss_fn)

        self.start_step = 0
        if self.ckpt_dir and ckpt.latest_step(self.ckpt_dir) is not None:
            try:
                self.state, self.start_step = ckpt.restore(
                    self.ckpt_dir, self.state,
                    on_fallback=lambda s, r: self.events.append(
                        "ckpt_fallback", s, reason=r))
            except FileNotFoundError:
                # every on-disk step is broken: start fresh rather than die
                self.events.append("ckpt_unusable", 0,
                                   dir=self.ckpt_dir)
                self._log(f"no intact checkpoint in {self.ckpt_dir}; "
                          f"starting from step 0")
            else:
                # the synthetic stream is a pure function of the step
                # index: skip what the interrupted run already consumed so
                # resumed == uninterrupted
                for _ in range(self.start_step):
                    next(self._host_it)
                self._log(f"restored step {self.start_step}")
        self._stream_step = self.start_step
        self._fresh_program = True
        self._built = True
        return self

    # -- data stream (resilient) -------------------------------------------

    def _rebuild_stream(self, step: int) -> None:
        """Fresh host iterator fast-forwarded so the next batch is step
        ``step``'s — bit-identical to the original stream (pure function
        of the step index): the recovery path for loader crashes and
        checkpoint rollback."""
        from repro.data import lm_batch_iterator, make_lm_data
        tokens = make_lm_data(self.cfg.vocab_size, self.data_tokens,
                              seed=self.spec.seed)
        it = lm_batch_iterator(tokens, self.batch, self.seq,
                               seed=self.spec.seed)
        next(it)                          # the build()-time trace batch
        for _ in range(step):
            next(it)
        self._host_it = it
        self._stream_step = step

    def _feed(self):
        """Host-batch generator for the loader worker; the loader-site
        fault hook lives here so an injected crash exercises the REAL
        worker-thread error path (exception raised on the prefetch thread,
        surfaced in ``__next__``)."""
        while True:
            try:
                b = next(self._host_it)
            except StopIteration:
                return
            s = self._stream_step
            self._stream_step = s + 1
            if self.injector is not None and self.injector.fires("loader", s):
                raise RuntimeError(
                    f"injected loader-worker fault at step {s}")
            yield self._to_batch(b)

    def _get_loader(self):
        """ONE persistent loader per engine: partial ``run()`` calls share
        it, so prefetched-but-untrained batches are consumed by the next
        call instead of silently dropped (the determinism contract holds
        for in-process continuation, not just checkpoint resume)."""
        from repro.data import ShardedLoader
        if self._loader is None:
            self._loader = ShardedLoader(self._feed(), self.batch_sh)
        return self._loader

    def _next_batch(self, step: int):
        """One batch for ``step``, surviving loader-worker crashes: a
        crashed worker's exception (re-raised by ``ShardedLoader.__next__``
        instead of hanging) is logged, the stream is rebuilt exactly at
        ``step``, and the batch is retried — up to ``loader_retries``
        rebuilds before giving up."""
        for attempt in range(self.loader_retries + 1):
            loader = self._get_loader()
            try:
                return next(loader)
            except StopIteration:
                raise
            except Exception as e:
                self.events.append("loader_error", step, error=repr(e),
                                   attempt=attempt)
                self._log(f"step {step}: loader worker died ({e!r}); "
                          f"rebuilding the stream (attempt {attempt + 1})")
                self.close()
                self._rebuild_stream(step)
        raise RuntimeError(
            f"loader failed {self.loader_retries + 1} times at step {step}")

    # -- checkpoint + rollback ---------------------------------------------

    def _save_checkpoint(self, step: int) -> None:
        from repro import checkpoint as ckpt
        path = ckpt.save(self.ckpt_dir, step, self.state,
                         keep_last=self.keep_last, injector=self.injector)
        self.events.append("ckpt_save", step)
        if self.injector is not None and \
                self.injector.fires("ckpt_truncate", step):
            # disk corruption / kill -9 straight after the commit: the
            # manifest checksum no longer matches, so restore() must skip
            # this step
            size = os.path.getsize(path)
            with open(path, "r+b") as fh:
                fh.truncate(max(1, size // 2))
            self.events.append("inject", step, site="ckpt_truncate")

    def _rollback(self, step: int) -> int:
        """Too many consecutive bad steps: restore the newest intact
        checkpoint, rewind the data stream to it, reset the guard.
        Returns the restored step (the new loop position)."""
        import jax
        from repro import checkpoint as ckpt
        restored = None
        if self.ckpt_dir:
            try:
                restored = ckpt.restore(
                    self.ckpt_dir, self.state,
                    on_fallback=lambda s, r: self.events.append(
                        "ckpt_fallback", s, reason=r))
            except FileNotFoundError:
                restored = None
        if restored is None:
            self.events.append("rollback_failed", step,
                               streak=self._bad_streak)
            raise RuntimeError(
                f"{self._bad_streak} consecutive bad steps at step {step} "
                f"and no intact checkpoint to roll back to "
                f"(ckpt_dir={self.ckpt_dir!r})")
        state, rstep = restored
        self.state = jax.device_put(state, self.state_sh)
        self.events.append("rollback", step, to_step=rstep,
                           streak=self._bad_streak)
        self._log(f"step {step}: {self._bad_streak} consecutive bad steps "
                  f"— rolling back to checkpoint step {rstep}")
        self.close()
        self._rebuild_stream(rstep)
        self.guard.reset()
        self._bad_streak = 0
        return rstep

    def _bump_step(self, state):
        """Advance ONLY the step counter (a skipped update keeps params and
        optimizer state): the loop position, LR schedule and CDP freshness
        stay in lockstep with the uninterrupted trajectory."""
        import jax
        import numpy as np
        new = dict(state)
        new["step"] = jax.device_put(np.int32(int(state["step"]) + 1),
                                     self.state_sh["step"])
        return new

    # -- elastic membership: snapshot / shrink / rejoin ----------------------

    def _state_template(self):
        """Shape/dtype skeleton of the CURRENT state layout — what the
        snapshot/checkpoint restore paths key on. Values are never read,
        so this stays valid even when the live buffers were donated."""
        import jax
        return jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self.state)

    def _host_state(self):
        import jax
        import numpy as np
        return jax.tree.map(lambda x: np.asarray(x), self.state)

    def _stage_sharded(self) -> bool:
        from repro.parallel import PLACE_STAGE_SHARDED
        return self.plan.placement == PLACE_STAGE_SHARDED

    def _take_snapshot(self, step: int) -> None:
        """Park a consistent snapshot of the committed state in the buddy
        store (``step`` = the resume point: the next step to run)."""
        from repro.engine import elastic as el
        t0 = time.monotonic()
        if self._snapshots is None or self._snapshots.n != self._n_data:
            self._snapshots = el.BuddySnapshotStore(
                self._n_data, chunked=self._stage_sharded())
        self._snapshots.take(step, self._host_state())
        dur = time.monotonic() - t0
        self._snapshot_s.append(dur)
        self.events.append("snapshot", step, dur_s=dur, n=self._n_data,
                           bytes=self._snapshots.nbytes)

    def _restore_point_for(self, step: int, dead: int):
        """(host_state, restored_step, source) for a rank-down recovery:
        the buddy snapshot when it survives the death, else the newest
        intact disk checkpoint. The state comes back at the OLD (pre-
        shrink) layout — the caller re-cuts it."""
        from repro import checkpoint as ckpt
        from repro.engine import elastic as el
        template = self._state_template()
        if self._snapshots is not None:
            self._snapshots.fail(dead)
            try:
                state, rstep = self._snapshots.assemble(template)
                return state, rstep, "snapshot"
            except el.SnapshotUnusable as e:
                self.events.append("snapshot_unusable", step, reason=str(e))
                self._log(f"step {step}: buddy snapshot unusable ({e}); "
                          f"falling back to disk")
        if self.ckpt_dir:
            try:
                state, rstep = ckpt.restore(
                    self.ckpt_dir, template,
                    on_fallback=lambda s, r: self.events.append(
                        "ckpt_fallback", s, reason=r))
                return state, rstep, "checkpoint"
            except FileNotFoundError:
                pass
        raise RuntimeError(
            f"data rank {dead} died at step {step} with no usable buddy "
            f"snapshot and no intact checkpoint "
            f"(snapshot_every={self.snapshot_every}, "
            f"ckpt_dir={self.ckpt_dir!r})")

    def _reprogram(self, host_state, stream_step: int) -> None:
        """Re-jit the step for the CURRENT mesh, land ``host_state`` on it,
        and invalidate everything compiled or prefetched for the old one
        (AOT executable, external-batch jits, the loader's shardings)."""
        import jax
        from repro.core.trainer import jit_train_step
        self.step_fn, self.state_sh, self.batch_sh = jit_train_step(
            self.cfg, self.trainer, self.mesh, self.opt, host_state,
            self._batch0, self.custom_loss_fn)
        self.state = jax.device_put(host_state, self.state_sh)
        self._hlo_text = None
        self._step_exec = None
        self._ext_steps = {}
        self._fresh_program = True
        self.close()
        self._rebuild_stream(stream_step)
        self._snapshots = None        # old-layout shards cannot restore the
                                      # resized ring; next take() re-creates

    def _recover_rank_down(self, step: int, dead: int, cause: str) -> int:
        """Rank ``dead`` is gone: re-form the ring on the N-1 survivors
        from the newest consistent snapshot (disk as fallback) and resume.
        Returns the restored step (the new loop position)."""
        n_old = self._n_data
        self.events.append("rank_down", step, rank=dead, cause=cause,
                           n=n_old)
        self._log(f"step {step}: data rank {dead} is down ({cause})")
        if not self.elastic:
            raise RuntimeError(
                f"data rank {dead} went down at step {step} and elastic "
                "membership is off (pass elastic=True / --elastic)")
        if not 0 <= dead < n_old:
            raise ValueError(
                f"rank_down rank {dead} outside the data axis (size {n_old})")
        from repro.engine.spec import shrink_mesh
        n_new = n_old - 1
        t0 = time.monotonic()
        self.plan.validate_resize(n_old, n_new)
        if self.batch % n_new:
            raise ValueError(
                f"global batch {self.batch} does not divide over the "
                f"{n_new} survivor(s); cannot re-form the ring")
        # pick the restore point BEFORE touching the mesh: the snapshot /
        # checkpoint is at the old layout and restores via its template
        host_state, rstep, source = self._restore_point_for(step, dead)
        self.mesh = shrink_mesh(self.mesh, dead, self.trainer.data_axis)
        if self._stage_sharded():
            from repro.parallel import zero_cdp as zcdp
            host_state = zcdp.recut_stage_state(self.cfg, host_state,
                                                n_old, n_new)
        self._n_data = n_new
        self._reprogram(host_state, rstep)
        if self.guard is not None:
            self.guard.reset()
        self._bad_streak = 0
        if self.watchdog is not None:
            self.watchdog.disarm()
        dur = time.monotonic() - t0
        self.recoveries.append({
            "failed_at": step, "step": rstep, "dead": dead, "cause": cause,
            "n": n_new, "source": source, "steps_lost": step - rstep,
            "duration_s": dur, "state": host_state})
        self.events.append("recover", rstep, failed_at=step, n=n_new,
                           source=source, steps_lost=step - rstep,
                           dur_s=dur)
        self._log(f"re-formed the ring on {n_new} rank(s) from {source} "
                  f"step {rstep} ({step - rstep} step(s) lost, "
                  f"{dur:.2f}s)")
        if self.rejoin_after:
            self._rejoin_at = rstep + self.rejoin_after
        return rstep

    def rejoin(self, step: int) -> None:
        """Scale back up at a step boundary: the failed rank returned, the
        mesh re-forms at the spec's full size and the state is re-cut
        N-1 -> N. No rewind — a step boundary is already a consistent cut
        (the rejoining rank receives its chunk instead of contributing
        one)."""
        n_old, n_new = self._n_data, self.spec.mesh_data
        if n_new <= n_old:
            raise RuntimeError(
                f"rejoin at step {step}: already at {n_old} rank(s)")
        t0 = time.monotonic()
        self.plan.validate_resize(n_old, n_new)
        if self.batch % n_new:
            raise ValueError(
                f"global batch {self.batch} does not divide over "
                f"{n_new} ranks; cannot rejoin")
        host_state = self._host_state()
        self.mesh = self.spec.build_mesh()
        if self._stage_sharded():
            from repro.parallel import zero_cdp as zcdp
            host_state = zcdp.recut_stage_state(self.cfg, host_state,
                                                n_old, n_new)
        self._n_data = n_new
        self._reprogram(host_state, step)
        self._rejoin_at = None
        dur = time.monotonic() - t0
        self.events.append("rejoin", step, n=n_new, dur_s=dur)
        self._log(f"step {step}: failed rank rejoined — ring re-formed at "
                  f"{n_new} ranks ({dur:.2f}s)")

    # -- external batches (the RL rollout path) ------------------------------

    def step_external(self, batch) -> Dict[str, float]:
        """Run ONE jitted train step on an externally built batch instead
        of the LM loader stream — the rollout loop's policy-gradient path.

        The batch may carry leaves the LM stream does not (``mask``,
        ``adv``), so the step is jitted once per batch STRUCTURE (sorted
        keys + shapes + dtypes) through the same ``jit_train_step`` the
        loader path uses — same plan, same donation, same shardings; pass
        a custom ``loss_fn=`` at construction to consume the extra leaves
        (it must return ``(loss, metrics)`` with a ``"loss"`` entry).
        Advances ``self.state`` and returns the metrics as host floats."""
        import jax.numpy as jnp
        from repro.core.trainer import jit_train_step
        self.build()
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        sig = tuple(sorted((k, v.shape, str(v.dtype))
                           for k, v in batch.items()))
        step_fn = self._ext_steps.get(sig)
        if step_fn is None:
            step_fn, _, _ = jit_train_step(
                self.cfg, self.trainer, self.mesh, self.opt, self.state,
                batch, self.custom_loss_fn)
            self._ext_steps[sig] = step_fn
        self.state, metrics = step_fn(self.state, batch)
        return {k: float(v) for k, v in metrics.items()}

    # -- compiled-step access ----------------------------------------------

    def hlo_text(self) -> str:
        """Optimized HLO of the compiled train step (builds if needed) —
        feed to ``launch.roofline.parse_collectives`` to read the plan's
        communication signature (all-reduce burst vs collective-permute
        ring vs streamed stages) off the real program. The AOT executable
        is kept and ``run()`` steps with it — call this BEFORE run() (the
        demo/benchmark order) and the whole engine compiles exactly once;
        after run() it costs one extra compile (the jit cache is not
        shared), cached for repeat calls."""
        if self._hlo_text is None:
            import jax
            self.build()
            compiled = self.step_fn.lower(self.state, self._batch0).compile()
            self._hlo_text = compiled.as_text()
            # unlike jit dispatch, the AOT executable does not auto-place
            # its inputs — commit the state to its shardings once
            self.state = jax.device_put(self.state, self.state_sh)
            self._step_exec = compiled
        return self._hlo_text

    def close(self) -> None:
        if self._loader is not None:
            self._loader.close()
            self._loader = None

    # -- the loop ----------------------------------------------------------

    def run(self, steps: Optional[int] = None) -> PyTree:
        """Train to ``steps`` (default: the configured total), checkpointing
        and logging on the way. Returns the final state. Stopping early
        (``steps < self.steps``) keeps the loader alive for continuation;
        reaching the configured total closes it."""
        self.build()
        total = self.steps if steps is None else steps
        t0 = time.time()
        if self.elastic and self.snapshot_every and self._snapshots is None:
            # arm the buddy store before the first step: a death in the
            # first interval recovers to here instead of dying diskless
            self._take_snapshot(self.start_step)
        try:
            step_fn = self._step_exec if self._step_exec is not None \
                else self.step_fn
            step = self.start_step
            while step < total:
                if self._rejoin_at is not None and step >= self._rejoin_at:
                    self.rejoin(step)
                    step_fn = self.step_fn
                if self.injector is not None:
                    f = self.injector.fires("rank_down", step)
                    if f is not None:
                        step = self._recover_rank_down(
                            step, dead=int(f.arg), cause="rank_down")
                        step_fn = self.step_fn
                        continue
                batch = self._next_batch(step)
                if self.injector is not None:
                    f = self.injector.fires("slow_step", step)
                    if f is not None:
                        # simulated preemption stall: the run survives it,
                        # the event log shows where the time went
                        dur = f.arg or 0.05
                        self.events.append("slow_step", step, sleep_s=dur)
                        time.sleep(dur)
                # the watchdog measures dispatch -> results materialized;
                # the first step after a (re)jit compiles, so it is exempt
                armed = self.watchdog is not None and not self._fresh_program
                if armed:
                    self.watchdog.arm(step)
                new_state, metrics = step_fn(self.state, batch)
                metrics = dict(metrics)
                if self.injector is not None:
                    f = self.injector.fires("step_hang", step)
                    if f is not None:
                        # a hung collective: a ring peer died mid-permute
                        # and this step never completes on the survivors —
                        # simulated as a stall past the watchdog deadline
                        dur = f.arg or (1.5 * self.watchdog.timeout_s
                                        if self.watchdog else 0.1)
                        self.events.append("inject", step, site="step_hang",
                                           sleep_s=dur)
                        time.sleep(dur)
                if armed:
                    float(metrics["loss"])    # block until the step is done
                    over = self.watchdog.expired()
                    if over is not None:
                        # indistinguishable from a dead peer on the ring:
                        # presume the highest rank dead and recover (its
                        # results never land, so drop this step's output)
                        self.events.append(
                            "step_hang", step, elapsed_s=over,
                            timeout_s=self.watchdog.timeout_s)
                        self._log(f"step {step}: exceeded the "
                                  f"{self.watchdog.timeout_s:.1f}s deadline "
                                  f"({over:.1f}s) — presuming a dead peer")
                        step = self._recover_rank_down(
                            step, dead=self._n_data - 1, cause="step_hang")
                        step_fn = self.step_fn
                        continue
                self._fresh_program = False
                if self.injector is not None:
                    new_state, metrics = self._inject_step_faults(
                        step, new_state, metrics)
                if self.guard is not None and \
                        not self._healthy(step, metrics):
                    if self._bad_streak >= self.guard_max_bad:
                        step = self._rollback(step)
                    else:
                        # skip the bad update: keep params/opt, advance the
                        # step counter — under CDP's uniform-staleness rules
                        # this is one more bounded delay, not a divergence
                        self.state = self._bump_step(self.state)
                        step += 1
                    continue
                self.state = new_state
                if step % self.log_every == 0 or step == total - 1:
                    rec = {"step": step,
                           "loss": float(metrics["loss"]),
                           "lr": float(metrics["lr"])}
                    self.history.append(rec)
                    self._log(f"step {step:5d}  loss {rec['loss']:.4f}  "
                              f"lr {rec['lr']:.4f}  {time.time()-t0:.1f}s")
                if self.ckpt_dir and (step + 1) % self.ckpt_every == 0:
                    self._save_checkpoint(step + 1)
                if self.elastic and self.snapshot_every and \
                        (step + 1) % self.snapshot_every == 0:
                    self._take_snapshot(step + 1)
                step += 1
        finally:
            if total >= self.steps:
                self.close()
        # never move the resume pointer backwards: a later run() with a
        # smaller target must not re-train completed steps
        self.start_step = max(self.start_step, total)
        return self.state

    def _inject_step_faults(self, step, new_state, metrics):
        import jax
        import jax.numpy as jnp
        f = self.injector.fires("nan_loss", step)
        if f is not None:
            # a real NaN gradient poisons the whole update, not just the
            # reported loss — corrupt both so an unguarded run genuinely
            # diverges
            poison = lambda x: x * jnp.nan \
                if jnp.issubdtype(x.dtype, jnp.inexact) else x
            new_state = dict(new_state)
            new_state["params"] = jax.tree.map(poison, new_state["params"])
            metrics["loss"] = float("nan")
            self.events.append("inject", step, site="nan_loss")
        f = self.injector.fires("loss_spike", step)
        if f is not None:
            factor = f.arg or 1e3
            metrics["loss"] = float(metrics["loss"]) * factor
            self.events.append("inject", step, site="loss_spike",
                               factor=factor)
        return new_state, metrics

    def _healthy(self, step, metrics) -> bool:
        loss = float(metrics["loss"])
        verdict = self.guard.check(loss)
        if verdict == "ok":
            self._bad_streak = 0
            return True
        self._bad_streak += 1
        self.events.append("skip", step, reason=verdict, loss=loss,
                           streak=self._bad_streak)
        self._log(f"step {step}: {verdict} loss ({loss}) — skipping the "
                  f"update (streak {self._bad_streak}/{self.guard_max_bad})")
        return False
