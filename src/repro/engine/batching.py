"""Continuous-batching building blocks for :class:`ServeEngine`.

Iteration-level (Orca-style) scheduling: the engine keeps ONE fixed-shape
decode batch of ``n_slots`` rows and admits a queued request into a slot the
moment the slot's previous request finishes — a single long generation no
longer holds every slot hostage until the whole batch drains (the same
peak-resource pathology the paper's cyclic schedule removes from training).

Three framework-light pieces live here so the engine stays a thin loop:

  * :class:`Request` / :func:`poisson_trace` — the workload description and
    a deterministic arrival-trace generator (arrival times are measured in
    decode STEPS, the scheduler's logical clock, so replays are exact).
  * :class:`SlotScheduler` — host-side slot bookkeeping with an event log.
    Invariants (tested): a slot serves at most ONE live request; a request
    occupies exactly one contiguous slot interval; tokens are only ever
    attributed to the slot's live owner.
  * cache surgery — :func:`cache_batch_axes` discovers each cache leaf's
    batch axis STRUCTURALLY (build the cache shape at two batch sizes and
    see which axis scaled; stacked-layer layouts put the row axis at
    different depths per family), and :func:`merge_caches` uses it to
    splice freshly prefilled rows into a live cache, which is what lets a
    new prompt prefill into a running batch without retracing.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, List, Optional, Union

import numpy as np

PyTree = Any


# ---------------------------------------------------------------------------
# Requests and arrival traces
# ---------------------------------------------------------------------------

#: terminal request states: every request leaves ``serve()`` in exactly one
REQUEST_STATUSES = ("ok", "timeout", "rejected", "failed")


@dataclasses.dataclass
class Request:
    """One serving request. ``arrival_step`` is in decode steps (the
    scheduler's logical clock); ``tokens`` is filled in by the engine after
    the request completes. ``status`` is the degradation contract: serve()
    always returns every request with a terminal status ("ok" | "timeout"
    | "rejected" | "failed") and whatever partial ``tokens`` it earned —
    it never raises a per-request failure at the whole batch.
    ``deadline_steps`` is this request's step budget (queue wait + decode)
    overriding serve()'s engine-wide default.

    Sampling controls are per-request so one jitted decode step can serve
    a heterogeneous batch (rollout groups need diverse samples of the SAME
    prompt): ``temperature`` overrides the engine-wide default (<= 0 means
    greedy for this row), ``top_k`` restricts sampling to the k most
    likely tokens (None/0 disables), and ``seed`` replaces ``rid`` as the
    fold-in for this request's sampling key stream — two requests with the
    same prompt and different seeds decode different continuations.

    Wall-clock serving (``ServePolicy.clock`` "wall" | "virtual") reads
    ``arrival_time``/``deadline_s`` in SECONDS instead of the step fields
    (each defaults to its step twin scaled by ``ServePolicy.step_dt`` when
    unset). ``on_token`` is the streaming hook: called as
    ``on_token(rid, token, step, wall_t)`` from the engine's post-step
    host sync for every token this request emits — it observes the host
    copy only, so greedy streams are bitwise identical with and without
    it."""
    rid: int
    prompt: np.ndarray                  # [S] int32, unpadded
    max_gen: int
    arrival_step: int = 0
    tokens: Optional[np.ndarray] = None
    status: str = "queued"
    error: Optional[str] = None
    deadline_steps: Optional[int] = None
    temperature: Optional[float] = None
    top_k: Optional[int] = None
    seed: Optional[int] = None
    arrival_time: Optional[float] = None      # seconds (wall/virtual clock)
    deadline_s: Optional[float] = None        # seconds (wall/virtual clock)
    on_token: Optional[Callable[[int, int, int, float], None]] = None


def poisson_trace(n: int, rate: float, seed: int = 0) -> List[int]:
    """Deterministic Poisson arrival steps: cumulative exponential gaps with
    mean ``1/rate`` decode steps, floored to the step grid."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(rate, 1e-9), size=n)
    return np.floor(np.cumsum(gaps)).astype(int).tolist()


def synthetic_requests(n: int, vocab: int, prompt_len: int, max_gen: int,
                       *, arrival: str = "none", rate: float = 0.5,
                       seed: int = 0) -> List[Request]:
    """A staggered-length workload: prompt lengths in [prompt_len//2,
    prompt_len], generation lengths alternating short (max_gen//4) and long
    (max_gen) — the shape continuous batching wins on. ``arrival`` is
    "none" (all at step 0) or "poisson" (trace replay via
    :func:`poisson_trace`)."""
    rng = np.random.default_rng(seed)
    arrivals = (poisson_trace(n, rate, seed) if arrival == "poisson"
                else [0] * n)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(max(1, prompt_len // 2), prompt_len + 1))
        gen = max(1, max_gen // 4) if i % 2 else max_gen
        prompt = rng.integers(0, vocab, size=plen).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_gen=gen,
                            arrival_step=arrivals[i]))
    return reqs


# ---------------------------------------------------------------------------
# ServePolicy: the one serve() configuration surface
# ---------------------------------------------------------------------------

#: scheduler clock modes: "step" is the historical decode-step logical
#: clock (bitwise-reproducible trace replay); "wall" reads the monotonic
#: clock in seconds (arrival_time/deadline_s); "virtual" runs the SAME
#: wall-clock code path on a deterministic clock (now = step * step_dt),
#: so wall-mode scheduling is testable bitwise.
CLOCK_MODES = ("step", "wall", "virtual")


@dataclasses.dataclass
class ServePolicy:
    """Everything ``ServeEngine.serve()`` used to take as nine kwargs, plus
    the chunked-prefill / wall-clock / admission knobs. ``serve(policy=
    ServePolicy(...))`` is the surface; the old kwargs remain as deprecated
    aliases resolved by :func:`serve_policy_from_legacy_kwargs`.

    ``prefill_chunk`` > 0 cuts every admitted prompt into chunks of that
    many tokens, prefilled one chunk per scheduler iteration interleaved
    with decode (a partially-prefilled request has status "prefilling" and
    emits nothing); 0 keeps the historical whole-prompt admission prefill.
    ``admission`` picks the queue-ordering policy ("fcfs" | "slo", or an
    :class:`AdmissionPolicy` instance). ``watchdog_s`` arms a
    :class:`~repro.engine.resilience.StepWatchdog` around each decode step
    in wall/virtual clock mode (slow steps land in the event log)."""
    max_slots: Optional[int] = None
    num_requests: int = 8
    arrival: str = "none"
    rate: float = 0.5
    eos_id: Optional[int] = None
    policy: str = "continuous"                # "continuous" | "static"
    deadline_steps: Optional[int] = None
    queue_limit: Optional[int] = None
    max_steps: int = 1_000_000
    prefill_chunk: int = 0                    # 0 = whole-prompt prefill
    admission: Union[str, "AdmissionPolicy"] = "fcfs"
    clock: str = "step"                       # "step" | "wall" | "virtual"
    step_dt: float = 1.0                      # virtual seconds per step
    deadline_s: Optional[float] = None        # wall/virtual default deadline
    watchdog_s: Optional[float] = None        # slow-step watchdog (wall)

    def __post_init__(self):
        if self.policy not in ("continuous", "static"):
            raise ValueError(f"unknown policy {self.policy!r}")
        if self.clock not in CLOCK_MODES:
            raise ValueError(f"unknown clock {self.clock!r} "
                             f"(expected one of {CLOCK_MODES})")
        if self.prefill_chunk < 0:
            raise ValueError(f"prefill_chunk={self.prefill_chunk} must "
                             "be >= 0")
        if isinstance(self.admission, str) and \
                self.admission not in ("fcfs", "slo"):
            raise ValueError(f"unknown admission policy "
                             f"{self.admission!r} (expected 'fcfs', 'slo' "
                             "or an AdmissionPolicy instance)")


#: the legacy serve() kwargs ServePolicy absorbed, in their historical order
LEGACY_SERVE_KWARGS = ("max_slots", "num_requests", "arrival", "rate",
                       "eos_id", "policy", "deadline_steps", "queue_limit",
                       "max_steps")


def serve_policy_from_legacy_kwargs(**kwargs) -> ServePolicy:
    """The :class:`ServePolicy` a deprecated ``serve(max_slots=..., ...)``
    call meant (the ``plan_from_legacy_flags`` idiom). Emits ONE
    `DeprecationWarning` naming the kwargs that were passed; unknown
    kwargs raise TypeError like a real signature would."""
    given = {k: v for k, v in kwargs.items() if v is not None}
    unknown = set(given) - set(LEGACY_SERVE_KWARGS)
    if unknown:
        raise TypeError(f"serve() got unexpected keyword arguments "
                        f"{sorted(unknown)}")
    if given:
        warnings.warn(
            f"serve({', '.join(sorted(given))}=...) kwargs are deprecated; "
            "pass serve(policy=ServePolicy(...)) instead",
            DeprecationWarning, stacklevel=3)
    return ServePolicy(**given)


# ---------------------------------------------------------------------------
# Admission policies (host-side, framework-free)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AdmissionContext:
    """What an :class:`AdmissionPolicy` may read when ordering the waiting
    queue: the scheduler clock, slot/queue pressure, the chunked-prefill
    granularity, and the engine event log's per-step degradation signals
    (timeouts and queue rejections so far)."""
    step: int
    now: float                      # clock units (steps, or seconds)
    free_slots: int
    queue_depth: int
    prefill_chunk: int              # 0 = whole-prompt prefill
    default_deadline: Optional[float]   # engine-wide, clock units
    timeouts: int = 0
    rejects: int = 0
    step_dt: float = 1.0            # clock units per scheduler iteration
    # engine-supplied clock resolution (wall/virtual modes map seconds
    # fields); the step-clock fallback below keeps the context usable
    # standalone in tests
    deadline_fn: Optional[Callable[[Request], Optional[float]]] = None

    def deadline_of(self, req: Request) -> Optional[float]:
        """Absolute deadline of ``req`` in clock units (None = none)."""
        if self.deadline_fn is not None:
            return self.deadline_fn(req)
        d = req.deadline_steps if req.deadline_steps is not None \
            else self.default_deadline
        return None if d is None else req.arrival_step + d

    def cost_of(self, req: Request) -> float:
        """Estimated clock units to finish ``req`` from admission: its
        prefill chunks plus one decode iteration per generated token,
        scaled by ``step_dt``."""
        chunk = self.prefill_chunk or len(req.prompt)
        iters = -(-len(req.prompt) // max(chunk, 1)) + req.max_gen
        return iters * self.step_dt


class AdmissionPolicy:
    """Orders (and optionally culls) the waiting queue each scheduler
    iteration; the engine admits from the front of the returned list while
    slots are free. Requests NOT returned stay queued (and expire through
    the normal deadline machinery)."""
    name = "base"

    def select(self, waiting: List[Request],
               ctx: AdmissionContext) -> List[Request]:
        raise NotImplementedError


class FCFSAdmission(AdmissionPolicy):
    """Arrival order, admit everything — the historical behaviour."""
    name = "fcfs"

    def select(self, waiting, ctx):
        return list(waiting)


class SLOAdmission(AdmissionPolicy):
    """Deadline-aware admission: earliest-deadline-first with feasibility
    culling. A request whose estimated cost (prefill chunks + max_gen
    decode steps) cannot fit inside its remaining deadline is SKIPPED —
    admitting it would burn a slot on work the deadline eviction will
    throw away, starving feasible requests behind it (the fcfs failure
    mode on a deadline-heavy queue). Ties break toward shorter prompts
    (protecting time-to-first-token of the cheap requests), then rid."""
    name = "slo"

    def select(self, waiting, ctx):
        feasible = []
        for r in waiting:
            d = ctx.deadline_of(r)
            if d is not None and ctx.now + ctx.cost_of(r) > d:
                continue                      # doomed: let it expire queued
            feasible.append(r)
        inf = float("inf")
        return sorted(feasible,
                      key=lambda r: (ctx.deadline_of(r) if ctx.deadline_of(r)
                                     is not None else inf,
                                     len(r.prompt), r.rid))


def resolve_admission(admission) -> AdmissionPolicy:
    """"fcfs" | "slo" | AdmissionPolicy instance -> AdmissionPolicy."""
    if isinstance(admission, AdmissionPolicy):
        return admission
    if admission == "fcfs":
        return FCFSAdmission()
    if admission == "slo":
        return SLOAdmission()
    raise ValueError(f"unknown admission policy {admission!r}")


# ---------------------------------------------------------------------------
# Slot scheduler (host-side, framework-free)
# ---------------------------------------------------------------------------

class SlotScheduler:
    """Iteration-level slot bookkeeping. The engine drives it:

        admit(slot, req, step, hist_idx)  — slot takes a queued request
        log_emissions(step, now)          — one token logged per live slot;
                                            returns slots that just finished
        evict(slot, step, now, reason)    — early termination (deadline /
                                            quarantine): frees the slot,
                                            keeps the partial emission count
        preempt(slot, step)               — paged-pool preemption: frees the
                                            slot WITHOUT terminating the
                                            request; a later ``admit(...,
                                            resume=True)`` continues it (in
                                            any slot)
        close(rid, step, now, reason)     — terminate a request that is not
                                            currently live (e.g. a parked /
                                            offloaded request whose deadline
                                            expired)

    A request's emissions therefore live in one or more SEGMENTS — contiguous
    (history-row, slot) intervals recorded in ``segments[rid]`` as
    ``[hist_idx, slot, count]`` triples; the engine reconstructs tokens by
    concatenating them. ``first_hist``/``slot_of`` keep their historical
    meaning (first segment's start, most recent slot) so single-segment
    consumers are unaffected.

    ``events`` is an append-only log of ("admit"|"resume"|"preempt"|
    "complete"|reason, step, slot, rid) tuples for tests and reporting."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.owner: List[Optional[int]] = [None] * n_slots
        self.logged = [0] * n_slots
        self.requests: Dict[int, Request] = {}
        self.slot_of: Dict[int, int] = {}
        self.first_hist: Dict[int, int] = {}
        self.segments: Dict[int, List[List[int]]] = {}
        self.admit_step: Dict[int, int] = {}
        self.complete_step: Dict[int, int] = {}
        self.complete_time: Dict[int, float] = {}
        self.gen_done: Dict[int, int] = {}
        self.events: List[tuple] = []
        # slots whose request is still mid-chunked-prefill: they own the
        # slot (nobody else can be admitted into it) but emit NO tokens
        # until prefill_done() flips them live
        self.prefilling: set = set()

    # -- queries ------------------------------------------------------------

    def free_slots(self) -> List[int]:
        return [i for i, o in enumerate(self.owner) if o is None]

    def live_slots(self) -> List[int]:
        return [i for i, o in enumerate(self.owner) if o is not None]

    # -- transitions ---------------------------------------------------------

    def admit(self, slot: int, req: Request, step: int, hist_idx: int,
              resume: bool = False, prefilling: bool = False) -> None:
        if self.owner[slot] is not None:
            raise RuntimeError(
                f"slot {slot} already serves request {self.owner[slot]}")
        if req.rid in self.requests and not resume:
            raise RuntimeError(f"request {req.rid} admitted twice")
        self.owner[slot] = req.rid
        self.logged[slot] = 0
        self.requests[req.rid] = req
        self.slot_of[req.rid] = slot
        self.segments.setdefault(req.rid, []).append([hist_idx, slot, 0])
        self.first_hist.setdefault(req.rid, hist_idx)
        self.admit_step[req.rid] = step
        if prefilling:
            # mid-chunked-prefill: hist_idx is provisional (the engine
            # rewrites it via prefill_done once the last chunk lands and
            # the slot starts emitting)
            self.prefilling.add(slot)
        self.events.append(("resume" if resume else "admit", step, slot,
                            req.rid))

    def prefill_done(self, slot: int, step: int, hist_idx: int) -> None:
        """The slot's chunked prefill finished: it starts emitting at
        history row ``hist_idx``. Rewrites the provisional segment start
        recorded at admit time (the engine only knows the true emission
        row once the final chunk lands)."""
        if slot not in self.prefilling:
            raise RuntimeError(f"prefill_done on non-prefilling slot {slot}")
        self.prefilling.discard(slot)
        rid = self.owner[slot]
        self.segments[rid][-1][0] = hist_idx
        if len(self.segments[rid]) == 1:
            self.first_hist[rid] = hist_idx

    def total_gen(self, rid: int) -> int:
        """Emissions logged for the request across ALL of its segments."""
        return sum(c for _, _, c in self.segments.get(rid, []))

    def token_segments(self, rid: int) -> List[List[int]]:
        """[hist_idx, slot, count] triples; concatenating
        ``history[h:h+c, slot]`` over them reconstructs the token stream."""
        return self.segments.get(rid, [])

    def log_emissions(self, step: int, now: float,
                      eos_hit: Optional[List[bool]] = None) -> List[int]:
        """One emission was just logged for every live slot. Rows that hit
        their generation budget (or EOS) complete and free their slot.
        Returns the freed slot ids."""
        freed = []
        for slot in self.live_slots():
            if slot in self.prefilling:
                continue                     # mid-prefill: emits nothing
            rid = self.owner[slot]
            self.logged[slot] += 1
            self.segments[rid][-1][2] += 1
            done = self.total_gen(rid) >= self.requests[rid].max_gen
            if eos_hit is not None and eos_hit[slot]:
                done = True
            if done:
                self.gen_done[rid] = self.total_gen(rid)
                self.complete_step[rid] = step
                self.complete_time[rid] = now
                self.events.append(("complete", step, slot, rid))
                self.owner[slot] = None
                freed.append(slot)
        return freed

    def evict(self, slot: int, step: int, now: float, reason: str) -> int:
        """Terminate the slot's live request early (deadline expiry or
        poison quarantine). The partial emission count is kept so the
        engine can return the tokens generated so far. Returns the evicted
        rid."""
        rid = self.owner[slot]
        if rid is None:
            raise RuntimeError(f"evict on free slot {slot}")
        self.gen_done[rid] = self.total_gen(rid)
        self.complete_step[rid] = step
        self.complete_time[rid] = now
        self.events.append((reason, step, slot, rid))
        self.owner[slot] = None
        self.prefilling.discard(slot)
        return rid

    def preempt(self, slot: int, step: int) -> int:
        """Free the slot WITHOUT terminating its request (paged block-pool
        preemption). The request's segment log stays; a later
        ``admit(..., resume=True)`` opens its next segment. Returns the
        preempted rid."""
        rid = self.owner[slot]
        if rid is None:
            raise RuntimeError(f"preempt on free slot {slot}")
        self.owner[slot] = None
        self.prefilling.discard(slot)
        self.events.append(("preempt", step, slot, rid))
        return rid

    def close(self, rid: int, step: int, now: float, reason: str) -> None:
        """Terminate a request that is NOT live in any slot (e.g. parked in
        host RAM when its deadline expired). Keeps the emissions already
        segmented so the engine returns partial tokens."""
        self.gen_done[rid] = self.total_gen(rid)
        self.complete_step[rid] = step
        self.complete_time[rid] = now
        self.events.append((reason, step, self.slot_of.get(rid, -1), rid))


# ---------------------------------------------------------------------------
# Cache surgery: structural batch-axis discovery + per-row merge
# ---------------------------------------------------------------------------

def cache_batch_axes(init_fn: Callable[[int], PyTree]) -> PyTree:
    """Per-leaf batch-axis index of the cache pytree built by
    ``init_fn(batch)``. Discovered structurally via ``jax.eval_shape`` at
    two batch sizes (no memory is allocated): the one axis whose extent
    scaled with the batch is the row axis — stacked-layer layouts put it at
    depth 1 ([L,B,T,...]) or 2 ([P,per,B,...]) depending on the family, so
    hardcoding would couple this module to every cache layout."""
    import jax

    s2 = jax.eval_shape(lambda: init_fn(2))
    s3 = jax.eval_shape(lambda: init_fn(3))

    def axis(a, b):
        diffs = [i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                 if x != y]
        if len(diffs) != 1:
            raise ValueError(
                f"cannot identify a unique batch axis: {a.shape} vs {b.shape}")
        return diffs[0]

    return jax.tree.map(axis, s2, s3)


def merge_caches(live: PyTree, fresh: PyTree, admit_mask, axes: PyTree):
    """Row-select between a live cache and a freshly prefilled one:
    ``admit_mask`` ([B] bool) rows take ``fresh``, the rest keep ``live``.
    This is the slot-local cache reset: ONE jitted where per leaf, no
    retrace, no host round-trip."""
    import jax
    import jax.numpy as jnp

    def sel(old, new, ax):
        m = admit_mask.reshape((1,) * ax + (-1,) +
                               (1,) * (old.ndim - ax - 1))
        return jnp.where(m, new, old)

    return jax.tree.map(sel, live, fresh, axes)
