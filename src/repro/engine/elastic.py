"""Elastic CDP: buddy-replicated host-RAM snapshots for rank-failure
recovery.

The paper's ZeRO-CDP layout (Sec. 4.4) makes each data rank the
persistent owner of one stage chunk of the f32 masters — which means a
dead rank takes a unique 1/N of the training state with it. The classic
answer is a disk checkpoint; the elastic answer here is cheaper and
loses less: every ``snapshot_every`` steps each rank parks its own chunk
in host RAM and mirrors a copy to its RING PREDECESSOR (the rank that
already talks to it every tick, so on a real deployment the mirror rides
the existing point-to-point channel). Any SINGLE rank death is then
recoverable from memory — rank r's chunk survives either as r's primary
or as the mirror held by rank (r-1) mod N — and recovery loses at most
``snapshot_every`` steps without touching disk. Two ADJACENT deaths (a
chunk losing both its primary and its mirror holder) raise
:class:`SnapshotUnusable` and the engine falls back to
``checkpoint.restore``'s newest-intact walk.

For tree-layout plans (dp / cdp_v1 / cdp_v2) the state is replicated, so
the "snapshot" is one full copy per rank and ANY survivor can restore
alone — same API, trivially stronger guarantee.

This is a single-process simulation of per-rank host memory (matching
the repo's forced-host-device meshes): the store keys snapshots by rank
and models a death by discarding that rank's holdings. The integrity
story is shared with the disk path — each shard is a
``checkpoint.MemorySnapshot`` with per-array CRC32s, the in-memory
analogue of the manifest.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Set

import numpy as np

from repro.checkpoint import io as ckpt_io

PyTree = Any


class SnapshotUnusable(RuntimeError):
    """The buddy store cannot reassemble a consistent state (no snapshot
    taken yet, a chunk lost both its primary and its mirror holder, or a
    checksum failed). The engine's next resort is the disk checkpoint."""


class BuddySnapshotStore:
    """Per-rank host-RAM snapshot storage with ring-buddy replication.

    ``take(step, state)`` splits a host-side train state across ``n``
    simulated rank memories:

      * ``chunked=True`` (stage-sharded plans): every ``[n, chunk]`` leaf
        is cut by row — rank r keeps row r of each as its PRIMARY shard
        plus every replicated scalar (``step`` etc.), and additionally
        holds a MIRROR of rank ``(r+1) % n``'s shard (i.e. each rank
        mirrors its chunk to its ring predecessor);
      * ``chunked=False`` (replicated plans): every rank keeps one full
        copy; mirrors would be redundant and are skipped.

    ``fail(r)`` models rank r's process dying with its host memory.
    ``assemble(template)`` rebuilds ``(state, step)`` from surviving
    primaries + mirrors, verifying every shard's CRC32s, or raises
    :class:`SnapshotUnusable`.
    """

    def __init__(self, n: int, chunked: bool):
        if n < 1:
            raise ValueError(f"need >= 1 rank, got {n}")
        self.n = int(n)
        self.chunked = bool(chunked)
        self.step: Optional[int] = None
        self.failed: Set[int] = set()
        self._own: Dict[int, ckpt_io.MemorySnapshot] = {}
        self._mirror: Dict[int, ckpt_io.MemorySnapshot] = {}
        self._chunk_keys: Set[str] = set()

    @property
    def nbytes(self) -> int:
        """Total host RAM parked across all ranks (primaries + mirrors)."""
        return (sum(s.nbytes for s in self._own.values())
                + sum(s.nbytes for s in self._mirror.values()))

    def take(self, step: int, state: PyTree) -> None:
        """Park a consistent snapshot of ``state`` (a host tree, taken at
        a step boundary) across the surviving ranks' memories. Replaces
        the previous snapshot — each rank holds exactly one step."""
        flat = ckpt_io._flatten(state)
        if self.chunked:
            self._chunk_keys = {k for k, v in flat.items()
                                if v.ndim == 2 and v.shape[0] == self.n}
            if not self._chunk_keys:
                raise ValueError(
                    f"chunked snapshot mode but no [{self.n}, chunk] "
                    "leaves in the state")
        else:
            self._chunk_keys = set()
        self._own.clear()
        self._mirror.clear()
        for r in range(self.n):
            if r in self.failed:
                continue
            shard = {k: (v[r] if k in self._chunk_keys else v)
                     for k, v in flat.items()}
            self._own[r] = ckpt_io.MemorySnapshot.from_flat(step, shard)
        if self.chunked:
            for r in range(self.n):
                succ = (r + 1) % self.n
                if r in self.failed or succ not in self._own:
                    continue
                self._mirror[r] = ckpt_io.MemorySnapshot.from_flat(
                    step, self._own[succ].arrays)
        self.step = int(step)

    def fail(self, rank: int) -> None:
        """Rank ``rank`` died: everything parked in its host memory (its
        primary shard AND the mirror it held for its ring successor) is
        gone."""
        if not 0 <= rank < self.n:
            raise ValueError(f"rank {rank} outside 0..{self.n - 1}")
        self.failed.add(int(rank))
        self._own.pop(rank, None)
        self._mirror.pop(rank, None)

    def _shard(self, rank: int) -> ckpt_io.MemorySnapshot:
        """Rank ``rank``'s chunk shard: its primary, else the mirror its
        ring predecessor holds. CRC-verified either way."""
        snap, where = self._own.get(rank), "primary"
        if snap is None:
            snap, where = self._mirror.get((rank - 1) % self.n), "mirror"
        if snap is None:
            raise SnapshotUnusable(
                f"rank {rank}'s chunk is unrecoverable: its primary died "
                f"and its mirror holder (ring predecessor "
                f"{(rank - 1) % self.n}) is down too")
        intact, reason = snap.verify()
        if not intact:
            raise SnapshotUnusable(
                f"rank {rank}'s {where} shard failed verification: {reason}")
        return snap

    def assemble(self, template: PyTree):
        """``(state, step)`` reassembled at the ORIGINAL n-rank layout
        (the caller re-cuts for the survivor ring afterwards).
        ``template`` supplies tree structure + dtypes, never values — it
        may be a ``ShapeDtypeStruct`` tree."""
        if self.step is None:
            raise SnapshotUnusable("no snapshot has been taken yet")
        if not self.chunked:
            reasons = []
            for r in range(self.n):
                snap = self._own.get(r)
                if snap is None:
                    continue
                intact, reason = snap.verify()
                if not intact:
                    reasons.append(f"rank {r}: {reason}")
                    continue
                return snap.restore(template), self.step
            raise SnapshotUnusable(
                "no surviving intact replica"
                + (f" ({'; '.join(reasons)})" if reasons else ""))
        shards = {r: self._shard(r) for r in range(self.n)}
        flat = {}
        for k in shards[min(shards)].arrays:
            if k in self._chunk_keys:
                flat[k] = np.stack([shards[r].arrays[k]
                                    for r in range(self.n)])
            else:
                flat[k] = shards[min(shards)].arrays[k]
        snap = ckpt_io.MemorySnapshot.from_flat(self.step, flat)
        return snap.restore(template), self.step
