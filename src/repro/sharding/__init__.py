from repro.sharding.specs import (batch_pspec, batch_sharding, cache_pspecs,
                                  param_pspecs, param_shardings,
                                  state_shardings)

__all__ = ["batch_pspec", "batch_sharding", "cache_pspecs", "param_pspecs",
           "param_shardings", "state_shardings"]
