"""Logical-axis sharding rules: param/batch/cache PartitionSpecs per arch.

Tensor parallelism over the ``model`` mesh axis by naming convention on the
parameter tree paths; data parallelism over ``data`` (+ ``pod``). Optional
ZeRO-style parameter sharding (``zero_axis``) additionally shards the
*other* matrix dim of large 2D weights over a data axis — GSPMD then inserts
the per-layer all-gathers of ZeRO-3/FSDP automatically (the baseline the
paper's ZeRO-CDP variant improves on; see repro.core.zero for the cyclic
point-to-point version).

All rules degrade to replication when a dim is not divisible by the axis
size, so every (arch x mesh) combination lowers.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

PyTree = Any


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _div(dim: int, n: int) -> bool:
    return n > 0 and dim % n == 0


def _spec_for_leaf(path_names, leaf, mesh, model_axis, zero_axis) -> P:
    """Choose a PartitionSpec for one parameter leaf."""
    msz = _axis_size(mesh, model_axis)
    zsz = _axis_size(mesh, zero_axis)
    name = path_names[-1] if path_names else ""
    shape = leaf.shape

    def ok(i, n=msz):
        return i < len(shape) and _div(shape[i], n)

    last = len(shape) - 1

    # --- embeddings / heads -------------------------------------------------
    if name == "embed":
        spec = [None, None]
        if _div(shape[0], msz):
            spec[0] = model_axis
        if zero_axis and _div(shape[1], zsz):
            spec[1] = zero_axis
        return P(*spec)
    if name in ("lm_head", "frontend_proj"):
        spec = [None, None]
        if _div(shape[1], msz):
            spec[1] = model_axis
        if zero_axis and _div(shape[0], zsz):
            spec[0] = zero_axis
        return P(*spec)

    # --- norms / small vectors ---------------------------------------------
    if leaf.ndim <= 1 or name in ("scale", "bias", "A_log", "D", "dt_bias",
                                  "gate_bias", "norm", "b", "conv_b",
                                  "q_norm", "kv_norm"):
        return P(*([None] * leaf.ndim))

    # --- MoE expert banks [L, E, din, dout] ---------------------------------
    if name in ("w1", "w3", "w2") and leaf.ndim == 4:
        L, E, di, do = shape
        if _div(E, msz):
            spec = [None, model_axis, None, None]
            if zero_axis and _div(do if name != "w2" else di, zsz):
                if name != "w2":
                    spec[3] = zero_axis
                else:
                    spec[2] = zero_axis
            return P(*spec)
        if name != "w2" and _div(do, msz):
            return P(None, None, None, model_axis)
        if name == "w2" and _div(di, msz):
            return P(None, None, model_axis, None)
        return P(None, None, None, None)
    if name == "router":
        return P(*([None] * leaf.ndim))

    # --- generic stacked / unstacked matrices -------------------------------
    # Convention: "column-parallel" (out-dim sharded) for input projections,
    # "row-parallel" (in-dim sharded) for output projections.
    row_parallel = name in ("wo", "w2", "down", "out_proj")
    mat_dims = (last - 1, last)

    spec = [None] * leaf.ndim
    if row_parallel:
        if _div(shape[mat_dims[0]], msz):
            spec[mat_dims[0]] = model_axis
        if zero_axis and _div(shape[mat_dims[1]], zsz):
            spec[mat_dims[1]] = zero_axis
    else:
        if _div(shape[mat_dims[1]], msz):
            spec[mat_dims[1]] = model_axis
        if zero_axis and _div(shape[mat_dims[0]], zsz):
            spec[mat_dims[0]] = zero_axis
    return P(*spec)


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        n = getattr(k, "key", None)
        if isinstance(n, str):
            names.append(n)
    return tuple(names)


def param_pspecs(params: PyTree, mesh, model_axis="model",
                 zero_axis=None) -> PyTree:
    return jax.tree_util.tree_map_with_path(
        lambda p, l: _spec_for_leaf(_path_names(p), l, mesh, model_axis,
                                    zero_axis), params)


def param_shardings(params: PyTree, mesh, model_axis="model",
                    zero_axis=None) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(params, mesh, model_axis, zero_axis))


# ---------------------------------------------------------------------------
# Batch / cache
# ---------------------------------------------------------------------------

def batch_pspec(mesh, data_axes=("data",)) -> P:
    """Leading (batch) dim sharded over the data axes (incl. pod if present)."""
    axes = tuple(a for a in data_axes if a in mesh.shape)
    return P(axes if len(axes) > 1 else axes[0])


def batch_sharding(batch: PyTree, mesh, data_axes=("data",)) -> PyTree:
    spec = batch_pspec(mesh, data_axes)

    def shard_one(x):
        if x.ndim == 0:
            return NamedSharding(mesh, P())
        n = _axis_size(mesh, spec[0]) if spec else 1
        if x.shape[0] % max(n, 1) == 0:
            return NamedSharding(mesh, P(*(spec + (None,) * (x.ndim - 1))))
        return NamedSharding(mesh, P())
    return jax.tree.map(shard_one, batch)


def batch_manual_pspecs(batch: PyTree, data_axes=("data",)) -> PyTree:
    """Per-leaf specs for a batch entering a shard_map manual over the data
    axes: leading dim sharded, scalars replicated (shared by the tree-layout
    trainer and the ZeRO-CDP stage-streaming step)."""
    ax = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]
    return jax.tree.map(
        lambda x: P(ax) if getattr(x, "ndim", 0) else P(), batch)


def cache_pspecs(cache: PyTree, mesh, data_axes=("data",),
                 model_axis="model", batch: Optional[int] = None) -> PyTree:
    """KV/state caches: shard the batch dim over data. Caches may be stacked
    once ([L, B, ...]) or twice ([P, per, B, ...] for the periodic SSM /
    hybrid stacks). When ``batch`` is given, only a dim equal to it is
    eligible (a stacked layer dim that happens to divide the axis must NOT be
    data-sharded — every device needs every layer's cache)."""
    daxes = tuple(a for a in data_axes if a in mesh.shape)
    dsz = _axis_size(mesh, daxes)

    def spec_one(x):
        spec = [None] * x.ndim
        ax = tuple(daxes) if len(daxes) > 1 else daxes[0]
        for i in range(min(3, x.ndim)):
            if batch is not None and x.shape[i] != batch:
                continue
            if _div(x.shape[i], dsz):
                spec[i] = ax
                break
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(spec_one, cache)


# ---------------------------------------------------------------------------
# Plan placements (repro.parallel): ZeRO-1 slots and ZeRO-CDP stage chunks
# ---------------------------------------------------------------------------

def zero1_param_pspecs(params: PyTree, mesh, data_axis: str = "data",
                       model_axis: str = "model",
                       zero_axis=None) -> PyTree:
    """Param pspecs with the data axis inserted at each leaf's ring slice
    axis — the layout of reduce-scattered grads and ZeRO-1 optimizer state
    (``placement='zero1'``)."""
    from repro.core import grad_sync
    gps = param_pspecs(params, mesh, model_axis, zero_axis)
    n = mesh.shape[data_axis]
    layout = grad_sync.zero1_layout(params, n, gps)

    def one(leaf, spec, ax):
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        if ax >= 0:
            entries[ax] = data_axis
        return P(*entries)
    return jax.tree.map(one, params, gps, layout)


def stage_chunk_shardings(tree: PyTree, mesh,
                          data_axis: str = "data") -> PyTree:
    """ZeRO-CDP placement (``placement='stage_sharded'``): every leaf is a
    [n_stages, chunk] stack of per-stage parameter chunks, stage j resident
    on data-rank j."""
    return jax.tree.map(
        lambda _: NamedSharding(mesh, P(data_axis, None)), tree)


def param_slot_keys(state: PyTree, params_like: PyTree) -> set:
    """Optimizer-state entries that are params-shaped trees (momenta,
    first/second moments, ...) — detected structurally against a
    params-structured template, NOT a hardcoded key list, so a new
    optimizer's slots shard correctly instead of silently replicating."""
    pdef = jax.tree.structure(params_like)
    return {k for k, v in state.items()
            if jax.tree.structure(v) == pdef}


def state_shardings(state: PyTree, params_sh: PyTree) -> PyTree:
    """Optimizer state mirrors the parameter shardings; everything that is
    not a params-shaped slot (step counters, scalars) is replicated."""
    mesh = jax.tree.leaves(params_sh)[0].mesh
    slots = param_slot_keys(state, params_sh)
    return {k: (params_sh if k in slots else NamedSharding(mesh, P()))
            for k in state}
