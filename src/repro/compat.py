"""Version-compat shims over the jax API surface this repo targets.

The codebase is written against the modern jax spelling (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``pltpu.CompilerParams``). Pinned
toolchains ship older jax builds that spell these differently; every call
site goes through this module so the rest of the tree stays version-agnostic.

Exports:
    CompilerParams   -- pallas-TPU compiler params dataclass (old name:
                        ``TPUCompilerParams``)
    make_mesh        -- ``jax.make_mesh`` with all-Auto axis types when the
                        installed jax supports typed mesh axes
    shard_map        -- ``jax.shard_map``; on old jax, maps ``axis_names``
                        (the *manual* axes) onto the legacy ``auto=`` set of
                        the experimental entry point
"""
from __future__ import annotations

import jax
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

# True when jax.shard_map exists, i.e. the body of a shard_map can stay
# auto (GSPMD) over unnamed mesh axes and sharding constraints over those
# axes are legal inside it. The old-jax fallback below is fully manual, so
# in-body with_sharding_constraint over a mesh axis would be rejected.
PARTIAL_AUTO_SHARD_MAP = hasattr(jax, "shard_map")


def make_mesh(axis_shapes, axis_names):
    """Mesh with Auto (GSPMD) axis types on every axis, on any jax version."""
    axis_type = getattr(getattr(jax.sharding, "AxisType", None), "Auto", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(axis_shapes, axis_names,
                                 axis_types=(axis_type,) * len(axis_names))
        except TypeError:
            pass
    return jax.make_mesh(axis_shapes, axis_names)


def mesh_from_devices(devices, axis_names):
    """Mesh over an EXPLICIT device ndarray with the same Auto axis types
    as :func:`make_mesh`. ``jax.make_mesh`` picks its own devices; the
    elastic re-formation path instead keeps the survivors' grid (so their
    resident shards stay where they are) and only drops the dead rank's
    row."""
    axis_type = getattr(getattr(jax.sharding, "AxisType", None), "Auto", None)
    if axis_type is not None:
        try:
            return jax.sharding.Mesh(
                devices, axis_names,
                axis_types=(axis_type,) * len(axis_names))
        except TypeError:
            pass
    return jax.sharding.Mesh(devices, axis_names)


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma=False):
    """``jax.shard_map`` on both new and 0.4.x jax.

    ``axis_names`` is the set of mesh axes the body is *manual* over; all
    other mesh axes stay auto (GSPMD). Old jax expresses the same split
    through the complementary ``auto=`` argument.
    """
    if hasattr(jax, "shard_map"):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma, **kw)
    # Old jax: partial-auto (auto=) cannot lower axis_index (PartitionId is
    # rejected by the SPMD partitioner), so run fully manual instead. Axes
    # absent from the specs are plain replication — numerically identical,
    # the body just loses GSPMD auto-partitioning over them.
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=bool(check_vma))
