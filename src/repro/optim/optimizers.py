"""Minimal functional optimizers (no external deps).

Interface:
    opt = sgd_momentum(momentum=0.9, weight_decay=1e-4)
    state = opt.init(params)
    new_params, new_state = opt.update(grads, state, params, lr)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree, Any], tuple]


def sgd_momentum(momentum: float = 0.9, weight_decay: float = 0.0,
                 state_dtype=jnp.float32) -> Optimizer:
    """The paper's optimizer (SGD + momentum + decoupled weight decay)."""

    def init(params):
        return {"mom": jax.tree.map(
            lambda p: jnp.zeros(p.shape, state_dtype), params)}

    def update(grads, state, params, lr):
        def upd(g, m, p):
            gf = g.astype(state_dtype)
            if weight_decay:
                gf = gf + weight_decay * p.astype(state_dtype)
            m_new = momentum * m + gf
            p_new = p.astype(jnp.float32) - lr * m_new.astype(jnp.float32)
            return p_new.astype(p.dtype), m_new

        flat = jax.tree.map(upd, grads, state["mom"], params)
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_mom = jax.tree.map(lambda t: t[1], flat,
                               is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"mom": new_mom}

    return Optimizer(init, update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, state_dtype=jnp.float32) -> Optimizer:

    def init(params):
        z = lambda p: jnp.zeros(p.shape, state_dtype)
        return {"m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        t = state["t"] + 1
        c1 = 1.0 - b1 ** t.astype(jnp.float32)
        c2 = 1.0 - b2 ** t.astype(jnp.float32)

        def upd(g, m, v, p):
            gf = g.astype(state_dtype)
            m_new = b1 * m + (1 - b1) * gf
            v_new = b2 * v + (1 - b2) * gf * gf
            mh = m_new.astype(jnp.float32) / c1
            vh = v_new.astype(jnp.float32) / c2
            step = mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m_new, v_new

        flat = jax.tree.map(upd, grads, state["m"], state["v"], params)
        pick = lambda i: jax.tree.map(lambda t_: t_[i], flat,
                                      is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), {"m": pick(1), "v": pick(2), "t": t}

    return Optimizer(init, update)
