from repro.optim.optimizers import (Optimizer, adamw, sgd_momentum)
from repro.optim.schedules import (constant, cosine_warmup, step_drops)

__all__ = ["Optimizer", "adamw", "sgd_momentum", "constant", "cosine_warmup",
           "step_drops"]
