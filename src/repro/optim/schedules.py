"""Learning-rate schedules (paper uses step drops at fixed epochs)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def step_drops(base_lr: float, boundaries, factor: float):
    """Paper protocol: lr dropped by ``factor`` at each boundary step."""
    bs = jnp.asarray(boundaries)

    def fn(step):
        k = jnp.sum(step >= bs)
        return jnp.float32(base_lr) * (factor ** k.astype(jnp.float32))
    return fn


def cosine_warmup(base_lr: float, warmup: int, total: int,
                  final_frac: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
        warm = base_lr * jnp.minimum(1.0, s / max(1, warmup))
        prog = jnp.clip((s - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, base_lr * cos)
    return fn
