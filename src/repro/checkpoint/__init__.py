from repro.checkpoint.io import (MemorySnapshot, gc_old_steps, intact_steps,
                                 latest_intact_step, latest_step, list_steps,
                                 restore, save, sweep_tmp, verify_step)

__all__ = ["MemorySnapshot", "gc_old_steps", "intact_steps",
           "latest_intact_step", "latest_step", "list_steps", "restore",
           "save", "sweep_tmp", "verify_step"]
