"""Pytree checkpointing: flat-keyed .npz + structure manifest.

Process-local (single-host CPU container); on a real multi-host deployment
each host writes its addressable shards — the flat-key format is unchanged.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional, Tuple

import jax
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten(tree: PyTree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":      # npz has no bf16; restore()
            arr = arr.astype(np.float32)      # casts back via the template
        flat[key] = arr
    return flat


def save(ckpt_dir: str, step: int, tree: PyTree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    tmp = path + ".tmp.npz"
    flat = _flatten(tree)
    np.savez(tmp, **flat)
    os.replace(tmp, path)
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, template: PyTree, step: Optional[int] = None,
            shardings: Optional[PyTree] = None) -> Tuple[PyTree, int]:
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    data = np.load(path)
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in paths:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in p)
        arr = data[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree, step
