"""Pytree checkpointing: flat-keyed .npz + a checksummed commit manifest.

Process-local (single-host CPU container); on a real multi-host deployment
each host writes its addressable shards — the flat-key format is unchanged.

Crash consistency: ``save`` writes the ``.npz`` via atomic
write-tmp-then-rename, then commits it with a ``step_XXXXXXXX.manifest.json``
carrying the file's CRC32 + byte size (also written atomically). A step is
INTACT iff its manifest checksum matches the file on disk — a ``kill -9``
at any point leaves either a fully committed step or a detectably broken
one, never a silently truncated restore. ``restore(step=None)`` walks steps
newest-first and falls back to the newest intact one (manifest-less legacy
steps count as intact when they still load). ``keep_last=`` garbage-collects
old steps after each successful save; transient IO errors retry with
exponential backoff; stale ``*.tmp.*`` junk from a killed prior run is
swept on the next save.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import time
import warnings
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any
_SEP = "/"
MANIFEST_FORMAT = 1


def _flat_key(path) -> str:
    """The flat-dict key for one ``tree_flatten_with_path`` path — shared
    by the npz writer, the loader, and the in-memory snapshots, so all
    three address leaves identically."""
    return _SEP.join(
        str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def _flatten(tree: PyTree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":      # npz has no bf16; restore()
            arr = arr.astype(np.float32)      # casts back via the template
        flat[_flat_key(path)] = arr
    return flat


def _fsync_path(path: str) -> None:
    """fsync a committed file AND its directory entry. ``os.replace`` is
    atomic against a crash of THIS process, but neither the renamed file's
    blocks nor the directory entry are durable across power loss until
    both are fsynced — without the directory fsync the rename itself can
    vanish, leaving the manifest pointing at the previous npz."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)
    dfd = os.open(os.path.dirname(path) or ".", os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)


def _step_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}.npz")


def _manifest_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}.manifest.json")


def _crc32(path: str, chunk: int = 1 << 20) -> int:
    crc = 0
    with open(path, "rb") as f:
        while True:
            buf = f.read(chunk)
            if not buf:
                return crc
            crc = zlib.crc32(buf, crc)


def sweep_tmp(ckpt_dir: str) -> List[str]:
    """Remove stale ``*.tmp.*`` files left by a crashed prior run — a
    killed save must not leave junk for the directory listing to trip
    over. Returns the swept paths."""
    if not os.path.isdir(ckpt_dir):
        return []
    swept = []
    for f in os.listdir(ckpt_dir):
        if ".tmp." in f or f.endswith(".tmp"):
            path = os.path.join(ckpt_dir, f)
            try:
                os.remove(path)
                swept.append(path)
            except OSError:
                pass                      # a racing writer owns it; skip
    return swept


def save(ckpt_dir: str, step: int, tree: PyTree, *,
         keep_last: Optional[int] = None,
         retries: int = 3, backoff_s: float = 0.05,
         injector=None) -> str:
    """Write + commit one step. Atomicity: the npz lands via tmp+rename,
    then the manifest (the commit record) lands via tmp+rename — readers
    only trust manifested steps, so any crash point is recoverable.
    Transient ``OSError``s retry ``retries`` times with exponential
    backoff. ``injector`` is a resilience ``FaultInjector`` probed at the
    ``ckpt_io`` site once per attempt (chaos tests)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    sweep_tmp(ckpt_dir)
    path = _step_path(ckpt_dir, step)
    tmp = path + ".tmp.npz"
    flat = _flatten(tree)
    last_err: Optional[BaseException] = None
    for attempt in range(retries + 1):
        try:
            if injector is not None and injector.fires("ckpt_io", step):
                raise OSError(f"injected transient IO error (step {step}, "
                              f"attempt {attempt})")
            np.savez(tmp, **flat)
            os.replace(tmp, path)
            _fsync_path(path)
            manifest = {"format": MANIFEST_FORMAT, "step": step,
                        "file": os.path.basename(path),
                        "crc32": _crc32(path),
                        "bytes": os.path.getsize(path)}
            mtmp = _manifest_path(ckpt_dir, step) + ".tmp.json"
            with open(mtmp, "w") as f:
                json.dump(manifest, f)
            os.replace(mtmp, _manifest_path(ckpt_dir, step))
            _fsync_path(_manifest_path(ckpt_dir, step))
            last_err = None
            break
        except OSError as e:
            last_err = e
            if attempt < retries:
                time.sleep(backoff_s * (2 ** attempt))
    if last_err is not None:
        raise last_err
    if keep_last is not None:
        gc_old_steps(ckpt_dir, keep_last)
    return path


def gc_old_steps(ckpt_dir: str, keep_last: int) -> List[int]:
    """Retention: drop everything but the newest ``keep_last`` steps
    (npz + manifest). Returns the removed step ids."""
    if keep_last < 1:
        raise ValueError(f"keep_last must be >= 1, got {keep_last}")
    steps = sorted(list_steps(ckpt_dir))
    drop = steps[:-keep_last] if len(steps) > keep_last else []
    for s in drop:
        for p in (_step_path(ckpt_dir, s), _manifest_path(ckpt_dir, s)):
            try:
                os.remove(p)
            except OSError:
                pass
    return drop


def list_steps(ckpt_dir: str) -> List[int]:
    """All step ids with an ``.npz`` on disk (committed or not); tmp junk
    from a killed save never matches the strict pattern."""
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(int(m.group(1)) for f in os.listdir(ckpt_dir)
                  if (m := re.fullmatch(r"step_(\d+)\.npz", f)))


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def verify_step(ckpt_dir: str, step: int) -> Tuple[bool, str]:
    """(intact, reason). Intact = manifest present and its CRC32/size
    match the file — or a legacy manifest-less npz that still loads
    (pre-manifest checkpoints stay restorable)."""
    path = _step_path(ckpt_dir, step)
    if not os.path.exists(path):
        return False, "missing npz"
    mpath = _manifest_path(ckpt_dir, step)
    if not os.path.exists(mpath):
        try:
            with np.load(path) as data:
                data.files
            return True, "legacy (no manifest)"
        except Exception as e:
            return False, f"legacy npz unreadable: {e!r}"
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return False, f"manifest unreadable: {e!r}"
    if manifest.get("bytes") != os.path.getsize(path):
        return False, (f"size mismatch: manifest {manifest.get('bytes')} "
                       f"vs disk {os.path.getsize(path)}")
    if manifest.get("crc32") != _crc32(path):
        return False, "crc32 mismatch"
    return True, "ok"


def intact_steps(ckpt_dir: str) -> List[int]:
    return [s for s in list_steps(ckpt_dir) if verify_step(ckpt_dir, s)[0]]


def latest_intact_step(ckpt_dir: str) -> Optional[int]:
    steps = intact_steps(ckpt_dir)
    return steps[-1] if steps else None


def _load_tree(path: str, template: PyTree) -> PyTree:
    data = np.load(path)
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in paths:
        arr = data[_flat_key(p)]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore(ckpt_dir: str, template: PyTree, step: Optional[int] = None,
            shardings: Optional[PyTree] = None, *,
            on_fallback: Optional[Callable[[int, str], None]] = None
            ) -> Tuple[PyTree, int]:
    """Restore a step. An EXPLICIT ``step`` is strict: a broken file
    raises (the caller asked for that exact state). ``step=None`` walks
    newest-first and automatically falls back to the newest INTACT step —
    every skipped step is reported via ``on_fallback(step, reason)`` (and
    a warning), so a truncated latest checkpoint costs one save interval,
    not the run."""
    if step is not None:
        intact, reason = verify_step(ckpt_dir, step)
        if not intact:
            raise ValueError(
                f"checkpoint step {step} in {ckpt_dir} is not intact: "
                f"{reason}")
        tree = _load_tree(_step_path(ckpt_dir, step), template)
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        return tree, step

    steps = list_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    for s in reversed(steps):
        intact, reason = verify_step(ckpt_dir, s)
        if not intact:
            warnings.warn(f"skipping broken checkpoint step {s} in "
                          f"{ckpt_dir}: {reason}", RuntimeWarning,
                          stacklevel=2)
            if on_fallback is not None:
                on_fallback(s, reason)
            continue
        try:
            tree = _load_tree(_step_path(ckpt_dir, s), template)
        except Exception as e:           # checksum raced a writer, etc.
            warnings.warn(f"skipping unreadable checkpoint step {s} in "
                          f"{ckpt_dir}: {e!r}", RuntimeWarning, stacklevel=2)
            if on_fallback is not None:
                on_fallback(s, repr(e))
            continue
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        return tree, s
    raise FileNotFoundError(
        f"no intact checkpoints in {ckpt_dir} (all of {steps} failed "
        f"verification)")


# ---------------------------------------------------------------------------
# In-memory snapshots (elastic CDP's buddy store)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MemorySnapshot:
    """One committed step parked in host RAM instead of on disk: the same
    flat-key layout as the npz (``_flatten``) and the same integrity
    contract as the manifest, but with a per-array CRC32 so a single
    corrupted buffer is detected without hashing the whole state.
    ``restore`` mirrors ``_load_tree``: template-keyed, casting each array
    back to the template leaf's dtype (bf16 round-trips through f32
    exactly, as on disk). Elastic recovery uses these as the zero-IO fast
    path; ``checkpoint.restore`` stays the disk fallback."""

    step: int
    arrays: Dict[str, np.ndarray]
    crc32: Dict[str, int]

    @classmethod
    def from_flat(cls, step: int, flat: Dict[str, np.ndarray]
                  ) -> "MemorySnapshot":
        arrays = {k: np.array(v, copy=True) for k, v in flat.items()}
        return cls(step=int(step), arrays=arrays,
                   crc32={k: zlib.crc32(v.tobytes())
                          for k, v in arrays.items()})

    @classmethod
    def from_tree(cls, step: int, tree: PyTree) -> "MemorySnapshot":
        return cls.from_flat(step, _flatten(tree))

    def verify(self) -> Tuple[bool, str]:
        """(intact, reason) — the in-memory analogue of ``verify_step``."""
        for k, v in self.arrays.items():
            if k not in self.crc32:
                return False, f"no checksum for {k!r}"
            if zlib.crc32(v.tobytes()) != self.crc32[k]:
                return False, f"crc32 mismatch at {k!r}"
        return True, "ok"

    def restore(self, template: PyTree) -> PyTree:
        """Rebuild the pytree onto ``template``'s structure and dtypes.
        Strict like an explicit-step disk restore: a failed checksum
        raises rather than silently handing back corrupt state."""
        intact, reason = self.verify()
        if not intact:
            raise ValueError(f"memory snapshot (step {self.step}) is not "
                             f"intact: {reason}")
        paths, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for p, leaf in paths:
            arr = self.arrays[_flat_key(p)]
            if hasattr(leaf, "dtype"):
                arr = arr.astype(leaf.dtype)
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    @property
    def nbytes(self) -> int:
        return sum(v.nbytes for v in self.arrays.values())
