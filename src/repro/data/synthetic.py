"""Deterministic synthetic data: token LM streams, CIFAR-like images, and
family-aware batch construction (incl. the audio/vision stub embeddings)."""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (FAMILY_ENCDEC, FAMILY_VLM, InputShape,
                                ModelConfig)


def make_lm_data(vocab: int, n_tokens: int, seed: int = 0,
                 order: int = 2) -> np.ndarray:
    """Markov-chain token stream: learnable structure (an LM can reduce loss
    well below log V) but fully deterministic and offline."""
    rng = np.random.default_rng(seed)
    k = min(vocab, 64)
    trans = rng.dirichlet(np.ones(k) * 0.3, size=k)
    toks = np.zeros(n_tokens, np.int32)
    s = 0
    for i in range(n_tokens):
        s = rng.choice(k, p=trans[s])
        toks[i] = s * (vocab // k) + rng.integers(0, max(1, vocab // k // 4))
    return toks % vocab


def make_classification_data(n: int, dim: int = 512, classes: int = 10,
                             seed: int = 0):
    """Gaussian-cluster classification set (stands in for CIFAR-10 in the
    paper-validation experiments; same optimisation character: multi-class,
    noisy, overparameterised net can fit it)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, 1.0, (classes, dim))
    y = rng.integers(0, classes, n)
    x = centers[y] + rng.normal(0, 1.2, (n, dim))
    return x.astype(np.float32), y.astype(np.int32)


def rollout_prompts(n: int, vocab: int, prompt_len: int,
                    seed: int = 0) -> list:
    """Deterministic distinct prompts for the rollout loop — one per
    trajectory group; the group members share the prompt and differ only
    in their sampling seed."""
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=prompt_len).astype(np.int32)
            for _ in range(n)]


def token_range_reward(target: int, width: int = 1):
    """The steerable synthetic reward for the rollout loop: the COUNT of
    generated tokens falling in ``[target, target + width)``. Maximising
    it has a known optimum (emit only in-range tokens), so a correct
    policy-gradient step must raise the mean group reward — the rollout
    subsystem's acceptance signal. ``width = 1`` is the literal
    count-of-one-token task; a wider band gives a randomly initialised
    policy enough baseline hits (~width/vocab per token) for the
    group-relative advantage to carry signal from iteration one."""
    if width < 1:
        raise ValueError(f"width={width} must be >= 1")

    def reward(prompt: np.ndarray, tokens: np.ndarray) -> float:
        toks = np.asarray(tokens)
        if toks.size == 0:
            return 0.0
        return float(np.count_nonzero((toks >= target)
                                      & (toks < target + width)))
    return reward


def lm_batch_iterator(tokens: np.ndarray, batch: int, seq: int,
                      seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    n = len(tokens) - seq - 1
    while True:
        starts = rng.integers(0, n, batch)
        x = np.stack([tokens[s:s + seq] for s in starts])
        y = np.stack([tokens[s + 1:s + seq + 1] for s in starts])
        yield {"tokens": x, "targets": y}


def synthetic_batch(cfg: ModelConfig, shape: InputShape,
                    dtype=jnp.int32) -> Dict[str, jnp.ndarray]:
    """Concrete (allocated) batch for smoke tests — small shapes only."""
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": jnp.zeros((B, S), jnp.int32),
             "targets": jnp.zeros((B, S), jnp.int32)}
    if cfg.family == FAMILY_VLM:
        v = cfg.vlm
        batch["patches"] = jnp.zeros((B, v.num_patches, v.vision_dim),
                                     jnp.dtype(cfg.dtype))
    if cfg.family == FAMILY_ENCDEC:
        e = cfg.encdec
        batch["frames"] = jnp.zeros((B, max(1, S // e.frame_rate_divisor),
                                     e.frontend_dim), jnp.dtype(cfg.dtype))
    return batch
