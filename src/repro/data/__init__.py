from repro.data.synthetic import (lm_batch_iterator, make_classification_data,
                                  make_lm_data, synthetic_batch)
from repro.data.loader import ShardedLoader

__all__ = ["lm_batch_iterator", "make_classification_data", "make_lm_data",
           "synthetic_batch", "ShardedLoader"]
