"""Sharding-aware host data loader with background prefetch."""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np


class ShardedLoader:
    """Wraps a host batch iterator; places each batch with the given
    shardings and prefetches ``depth`` batches ahead on a worker thread."""

    def __init__(self, host_iter: Iterator, shardings=None, depth: int = 2):
        self._it = host_iter
        self._sh = shardings
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _place(self, batch):
        if self._sh is None:
            return jax.tree.map(jax.numpy.asarray, batch)
        return jax.tree.map(
            lambda x, s: jax.device_put(np.asarray(x), s), batch, self._sh)

    def _work(self):
        try:
            for batch in self._it:
                if self._stop.is_set():
                    return
                placed = self._place(batch)
                while not self._stop.is_set():   # stop-aware put: close()
                    try:                          # must not deadlock on a
                        self._q.put(placed, timeout=0.1)  # full queue
                        break
                    except queue.Full:
                        continue
                if self._stop.is_set():
                    return
        except Exception as e:  # surface loader errors to the consumer
            self._q.put(e)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if isinstance(item, Exception):
            raise item
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        # wait for the worker to notice the stop flag: letting the daemon
        # thread die mid device_put at interpreter teardown aborts the
        # process ("terminate called without an active exception")
        self._thread.join(timeout=10.0)
