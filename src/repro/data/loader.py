"""Sharding-aware host data loader with background prefetch."""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np


class _Sentinel:
    def __init__(self, name):
        self.name = name

    def __repr__(self):
        return f"<loader {self.name}>"


_ERROR = _Sentinel("error")      # worker died; loader._exc has the cause
_END = _Sentinel("end")          # host iterator exhausted cleanly


class ShardedLoader:
    """Wraps a host batch iterator; places each batch with the given
    shardings and prefetches ``depth`` batches ahead on a worker thread.

    Failure contract (tested in tests/test_resilience.py): a worker-thread
    exception is re-raised in ``__next__`` — after the already-prefetched
    good batches drain — instead of hanging the training loop forever, and
    every subsequent ``__next__`` re-raises the same exception (a consumer
    retry loop never blocks on a dead worker). A cleanly exhausted iterator
    raises ``StopIteration`` the same way. ``close()`` joins the worker in
    both cases."""

    def __init__(self, host_iter: Iterator, shardings=None, depth: int = 2):
        self._it = host_iter
        self._sh = shardings
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._exc: Optional[BaseException] = None
        self._ended = False
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _place(self, batch):
        if self._sh is None:
            return jax.tree.map(jax.numpy.asarray, batch)
        return jax.tree.map(
            lambda x, s: jax.device_put(np.asarray(x), s), batch, self._sh)

    def _put(self, item) -> bool:
        """Stop-aware put: close() must not deadlock on a full queue (and a
        crash sentinel must not block behind one either)."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _work(self):
        try:
            for batch in self._it:
                if self._stop.is_set():
                    return
                placed = self._place(batch)
                if not self._put(placed):
                    return
            self._ended = True
            self._put(_END)
        except Exception as e:  # surface loader errors to the consumer
            self._exc = e       # set BEFORE the sentinel lands: a consumer
            self._put(_ERROR)   # that sees _ERROR always finds the cause

    def __iter__(self):
        return self

    def __next__(self):
        # a dead worker with a drained queue must fail immediately, not
        # block in q.get() forever (the sentinel was consumed by an
        # earlier __next__, or never enqueued because close() raced it)
        if self._q.empty():
            if self._exc is not None:
                raise self._exc
            if self._ended:
                raise StopIteration
        item = self._q.get()
        if item is _ERROR:
            raise self._exc
        if item is _END:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        # wait for the worker to notice the stop flag: letting the daemon
        # thread die mid device_put at interpreter teardown aborts the
        # process ("terminate called without an active exception"). After
        # a worker crash the thread is already dead and this returns
        # immediately.
        self._thread.join(timeout=10.0)
