"""Pallas TPU attention over a paged KV cache: block-table-indexed reads.

The serving engine's paged cache stores KV in a fixed pool of ``bs``-token
blocks, ``k/v: [NB+1, bs, KV, dh]`` (the last block is a write-off "trash"
block that absorbs masked writes and backs unallocated table entries), with
a per-row block table ``table: [B, nb]`` mapping logical block j of row b to
a physical pool slot.

This is the page-table extension of the block-sparse ``flash_grid_plan``
machinery: a page table IS a ragged grid plan, except the visited block
index comes from a scalar-prefetched table instead of the causal/window
enumerator.  Both kernels below keep grid position ``j`` as the *logical*
block (masking is positional: ``pos = j*bs + iota``), and only the BlockSpec
index map goes through the table — ``k_pool[tbl[row*nb + j]]`` — so the
online-softmax math is identical to the dense kernels visiting the same
logical blocks.

Because pool blocks hold whatever a freed/poisoned row left behind, both
kernels zero the V tile outside validity (0 * NaN would otherwise poison the
accumulator through the exactly-zero masked probabilities) and mask S after
the dot, which keeps the valid lanes bit-identical to the dense path.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# decode: one query token per row, KV gathered through the block table
# ---------------------------------------------------------------------------

def _paged_decode_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, scale: float, bs: int,
                         nb: int, heads: int):
    b = pl.program_id(0)
    jk = pl.program_id(1)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                     # [1, d]
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # [bs, d]
    v = v_ref[0, :, 0, :].astype(jnp.float32)            # [bs, dv]
    valid_len = len_ref[b // heads]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = jk * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    valid = pos < valid_len
    s = jnp.where(valid, s, NEG_INF)
    # zero V outside validity: pool blocks can hold garbage (even NaN, from
    # quarantined rows) and 0 * NaN = NaN would leak through masked lanes
    v = jnp.where(valid.reshape(bs, 1), v, 0.0)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(jk == nb - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def paged_decode_attention_kernel(q, k_pool, v_pool, table, cache_len, *,
                                  heads: int, interpret: bool = False):
    """q: [B*H, d]; k/v_pool: [NB+1, bs, KV, dh]; table: [B*nb] int32
    (flattened [B, nb], unallocated entries point at the trash block NB);
    cache_len: [B] int32 -> [B*H, dv]."""
    BH, d = q.shape
    _, bs, KV, dv = v_pool.shape
    B = cache_len.shape[0]
    nb = table.shape[0] // B
    g = (BH // B) // KV if KV else 1          # query heads per kv head
    H = heads
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(_paged_decode_kernel, scale=scale, bs=bs,
                               nb=nb, heads=H)
    q3 = q[:, None, :]                                   # [BH, 1, d]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(BH, nb),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda b, j, tbl, ln: (b, 0, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda b, j, tbl, ln: (tbl[(b // H) * nb + j], 0,
                                                (b % H) // g, 0)),
            pl.BlockSpec((1, bs, 1, dv),
                         lambda b, j, tbl, ln: (tbl[(b // H) * nb + j], 0,
                                                (b % H) // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, dv), lambda b, j, tbl, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BH, 1, dv), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(table, cache_len, q3, k_pool, v_pool)
    return out[:, 0, :]


# ---------------------------------------------------------------------------
# prefill: ragged tail of new tokens (per-row start offset) vs paged cache
# ---------------------------------------------------------------------------

def _paged_prefill_kernel(tbl_ref, qs_ref, kl_ref, q_ref, k_ref, v_ref,
                          o_ref, m_ref, l_ref, acc_ref, *, scale: float,
                          bq: int, bs: int, nb: int, heads: int):
    b = pl.program_id(0)
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    row = b // heads
    q = q_ref[0].astype(jnp.float32)                     # [bq, d]
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # [bs, d]
    v = v_ref[0, :, 0, :].astype(jnp.float32)            # [bs, dv]
    q_start = qs_ref[row]
    kv_len = kl_ref[row]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    q_pos = (q_start + iq * bq +
             jax.lax.broadcasted_iota(jnp.int32, (bq, bs), 0))
    kv_pos = (jk * bs +
              jax.lax.broadcasted_iota(jnp.int32, (bq, bs), 1))
    valid = (kv_pos <= q_pos) & (kv_pos < kv_len)
    s = jnp.where(valid, s, NEG_INF)
    col_valid = (jk * bs + jax.lax.broadcasted_iota(jnp.int32, (bs, 1), 0)
                 ) < kv_len
    v = jnp.where(col_valid, v, 0.0)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(jk == nb - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def paged_prefill_attention_kernel(q, k_pool, v_pool, table, q_start, kv_len,
                                   *, heads: int, bq: int = 128,
                                   interpret: bool = False):
    """q: [B*H, Sq, d] (the ragged tail, row b's token i sits at absolute
    position ``q_start[b//H] + i``); pools/table as in the decode kernel;
    kv_len: [B] total valid cache length per row -> [B*H, Sq, dv]."""
    BH, Sq, d = q.shape
    _, bs, KV, dv = v_pool.shape
    B = q_start.shape[0]
    nb = table.shape[0] // B
    H = heads
    g = (BH // B) // KV if KV else 1
    bq = min(bq, Sq)
    assert Sq % bq == 0, (Sq, bq)
    nq = Sq // bq
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(_paged_prefill_kernel, scale=scale, bq=bq,
                               bs=bs, nb=nb, heads=H)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(BH, nq, nb),
        in_specs=[
            pl.BlockSpec((1, bq, d),
                         lambda b, i, j, tbl, qs, kl: (b, i, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda b, i, j, tbl, qs, kl: (tbl[(b // H) * nb + j],
                                                       0, (b % H) // g, 0)),
            pl.BlockSpec((1, bs, 1, dv),
                         lambda b, i, j, tbl, qs, kl: (tbl[(b // H) * nb + j],
                                                       0, (b % H) // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dv),
                               lambda b, i, j, tbl, qs, kl: (b, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dv), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BH, Sq, dv), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(table, q_start, kv_len, q, k_pool, v_pool)
