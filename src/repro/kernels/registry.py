"""Per-op kernel backend registry.

One execution-plan choice per compute hot-spot, first-class in config
(``ModelConfig.kernels``) instead of a single scattered ``attn_backend``
flag:

    op            "jnp" (reference)              "pallas" (fused TPU kernel)
    ------------  -----------------------------  ------------------------------
    train_attn    blockwise online-softmax VJP   ops.flash_attention custom_vjp
                                                 (block-sparse pruned grids)
    prefill_attn  blockwise forward              ops.flash_attention forward
    decode_attn   models.attention jnp decode    ops.decode_attention
    ssm_scan      chunked jnp GLA scan           ops.gla_scan custom_vjp (fused
                                                 one-pass reverse chunk-scan
                                                 backward)
    paged_attn    gather-through-table + jnp     ops.paged_decode_attention /
                  decode / masked flash          ops.paged_prefill_attention
                                                 (block-table scalar prefetch)

Off-TPU every Pallas op runs with ``interpret=True`` automatically
(``ops.default_interpret``), so all four backends stay CPU-testable.

``ModelConfig.attn_backend`` (and the ``--attn-backend`` CLI flag) survive
as deprecated aliases: when ``cfg.kernels`` is unset, ``resolve`` populates
``train_attn``/``prefill_attn`` from the alias.  New code should set
``cfg.kernels`` (a :class:`KernelSpec`) directly.

This module is dependency-light on purpose (no jax import): ``repro.configs``
embeds :class:`KernelSpec` in ``ModelConfig`` without pulling in the Pallas
tool-chain at config time.
"""
from __future__ import annotations

import contextlib
import dataclasses
from dataclasses import dataclass, replace
from typing import Optional, Union

KERNEL_OPS = ("train_attn", "prefill_attn", "decode_attn", "ssm_scan",
              "paged_attn")
KERNEL_BACKENDS = ("jnp", "pallas")


@dataclass(frozen=True)
class KernelSpec:
    """Backend choice per kernel op; the value of ``ModelConfig.kernels``."""
    train_attn: str = "jnp"
    prefill_attn: str = "jnp"
    decode_attn: str = "jnp"
    ssm_scan: str = "jnp"
    # both paged ops (decode + ragged-tail prefill) of the serving engine's
    # paged KV cache; independent of decode_attn so the dense and paged
    # backends can be compared side by side
    paged_attn: str = "jnp"

    def validate(self) -> "KernelSpec":
        for op in KERNEL_OPS:
            b = getattr(self, op)
            if b not in KERNEL_BACKENDS:
                raise ValueError(
                    f"kernels.{op}={b!r}; expected one of {KERNEL_BACKENDS}")
        return self

    def with_(self, **kw) -> "KernelSpec":
        return replace(self, **kw)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def all(cls, backend: str) -> "KernelSpec":
        return cls(**{op: backend for op in KERNEL_OPS}).validate()

    @classmethod
    def parse(cls, text: str) -> "KernelSpec":
        """Parse a CLI value: either one backend for every op ("pallas") or a
        comma list of op=backend pairs ("decode_attn=pallas,ssm_scan=jnp")."""
        if "=" in (text or ""):
            return cls(**coerce_ops(text)).validate()
        return cls.all(text) if (text or "").strip() else cls()


def coerce_ops(value: Union["KernelSpec", dict, str, None]) -> Optional[dict]:
    """The per-op backend dict a user input EXPLICITLY names (so callers can
    merge defaults — e.g. the attn_backend alias — into unnamed ops only).
    KernelSpec names every op; dict/CLI-string name a subset; None -> None."""
    if value is None:
        return None
    if isinstance(value, KernelSpec):
        return value.validate().as_dict()
    if isinstance(value, dict):
        bad = set(value) - set(KERNEL_OPS)
        if bad:
            raise ValueError(f"unknown kernel ops {sorted(bad)}; "
                             f"expected from {KERNEL_OPS}")
        KernelSpec(**value).validate()
        return dict(value)
    if isinstance(value, str):
        text = value.strip()
        if not text:
            return {}
        if "=" not in text:
            return KernelSpec.all(text).as_dict()
        ops = {}
        for item in text.split(","):
            op, _, backend = item.partition("=")
            ops[op.strip()] = backend.strip()
        return coerce_ops(ops)
    raise TypeError(f"cannot build a KernelSpec from {type(value).__name__}")


def coerce(value: Union["KernelSpec", dict, str, None]) -> Optional["KernelSpec"]:
    """Normalise user input (KernelSpec | dict | CLI string | None)."""
    ops = coerce_ops(value)
    return None if ops is None else KernelSpec(**ops).validate()


def resolve(cfg) -> KernelSpec:
    """The effective KernelSpec of a ModelConfig.

    ``cfg.kernels`` wins when set; otherwise the deprecated
    ``cfg.attn_backend`` alias populates the attention ops.  Raises
    ``ValueError`` on any unknown backend — call this where you want to fail
    fast (a typo would otherwise only surface mid-trace in a jitted step).
    """
    spec = getattr(cfg, "kernels", None)
    if spec is None:
        alias = getattr(cfg, "attn_backend", "jnp")
        spec = KernelSpec(train_attn=alias, prefill_attn=alias)
    return spec.validate()


def backend_for(cfg, op: str) -> str:
    if op not in KERNEL_OPS:
        raise ValueError(f"unknown kernel op {op!r}")
    return getattr(resolve(cfg), op)


# ---------------------------------------------------------------------------
# Attention phase: the full-sequence attention contraction is shared by the
# training forward and the serve prefill, so model code cannot tell from its
# arguments which registry op applies.  ``prefill_logits`` /
# ``prefill_with_cache`` enter a prefill scope around their (trace-time)
# body; everything else defaults to the train op.
# ---------------------------------------------------------------------------

_ATTN_PHASE = ["train_attn"]


def attn_op() -> str:
    """The registry op of the current full-sequence attention phase."""
    return _ATTN_PHASE[-1]


@contextlib.contextmanager
def prefill_scope():
    _ATTN_PHASE.append("prefill_attn")
    try:
        yield
    finally:
        _ATTN_PHASE.pop()


def active_attn_backend(cfg) -> str:
    """Backend of the current attention phase (train vs prefill)."""
    return backend_for(cfg, attn_op())
