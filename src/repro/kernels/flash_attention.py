"""Pallas TPU flash-attention (training forward AND backward), causal +
sliding window, GQA-aware via the wrapper in ops.py.

Layout: q [BH, Sq, d], k/v [BKV, Sk, d] with BH = batch*heads,
BKV = batch*kv_heads.

Forward — grid (BH, nq, nk): the kv dimension is the innermost (sequential)
axis; the online-softmax accumulators (m, l, acc) live in VMEM scratch and
persist across the kv iterations of one (bh, iq) tile — the classic flash
structure mapped to the TPU grid. The per-row logsumexp is written out as a
second output so the backward pass can recompute the probabilities blockwise
(FlashAttention-2 residual).

Backward — two kernels, both recomputing scores from (q, k, lse) in VMEM:

  * dq: grid (BH, nq, nk), kv innermost; a [bq, d] accumulator persists
    across kv blocks of one query tile. ds = p * (dp - delta) * scale,
    dq += ds @ k.
  * dk/dv: grid (BKV, nk, G, nq) with the (query-group, query-block) axes
    innermost, so the [bk, d] accumulators sum across every query head of
    the kv head's GQA group AND every query block — the GQA dk/dv reduction
    happens inside the kernel, no post-hoc head-sum needed.

``delta = sum(dO * O, axis=-1)`` is precomputed by the caller (ops.py) — the
standard separate-pass trick that keeps both backward kernels matmul-only.

Block shapes are multiples of 128 on the lane dim for MXU alignment (ops.py
pads); padded kv positions are masked via ``sk_valid`` and padded q rows are
harmless because their output rows are sliced off (forward) and their dO rows
are zero (backward).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams

NEG_INF = -1e30


def _tile_mask(iq, jk, *, bq, bk, causal, window, q_offset, sk):
    """[bq, bk] validity mask of one (query-block, kv-block) tile."""
    q_pos = q_offset + iq * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0)
    k_pos = jk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = k_pos < sk
    if causal:
        valid = valid & (k_pos <= q_pos)
    if window:
        valid = valid & (k_pos > q_pos - window)
    return valid


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref,
                  *, scale: float, causal: bool, window: int, q_offset: int,
                  bq: int, bk: int, nk: int, sk: int):
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                  # [bq, d]
    k = k_ref[0].astype(jnp.float32)                  # [bk, d]
    v = v_ref[0].astype(jnp.float32)                  # [bk, dv]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    valid = _tile_mask(iq, jk, bq=bq, bk=bk, causal=causal, window=window,
                       q_offset=q_offset, sk=sk)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(jk == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m_ref[...] + jnp.log(l)


def flash_attention_kernel(q, k, v, *, causal: bool = True, window: int = 0,
                           q_offset: int = 0, bq: int = 128, bk: int = 128,
                           group: int = 1, sk_valid: int = 0,
                           interpret: bool = False):
    """q: [BH, Sq, d]; k, v: [BKV, Sk, d]; group = heads per kv head.
    ``sk_valid``: true kv length (padded tail positions are masked).
    ``q_offset``: absolute position of q row 0 (for masking parity with
    ``models.attention.blockwise_attention``).

    Returns (out [BH, Sq, dv], lse [BH, Sq] float32) — lse is the per-row
    logsumexp residual the backward kernels consume.
    """
    BH, Sq, d = q.shape
    BKV, Sk, dv = v.shape
    nq = Sq // bq
    nk = Sk // bk
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, bq=bq, bk=bk, nk=nk, sk=sk_valid or Sk)

    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, bk, dv), lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, dv), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sq, dv), q.dtype),
            jax.ShapeDtypeStruct((BH, Sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dv), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Backward: dq
# ---------------------------------------------------------------------------

def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                         dq_ref, dq_acc_ref, *, scale: float, causal: bool,
                         window: int, q_offset: int, bq: int, bk: int,
                         nk: int, sk: int):
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        dq_acc_ref[...] = jnp.zeros_like(dq_acc_ref)

    q = q_ref[0].astype(jnp.float32)                  # [bq, d]
    k = k_ref[0].astype(jnp.float32)                  # [bk, d]
    v = v_ref[0].astype(jnp.float32)                  # [bk, dv]
    do = do_ref[0].astype(jnp.float32)                # [bq, dv]
    lse = lse_ref[0]                                  # [bq]
    delta = delta_ref[0]                              # [bq]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    valid = _tile_mask(iq, jk, bq=bq, bk=bk, causal=causal, window=window,
                       q_offset=q_offset, sk=sk)
    s = jnp.where(valid, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])                     # [bq, bk]

    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None]) * scale            # [bq, bk]
    dq_acc_ref[...] += jax.lax.dot(ds, k, preferred_element_type=jnp.float32)

    @pl.when(jk == nk - 1)
    def _finish():
        dq_ref[0] = dq_acc_ref[...].astype(dq_ref.dtype)


def flash_attention_bwd_dq(q, k, v, do, lse, delta, *, causal: bool = True,
                           window: int = 0, q_offset: int = 0, bq: int = 128,
                           bk: int = 128, group: int = 1, sk_valid: int = 0,
                           interpret: bool = False):
    """dq of flash attention. Shapes as the forward; lse/delta: [BH, Sq] f32.
    Returns dq [BH, Sq, d] in q.dtype."""
    BH, Sq, d = q.shape
    BKV, Sk, dv = v.shape
    nq = Sq // bq
    nk = Sk // bk
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _flash_bwd_dq_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, bq=bq, bk=bk, nk=nk, sk=sk_valid or Sk)

    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, bk, dv), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, bq, dv), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta)


# ---------------------------------------------------------------------------
# Backward: dk / dv (GQA reduction over the query-group axis in-kernel)
# ---------------------------------------------------------------------------

def _flash_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                          dk_ref, dv_ref, dk_acc_ref, dv_acc_ref, *,
                          scale: float, causal: bool, window: int,
                          q_offset: int, bq: int, bk: int, nq: int,
                          ng: int, sk: int):
    jk = pl.program_id(1)
    g = pl.program_id(2)
    iq = pl.program_id(3)

    @pl.when((g == 0) & (iq == 0))
    def _init():
        dk_acc_ref[...] = jnp.zeros_like(dk_acc_ref)
        dv_acc_ref[...] = jnp.zeros_like(dv_acc_ref)

    q = q_ref[0].astype(jnp.float32)                  # [bq, d]
    k = k_ref[0].astype(jnp.float32)                  # [bk, d]
    v = v_ref[0].astype(jnp.float32)                  # [bk, dv]
    do = do_ref[0].astype(jnp.float32)                # [bq, dv]
    lse = lse_ref[0]                                  # [bq]
    delta = delta_ref[0]                              # [bq]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    valid = _tile_mask(iq, jk, bq=bq, bk=bk, causal=causal, window=window,
                       q_offset=q_offset, sk=sk)
    s = jnp.where(valid, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])                     # [bq, bk]

    # dv += p^T @ dO
    dv_acc_ref[...] += jax.lax.dot_general(
        p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None]) * scale            # [bq, bk]
    # dk += ds^T @ q
    dk_acc_ref[...] += jax.lax.dot_general(
        ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when((g == ng - 1) & (iq == nq - 1))
    def _finish():
        dk_ref[0] = dk_acc_ref[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc_ref[...].astype(dv_ref.dtype)


def flash_attention_bwd_dkv(q, k, v, do, lse, delta, *, causal: bool = True,
                            window: int = 0, q_offset: int = 0, bq: int = 128,
                            bk: int = 128, group: int = 1, sk_valid: int = 0,
                            interpret: bool = False):
    """dk, dv of flash attention, accumulated across all ``group`` query
    heads of each kv head (GQA) and all query blocks inside the kernel.
    Returns (dk [BKV, Sk, d], dv [BKV, Sk, dv]) in k/v dtype."""
    BH, Sq, d = q.shape
    BKV, Sk, dv = v.shape
    nq = Sq // bq
    nk = Sk // bk
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _flash_bwd_dkv_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, bq=bq, bk=bk, nq=nq, ng=group, sk=sk_valid or Sk)

    qmap = lambda b, j, g, i, G=group: (b * G + g, i, 0)
    qmap2 = lambda b, j, g, i, G=group: (b * G + g, i)
    kmap = lambda b, j, g, i: (b, j, 0)

    return pl.pallas_call(
        kernel,
        grid=(BKV, nk, group, nq),
        in_specs=[
            pl.BlockSpec((1, bq, d), qmap),
            pl.BlockSpec((1, bk, d), kmap),
            pl.BlockSpec((1, bk, dv), kmap),
            pl.BlockSpec((1, bq, dv), qmap),
            pl.BlockSpec((1, bq), qmap2),
            pl.BlockSpec((1, bq), qmap2),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, d), kmap),
            pl.BlockSpec((1, bk, dv), kmap),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BKV, Sk, d), k.dtype),
            jax.ShapeDtypeStruct((BKV, Sk, dv), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, d), jnp.float32),
            pltpu.VMEM((bk, dv), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta)
