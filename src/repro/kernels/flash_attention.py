"""Pallas TPU flash-attention (training forward AND backward), causal +
sliding window, GQA-aware via the wrapper in ops.py — with BLOCK-SPARSE
grids: fully-masked (query-block, kv-block) tiles are never visited.

Layout: q [BH, Sq, d], k/v [BKV, Sk, d] with BH = batch*heads,
BKV = batch*kv_heads.

Grid structure — every kernel iterates a host-built tile plan
(:func:`flash_grid_plan`) instead of the dense (nq, nk) rectangle: the plan
enumerates exactly the (iq, jk) pairs with any unmasked element (causal ->
the lower block triangle jk <= iq; sliding window -> a constant-width band
of ~ceil(window/bk)+1 kv blocks per q block; non-causal -> the full
rectangle), and the kernels walk it as a 1D ragged axis whose block indices
come from scalar-prefetched arrays (``pltpu.PrefetchScalarGridSpec``).
Per-tile metadata flags mark the first/last tile of each accumulator group
and whether the tile is FULL — ``_tile_mask`` is only evaluated on the
diagonal/boundary tiles; interior tiles skip masking entirely.

Forward — grid (BH, T): the online-softmax accumulators (m, l, acc) live in
VMEM scratch and persist across the kv tiles of one (bh, iq) group; the
per-row logsumexp is written out as a second output so the backward pass can
recompute the probabilities blockwise (FlashAttention-2 residual).

Backward — two kernels, both recomputing scores from (q, k, lse) in VMEM:

  * dq: grid (BH, T) over the same plan; a [bq, d] accumulator persists
    across the kv tiles of one query block. ds = p * (dp - delta) * scale,
    dq += ds @ k.
  * dk/dv: grid (BKV, T2, G) where T2 is the plan transposed (tiles ordered
    by kv block, then q block) and G is the GQA query-group axis innermost:
    the [bk, d] accumulators sum across every query head of the kv head's
    group AND every visited query block — kv blocks no q block attends to
    get one masked sentinel tile so their dk/dv are written as exact zeros.

``delta = sum(dO * O, axis=-1)`` is precomputed by the caller (ops.py) — the
standard separate-pass trick that keeps both backward kernels matmul-only.

Block shapes are multiples of 128 on the lane dim for MXU alignment (ops.py
pads); padded kv positions are masked via ``sk_valid`` (tiles touching the
padded tail are never marked FULL) and padded q rows are harmless because
their output rows are sliced off (forward) and their dO rows are zero
(backward).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams

NEG_INF = -1e30

# tile metadata bits (host-packed into the plan's int32 meta arrays)
_FIRST = 1   # first tile of this accumulator group (init scratch)
_LAST = 2    # last tile of this group (write outputs)
_FULL = 4    # no masked element in the tile (skip _tile_mask)


def _tile_mask(iq, jk, *, bq, bk, causal, window, q_offset, sk):
    """[bq, bk] validity mask of one (query-block, kv-block) tile.
    ``iq``/``jk`` may be traced scalars (read from the prefetched plan)."""
    q_pos = q_offset + iq * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0)
    k_pos = jk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = k_pos < sk
    if causal:
        valid = valid & (k_pos <= q_pos)
    if window:
        valid = valid & (k_pos > q_pos - window)
    return valid


# ---------------------------------------------------------------------------
# Host-side tile plan
# ---------------------------------------------------------------------------

def _group_meta(keys, full):
    """Pack FIRST/LAST/FULL flags for a pair list grouped by ``keys`` (the
    accumulator-owning block index, already contiguous)."""
    n = len(keys)
    meta = np.where(full, _FULL, 0).astype(np.int32)
    if n:
        first = np.ones(n, bool)
        first[1:] = keys[1:] != keys[:-1]
        last = np.ones(n, bool)
        last[:-1] = keys[1:] != keys[:-1]
        meta |= np.where(first, _FIRST, 0).astype(np.int32)
        meta |= np.where(last, _LAST, 0).astype(np.int32)
    return meta


@functools.lru_cache(maxsize=256)
def flash_grid_plan(Sq: int, Sk: int, bq: int, bk: int, causal: bool,
                    window: int, q_offset: int, sk_valid: int):
    """Block-sparse tile plan shared by the forward, dq and dk/dv kernels.

    Enumerates the (iq, jk) tiles with at least one unmasked (q_pos, k_pos)
    pair under causal/window/sk_valid masking, in two orders:

      * ``qblk``/``kblk``/``meta`` — row-major (by q block), for the forward
        and dq kernels whose accumulators are per q block;
      * ``kblk2``/``qblk2``/``meta2`` — column-major (by kv block), for the
        dk/dv kernel whose accumulators are per kv block.

    Tiles fully inside the mask are flagged ``_FULL`` (the kernels skip
    ``_tile_mask`` there). Every output block is guaranteed at least one
    tile in the enumeration order that writes it — and ONLY there: a q
    block with no valid kv tile (only possible for padded q rows) gets a
    masked sentinel in the row-major list, a kv block no q attends to (its
    dk/dv are exact zeros) gets one in the column-major list, so neither
    sentinel class inflates the other kernels' grids.

    ``visited``/``visited_dkv``/``total`` are the pruning ledger the
    benchmarks audit: (iq, jk) tiles walked per order vs the dense nq*nk
    rectangle.
    """
    nq, nk = Sq // bq, Sk // bk
    sk = sk_valid or Sk
    iq = np.arange(nq)[:, None]
    jk = np.arange(nk)[None, :]
    q_lo = q_offset + iq * bq
    q_hi = q_lo + bq - 1
    k_lo = jk * bk
    k_hi = k_lo + bk - 1

    visit = np.broadcast_to(k_lo < sk, (nq, nk)).copy()
    if causal:
        visit &= k_lo <= q_hi
    if window:
        visit &= k_hi > q_lo - window

    full = np.broadcast_to(k_hi < sk, (nq, nk)).copy()
    if causal:
        full &= k_hi <= q_lo
    if window:
        full &= k_lo > q_hi - window
    full &= visit

    # each sentinel class goes ONLY to the enumeration order that needs it
    # (a dkv sentinel walked by fwd/dq would erase the pruning win there)
    visit_fwd = visit.copy()
    empty_q = ~visit.any(axis=1)
    if empty_q.any():                       # padded q rows: force one tile
        visit_fwd[empty_q, 0] = True
    visit_dkv = visit.copy()
    empty_k = ~visit.any(axis=0)
    if empty_k.any():                       # unattended kv: zeros sentinel
        visit_dkv[nq - 1, empty_k] = True

    rows = np.argwhere(visit_fwd)           # row-major: sorted by (iq, jk)
    qblk, kblk = rows[:, 0].astype(np.int32), rows[:, 1].astype(np.int32)
    meta = _group_meta(qblk, full[rows[:, 0], rows[:, 1]])

    cols = np.argwhere(visit_dkv.T)         # column-major: sorted by (jk, iq)
    kblk2, qblk2 = cols[:, 0].astype(np.int32), cols[:, 1].astype(np.int32)
    meta2 = _group_meta(kblk2, full[cols[:, 1], cols[:, 0]])

    return {"qblk": qblk, "kblk": kblk, "meta": meta,
            "kblk2": kblk2, "qblk2": qblk2, "meta2": meta2,
            "visited": int(len(rows)), "visited_dkv": int(len(cols)),
            "total": int(nq * nk)}


def _plan_args(plan, transposed: bool):
    keys = ("kblk2", "qblk2", "meta2") if transposed else \
        ("qblk", "kblk", "meta")
    return tuple(jnp.asarray(plan[k]) for k in keys)


def _tile_dispatch(meta, s, accumulate, iq, jk, *, bq, bk, causal, window,
                   q_offset, sk):
    """Feed one tile's scores to ``accumulate``: FULL tiles skip the mask
    entirely; boundary tiles get ``_tile_mask`` applied first."""
    @pl.when((meta & _FULL) != 0)
    def _interior():
        accumulate(s)

    @pl.when((meta & _FULL) == 0)
    def _boundary():
        valid = _tile_mask(iq, jk, bq=bq, bk=bk, causal=causal,
                           window=window, q_offset=q_offset, sk=sk)
        accumulate(jnp.where(valid, s, NEG_INF))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _flash_kernel(qblk_ref, kblk_ref, meta_ref, q_ref, k_ref, v_ref,
                  o_ref, lse_ref, m_ref, l_ref, acc_ref, *, scale: float,
                  causal: bool, window: int, q_offset: int, bq: int, bk: int,
                  sk: int):
    t = pl.program_id(1)
    iq, jk, meta = qblk_ref[t], kblk_ref[t], meta_ref[t]

    @pl.when((meta & _FIRST) != 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                  # [bq, d]
    k = k_ref[0].astype(jnp.float32)                  # [bk, d]
    v = v_ref[0].astype(jnp.float32)                  # [bk, dv]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    def _accumulate(s):
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    _tile_dispatch(meta, s, _accumulate, iq, jk, bq=bq, bk=bk, causal=causal,
                   window=window, q_offset=q_offset, sk=sk)

    @pl.when((meta & _LAST) != 0)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m_ref[...] + jnp.log(l)


def flash_attention_kernel(q, k, v, *, causal: bool = True, window: int = 0,
                           q_offset: int = 0, bq: int = 128, bk: int = 128,
                           group: int = 1, sk_valid: int = 0,
                           interpret: bool = False):
    """q: [BH, Sq, d]; k, v: [BKV, Sk, d]; group = heads per kv head.
    ``sk_valid``: true kv length (padded tail positions are masked).
    ``q_offset``: absolute position of q row 0 (for masking parity with
    ``models.attention.blockwise_attention``).

    Returns (out [BH, Sq, dv], lse [BH, Sq] float32) — lse is the per-row
    logsumexp residual the backward kernels consume. The grid walks only the
    tiles in :func:`flash_grid_plan` (block-sparse under causal/window).
    """
    BH, Sq, d = q.shape
    BKV, Sk, dv = v.shape
    scale = 1.0 / math.sqrt(d)
    plan = flash_grid_plan(Sq, Sk, bq, bk, causal, window, q_offset,
                           sk_valid or Sk)
    qblk, kblk, meta = _plan_args(plan, transposed=False)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, bq=bq, bk=bk, sk=sk_valid or Sk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(BH, plan["visited"]),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, t, qb, kb, mt: (b, qb[t], 0)),
            pl.BlockSpec((1, bk, d),
                         lambda b, t, qb, kb, mt, g=group: (b // g, kb[t], 0)),
            pl.BlockSpec((1, bk, dv),
                         lambda b, t, qb, kb, mt, g=group: (b // g, kb[t], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, dv), lambda b, t, qb, kb, mt: (b, qb[t], 0)),
            pl.BlockSpec((1, bq), lambda b, t, qb, kb, mt: (b, qb[t])),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dv), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sq, dv), q.dtype),
            jax.ShapeDtypeStruct((BH, Sq), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qblk, kblk, meta, q, k, v)


# ---------------------------------------------------------------------------
# Backward: dq
# ---------------------------------------------------------------------------

def _flash_bwd_dq_kernel(qblk_ref, kblk_ref, meta_ref, q_ref, k_ref, v_ref,
                         do_ref, lse_ref, delta_ref, dq_ref, dq_acc_ref, *,
                         scale: float, causal: bool, window: int,
                         q_offset: int, bq: int, bk: int, sk: int):
    t = pl.program_id(1)
    iq, jk, meta = qblk_ref[t], kblk_ref[t], meta_ref[t]

    @pl.when((meta & _FIRST) != 0)
    def _init():
        dq_acc_ref[...] = jnp.zeros_like(dq_acc_ref)

    q = q_ref[0].astype(jnp.float32)                  # [bq, d]
    k = k_ref[0].astype(jnp.float32)                  # [bk, d]
    v = v_ref[0].astype(jnp.float32)                  # [bk, dv]
    do = do_ref[0].astype(jnp.float32)                # [bq, dv]
    lse = lse_ref[0]                                  # [bq]
    delta = delta_ref[0]                              # [bq]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    def _accumulate(s):
        p = jnp.exp(s - lse[:, None])                 # [bq, bk]
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale        # [bq, bk]
        dq_acc_ref[...] += jax.lax.dot(ds, k,
                                       preferred_element_type=jnp.float32)

    _tile_dispatch(meta, s, _accumulate, iq, jk, bq=bq, bk=bk, causal=causal,
                   window=window, q_offset=q_offset, sk=sk)

    @pl.when((meta & _LAST) != 0)
    def _finish():
        dq_ref[0] = dq_acc_ref[...].astype(dq_ref.dtype)


def flash_attention_bwd_dq(q, k, v, do, lse, delta, *, causal: bool = True,
                           window: int = 0, q_offset: int = 0, bq: int = 128,
                           bk: int = 128, group: int = 1, sk_valid: int = 0,
                           interpret: bool = False):
    """dq of flash attention. Shapes as the forward; lse/delta: [BH, Sq] f32.
    Returns dq [BH, Sq, d] in q.dtype. Walks the same pruned tile plan as
    the forward."""
    BH, Sq, d = q.shape
    BKV, Sk, dv = v.shape
    scale = 1.0 / math.sqrt(d)
    plan = flash_grid_plan(Sq, Sk, bq, bk, causal, window, q_offset,
                           sk_valid or Sk)
    qblk, kblk, meta = _plan_args(plan, transposed=False)

    kernel = functools.partial(
        _flash_bwd_dq_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, bq=bq, bk=bk, sk=sk_valid or Sk)

    qmap = lambda b, t, qb, kb, mt: (b, qb[t], 0)
    qmap2 = lambda b, t, qb, kb, mt: (b, qb[t])
    kmap = lambda b, t, qb, kb, mt, g=group: (b // g, kb[t], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(BH, plan["visited"]),
        in_specs=[
            pl.BlockSpec((1, bq, d), qmap),
            pl.BlockSpec((1, bk, d), kmap),
            pl.BlockSpec((1, bk, dv), kmap),
            pl.BlockSpec((1, bq, dv), qmap),
            pl.BlockSpec((1, bq), qmap2),
            pl.BlockSpec((1, bq), qmap2),
        ],
        out_specs=pl.BlockSpec((1, bq, d), qmap),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BH, Sq, d), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qblk, kblk, meta, q, k, v, do, lse, delta)


# ---------------------------------------------------------------------------
# Backward: dk / dv (GQA reduction over the query-group axis in-kernel)
# ---------------------------------------------------------------------------

def _flash_bwd_dkv_kernel(kblk_ref, qblk_ref, meta_ref, q_ref, k_ref, v_ref,
                          do_ref, lse_ref, delta_ref, dk_ref, dv_ref,
                          dk_acc_ref, dv_acc_ref, *, scale: float,
                          causal: bool, window: int, q_offset: int, bq: int,
                          bk: int, ng: int, sk: int):
    t = pl.program_id(1)
    g = pl.program_id(2)
    jk, iq, meta = kblk_ref[t], qblk_ref[t], meta_ref[t]

    @pl.when(((meta & _FIRST) != 0) & (g == 0))
    def _init():
        dk_acc_ref[...] = jnp.zeros_like(dk_acc_ref)
        dv_acc_ref[...] = jnp.zeros_like(dv_acc_ref)

    q = q_ref[0].astype(jnp.float32)                  # [bq, d]
    k = k_ref[0].astype(jnp.float32)                  # [bk, d]
    v = v_ref[0].astype(jnp.float32)                  # [bk, dv]
    do = do_ref[0].astype(jnp.float32)                # [bq, dv]
    lse = lse_ref[0]                                  # [bq]
    delta = delta_ref[0]                              # [bq]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    def _accumulate(s):
        p = jnp.exp(s - lse[:, None])                 # [bq, bk]
        # dv += p^T @ dO
        dv_acc_ref[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale        # [bq, bk]
        # dk += ds^T @ q
        dk_acc_ref[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    _tile_dispatch(meta, s, _accumulate, iq, jk, bq=bq, bk=bk, causal=causal,
                   window=window, q_offset=q_offset, sk=sk)

    @pl.when(((meta & _LAST) != 0) & (g == ng - 1))
    def _finish():
        dk_ref[0] = dk_acc_ref[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc_ref[...].astype(dv_ref.dtype)


def flash_attention_bwd_dkv(q, k, v, do, lse, delta, *, causal: bool = True,
                            window: int = 0, q_offset: int = 0, bq: int = 128,
                            bk: int = 128, group: int = 1, sk_valid: int = 0,
                            interpret: bool = False):
    """dk, dv of flash attention, accumulated across all ``group`` query
    heads of each kv head (GQA) and every visited query block inside the
    kernel. Walks the plan transposed (tiles grouped by kv block); kv blocks
    outside every q block's mask get a single sentinel tile so their dk/dv
    are written as exact zeros. Returns (dk [BKV, Sk, d], dv [BKV, Sk, dv])
    in k/v dtype."""
    BH, Sq, d = q.shape
    BKV, Sk, dv = v.shape
    scale = 1.0 / math.sqrt(d)
    plan = flash_grid_plan(Sq, Sk, bq, bk, causal, window, q_offset,
                           sk_valid or Sk)
    kblk2, qblk2, meta2 = _plan_args(plan, transposed=True)

    kernel = functools.partial(
        _flash_bwd_dkv_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, bq=bq, bk=bk, ng=group, sk=sk_valid or Sk)

    qmap = lambda b, t, g, kb, qb, mt, G=group: (b * G + g, qb[t], 0)
    qmap2 = lambda b, t, g, kb, qb, mt, G=group: (b * G + g, qb[t])
    kmap = lambda b, t, g, kb, qb, mt: (b, kb[t], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(BKV, plan["visited_dkv"], group),
        in_specs=[
            pl.BlockSpec((1, bq, d), qmap),
            pl.BlockSpec((1, bk, d), kmap),
            pl.BlockSpec((1, bk, dv), kmap),
            pl.BlockSpec((1, bq, dv), qmap),
            pl.BlockSpec((1, bq), qmap2),
            pl.BlockSpec((1, bq), qmap2),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, d), kmap),
            pl.BlockSpec((1, bk, dv), kmap),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, d), jnp.float32),
            pltpu.VMEM((bk, dv), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((BKV, Sk, d), k.dtype),
            jax.ShapeDtypeStruct((BKV, Sk, dv), v.dtype),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(kblk2, qblk2, meta2, q, k, v, do, lse, delta)
