"""Pallas TPU flash-attention (prefill/training forward), causal + sliding
window, GQA-aware via the wrapper in ops.py.

Layout: q [BH, Sq, d], k/v [BKV, Sk, d] with BH = batch*heads,
BKV = batch*kv_heads. Grid (BH, nq, nk): the kv dimension is the innermost
(sequential) axis; the online-softmax accumulators (m, l, acc) live in VMEM
scratch and persist across the kv iterations of one (bh, iq) tile — the
classic flash structure mapped to the TPU grid. Block shapes are multiples
of 128 on the lane dim for MXU alignment (ops.py pads).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, bq: int, bk: int,
                  nk: int, sk: int):
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                  # [bq, d]
    k = k_ref[0].astype(jnp.float32)                  # [bk, d]
    v = v_ref[0].astype(jnp.float32)                  # [bk, dv]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = jk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = k_pos < sk
    if causal:
        valid = valid & (k_pos <= q_pos)
    if window:
        valid = valid & (k_pos > q_pos - window)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(jk == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True, window: int = 0,
                           bq: int = 128, bk: int = 128, group: int = 1,
                           sk_valid: int = 0, interpret: bool = False):
    """q: [BH, Sq, d]; k, v: [BKV, Sk, d]; group = heads per kv head.
    ``sk_valid``: true kv length (padded tail positions are masked)."""
    BH, Sq, d = q.shape
    BKV, Sk, dv = v.shape
    nq = Sq // bq
    nk = Sk // bk
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, nk=nk, sk=sk_valid or Sk)

    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, bk, dv), lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dv), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
