"""Pure-jnp oracles for every Pallas kernel — the correctness ground truth
the shape/dtype sweep tests assert against."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        group: int = 1):
    """q: [BH, Sq, d]; k, v: [BKV, Sk, d]. Naive full-matrix attention."""
    BH, Sq, d = q.shape
    BKV, Sk, dv = v.shape
    scale = 1.0 / math.sqrt(d)
    kq = jnp.repeat(k, group, axis=0)
    vq = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32),
                   kq.astype(jnp.float32)) * scale
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (kp <= qp)
    if window:
        mask = mask & (kp > qp - window)
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bst,btd->bsd", p, vq.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k, v, cache_len, *, group: int = 1):
    """q: [BH, d]; k, v: [BKV, T, d]; cache_len: [BKV]."""
    BH, d = q.shape
    scale = 1.0 / math.sqrt(d)
    kq = jnp.repeat(k, group, axis=0)
    vq = jnp.repeat(v, group, axis=0)
    ln = jnp.repeat(cache_len, group, axis=0)
    s = jnp.einsum("bd,btd->bt", q.astype(jnp.float32),
                   kq.astype(jnp.float32)) * scale
    T = k.shape[1]
    s = jnp.where(jnp.arange(T)[None] < ln[:, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bt,btd->bd", p, vq.astype(jnp.float32)).astype(q.dtype)


def _gla_scan_full(q, k, v, g):
    def step(state, inp):
        qt, kt, vt, gt = inp
        state = jnp.exp(gt.astype(jnp.float32))[:, None, None] * state + \
            jnp.einsum("bd,bv->bdv", kt.astype(jnp.float32),
                       vt.astype(jnp.float32))
        yt = jnp.einsum("bd,bdv->bv", qt.astype(jnp.float32), state)
        return state, yt

    BH, _, dk = q.shape
    s0 = jnp.zeros((BH, dk, v.shape[-1]), jnp.float32)
    state, ys = jax.lax.scan(step, s0, (jnp.moveaxis(q, 1, 0),
                                        jnp.moveaxis(k, 1, 0),
                                        jnp.moveaxis(v, 1, 0),
                                        jnp.moveaxis(g, 1, 0)))
    return jnp.moveaxis(ys, 0, 1).astype(q.dtype), state


def gla_scan_ref(q, k, v, g):
    """Exact sequential recurrence: S_t = exp(g_t) S_{t-1} + k_t v_t^T;
    y_t = q_t . S_t.  q,k: [BH,S,dk]; v: [BH,S,dv]; g: [BH,S]."""
    return _gla_scan_full(q, k, v, g)[0]


def gla_final_state_ref(q, k, v, g):
    """The [BH, dk, dv] float32 state after the last position — the oracle
    for the kernel's final-state output (and its padded-row masking)."""
    return _gla_scan_full(q, k, v, g)[1]
