"""Pallas TPU decode attention: one query token vs a long KV cache.

Layout: q [BH, d], k/v [BKV, T, d]. Grid (BH, nk): kv blocks stream through
VMEM while the online-softmax accumulator persists in scratch — the memory-
bound flash-decode pattern (arithmetic intensity ~= 1 FLOP/byte, so the block
size mainly amortises HBM->VMEM latency).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, scale: float, bk: int, nk: int, window: int):
    jk = pl.program_id(1)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                    # [1, d] row
    k = k_ref[0].astype(jnp.float32)                    # [bk, d]
    v = v_ref[0].astype(jnp.float32)                    # [bk, dv]
    valid_len = len_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # [1,bk]
    pos = jk * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    valid = pos < valid_len
    if window:
        # sliding window over a linear cache: the query position is
        # valid_len - 1, so only pos > valid_len - 1 - window contributes
        valid &= pos > valid_len - 1 - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(jk == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def decode_attention_kernel(q, k, v, cache_len, *, bk: int = 512,
                            group: int = 1, window: int = 0,
                            interpret: bool = False):
    """q: [BH, d]; k: [BKV, T, d]; v: [BKV, T, dv]; cache_len: [BKV] int32
    -> [BH, dv]. ``window`` > 0 masks cache positions more than ``window``
    behind the query (linear caches; ring buffers pass window=0)."""
    BH, d = q.shape
    BKV, T, dv = v.shape
    nk = T // bk
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(_decode_kernel, scale=scale, bk=bk, nk=nk,
                               window=window)
    q3 = q[:, None, :]                                   # [BH, 1, d]

    out = pl.pallas_call(
        kernel,
        grid=(BH, nk),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, bk, dv), lambda b, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1,), lambda b, j, g=group: (b // g,),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, dv), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, 1, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, dv), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q3, k, v, cache_len)
    return out[:, 0, :]
