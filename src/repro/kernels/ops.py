"""Jitted public wrappers around the Pallas kernels: padding, GQA head
bookkeeping, block-size selection, and the interpret switch (CPU validation
vs TPU execution)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention_kernel
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.ssm_scan import gla_scan_kernel


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if not pad:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


@partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                   "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128, interpret: bool = False):
    """q: [B,Sq,H,dh]; k,v: [B,Sk,KV,dh] -> [B,Sq,H,dh]. Heads fold into the
    grid's batch dim; GQA via the kv index map (group = H // KV)."""
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    group = H // KV
    bq = min(bq, Sq)
    bk = min(bk, Sk)

    qh = jnp.moveaxis(q, 2, 1).reshape(B * H, Sq, dh)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * KV, Sk, dh)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * KV, Sk, dh)
    qh, sq0 = _pad_to(qh, 1, bq)
    kh, sk0 = _pad_to(kh, 1, bk)
    vh, _ = _pad_to(vh, 1, bk)
    # padded kv positions are masked because kv_pos < sk is checked with the
    # ORIGINAL length baked into the kernel closure
    out = flash_attention_kernel(qh, kh, vh, causal=causal, window=window,
                                 bq=bq, bk=bk, group=group, sk_valid=sk0,
                                 interpret=interpret)
    out = out[:, :sq0]
    return jnp.moveaxis(out.reshape(B, H, Sq, dh), 1, 2)


@partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention(q, k, v, cache_len, *, bk: int = 512,
                     interpret: bool = False):
    """q: [B,1,H,dh]; k,v: [B,T,KV,dh]; cache_len: [B] -> [B,1,H,dh]."""
    B, _, H, dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    group = H // KV
    bk = min(bk, T)
    qh = q[:, 0].reshape(B, H, dh).reshape(B * H, dh)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * KV, T, dh)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * KV, T, dh)
    kh, _ = _pad_to(kh, 1, bk)
    vh, _ = _pad_to(vh, 1, bk)
    ln = jnp.repeat(cache_len, KV, axis=0)
    out = decode_attention_kernel(qh, kh, vh, ln, bk=bk, group=group,
                                  interpret=interpret)
    return out.reshape(B, H, dh)[:, None][:, :, :, :].reshape(B, 1, H, dh)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def gla_scan(q, k, v, g, *, chunk: int = 64, interpret: bool = False):
    """Chunked gated-linear-attention. q,k: [B,S,H,dk]; v: [B,S,H,dv];
    g: [B,S,H] log-decay. Returns y: [B,S,H,dv]."""
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, S)

    def fold(x):
        return jnp.moveaxis(x, 2, 1).reshape((B * H, S) + x.shape[3:])

    qh, kh, vh = fold(q), fold(k), fold(v)
    gh = jnp.moveaxis(g, 2, 1).reshape(B * H, S)
    qh, s0 = _pad_to(qh, 1, chunk)
    kh, _ = _pad_to(kh, 1, chunk)
    vh, _ = _pad_to(vh, 1, chunk)
    gh, _ = _pad_to(gh, 1, chunk)
    y = gla_scan_kernel(qh, kh, vh, gh, chunk=chunk, interpret=interpret)
    y = y[:, :s0]
    return jnp.moveaxis(y.reshape(B, H, S, dv), 1, 2)
