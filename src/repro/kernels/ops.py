"""Jitted public wrappers around the Pallas kernels: padding, GQA head
bookkeeping, block-size selection, and the interpret switch (CPU validation
vs TPU execution).

``flash_attention`` is differentiable: a ``jax.custom_vjp`` routes its
backward pass through the fused Pallas dq and dk/dv kernels in
``repro.kernels.flash_attention`` (FlashAttention-2 style — the forward
saves the per-row logsumexp, the backward recomputes probabilities blockwise
from it after a precomputed ``delta = sum(dO * O)`` pass). This is the
kernel pair behind ``attn_backend="pallas"`` in ``ModelConfig``; with
``interpret=True`` the same VJP runs on CPU for tier-1 validation.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention_kernel
from repro.kernels.flash_attention import (flash_attention_bwd_dkv,
                                           flash_attention_bwd_dq,
                                           flash_attention_kernel)
from repro.kernels.ssm_scan import gla_scan_kernel


def default_interpret() -> bool:
    """True off-TPU: Pallas kernels run in the (slow, exact) interpreter so
    the kernel-backed paths stay testable on CPU hosts."""
    return jax.default_backend() != "tpu"


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if not pad:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


# ---------------------------------------------------------------------------
# Flash attention with a fused-kernel VJP. The custom_vjp core operates on
# the folded, block-padded layout (q [BH, Sq, d]; k/v [BKV, Sk, d]) so the
# residuals are exactly the kernel operands; head fold/unfold and padding
# live in the public wrapper, where plain jax AD transposes them.
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9, 10))
def _flash_core(qh, kh, vh, causal, window, q_offset, bq, bk, group,
                sk_valid, interpret):
    out, _ = flash_attention_kernel(
        qh, kh, vh, causal=causal, window=window, q_offset=q_offset,
        bq=bq, bk=bk, group=group, sk_valid=sk_valid, interpret=interpret)
    return out


def _flash_core_fwd(qh, kh, vh, causal, window, q_offset, bq, bk, group,
                    sk_valid, interpret):
    out, lse = flash_attention_kernel(
        qh, kh, vh, causal=causal, window=window, q_offset=q_offset,
        bq=bq, bk=bk, group=group, sk_valid=sk_valid, interpret=interpret)
    return out, (qh, kh, vh, out, lse)


def _flash_core_bwd(causal, window, q_offset, bq, bk, group, sk_valid,
                    interpret, res, do):
    qh, kh, vh, out, lse = res
    # delta pass: D_i = sum_d dO_id * O_id, one fused elementwise-reduce
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    kw = dict(causal=causal, window=window, q_offset=q_offset, bq=bq, bk=bk,
              group=group, sk_valid=sk_valid, interpret=interpret)
    dq = flash_attention_bwd_dq(qh, kh, vh, do, lse, delta, **kw)
    dk, dv = flash_attention_bwd_dkv(qh, kh, vh, do, lse, delta, **kw)
    return dq, dk, dv


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


@partial(jax.jit, static_argnames=("causal", "window", "q_offset", "bq",
                                   "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, bq: int = 128, bk: int = 128,
                    interpret: bool = False):
    """q: [B,Sq,H,dh]; k,v: [B,Sk,KV,dv] -> [B,Sq,H,dv]. Heads fold into the
    grid's batch dim; GQA via the kv index map (group = H // KV).

    Differentiable — ``jax.grad`` through this runs the Pallas dq + dk/dv
    kernels. kv padding beyond ``Sk`` is masked inside every kernel
    (``sk_valid``); q padding is sliced off here (forward) and carries zero
    cotangents (backward)."""
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    group = H // KV
    bq = min(bq, Sq)
    bk = min(bk, Sk)

    qh = jnp.moveaxis(q, 2, 1).reshape(B * H, Sq, dh)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * KV, Sk, dh)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * KV, Sk, dv)
    qh, sq0 = _pad_to(qh, 1, bq)
    kh, sk0 = _pad_to(kh, 1, bk)
    vh, _ = _pad_to(vh, 1, bk)
    # padded kv positions are masked because kv_pos < sk is checked with the
    # ORIGINAL length baked into the kernel closure
    out = _flash_core(qh, kh, vh, causal, window, q_offset, bq, bk, group,
                      sk0, interpret)
    out = out[:, :sq0]
    return jnp.moveaxis(out.reshape(B, H, Sq, dv), 1, 2)


@partial(jax.jit, static_argnames=("window", "bk", "interpret"))
def decode_attention(q, k, v, cache_len, *, window: int = 0, bk: int = 512,
                     interpret: bool = False):
    """q: [B,1,H,dh]; k: [B,T,KV,dh]; v: [B,T,KV,dv]; cache_len: [B]
    -> [B,1,H,dv]. This is the ``decode_attn="pallas"`` registry op; dv may
    differ from dh (MLA latent decode). ``window`` > 0 applies sliding-window
    masking on a linear cache (ring-buffer callers pass window=0 — the
    wrapped ``cache_len`` semantics already cover the ring)."""
    B, _, H, dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    group = H // KV
    bk = min(bk, T)
    qh = q[:, 0].reshape(B, H, dh).reshape(B * H, dh)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * KV, T, dh)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * KV, T, dv)
    kh, _ = _pad_to(kh, 1, bk)
    vh, _ = _pad_to(vh, 1, bk)
    ln = jnp.repeat(cache_len, KV, axis=0)
    out = decode_attention_kernel(qh, kh, vh, ln, bk=bk, group=group,
                                  window=window, interpret=interpret)
    return out.reshape(B, 1, H, dv)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def gla_scan(q, k, v, g, *, chunk: int = 64, interpret: bool = False):
    """Chunked gated-linear-attention. q,k: [B,S,H,dk]; v: [B,S,H,dv];
    g: [B,S,H] log-decay. Returns y: [B,S,H,dv]."""
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, S)

    def fold(x):
        return jnp.moveaxis(x, 2, 1).reshape((B * H, S) + x.shape[3:])

    qh, kh, vh = fold(q), fold(k), fold(v)
    gh = jnp.moveaxis(g, 2, 1).reshape(B * H, S)
    qh, s0 = _pad_to(qh, 1, chunk)
    kh, _ = _pad_to(kh, 1, chunk)
    vh, _ = _pad_to(vh, 1, chunk)
    gh, _ = _pad_to(gh, 1, chunk)
    y = gla_scan_kernel(qh, kh, vh, gh, chunk=chunk, interpret=interpret)
    y = y[:, :s0]
    return jnp.moveaxis(y.reshape(B, H, S, dv), 1, 2)
