"""Jitted public wrappers around the Pallas kernels: padding, GQA head
bookkeeping, block-size selection, and the interpret switch (CPU validation
vs TPU execution).

``flash_attention`` is differentiable: a ``jax.custom_vjp`` routes its
backward pass through the fused Pallas dq and dk/dv kernels in
``repro.kernels.flash_attention`` (FlashAttention-2 style — the forward
saves the per-row logsumexp, the backward recomputes probabilities blockwise
from it after a precomputed ``delta = sum(dO * O)`` pass). The pruned
block-sparse grids are picked automatically from the ``causal``/``window``
statics — every kernel call walks ``flash_grid_plan``'s tile list, so
causal training skips the upper block triangle and sliding-window training
visits a constant ~ceil(window/bk)+1 kv blocks per q block.

``gla_scan`` is differentiable the same way: its ``jax.custom_vjp`` pairs
the forward chunk-scan kernel (which checkpoints the per-chunk entering
states) with the fused reverse chunk-scan kernel in
``repro.kernels.ssm_scan`` — a single backward pass, no recompute through
the jnp scan. These are the kernels behind ``kernels="pallas"`` in
``ModelConfig``; with ``interpret=True`` the same VJPs run on CPU for
tier-1 validation.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention_kernel
from repro.kernels.flash_attention import (flash_attention_bwd_dkv,
                                           flash_attention_bwd_dq,
                                           flash_attention_kernel)
from repro.kernels.paged_attention import (paged_decode_attention_kernel,
                                           paged_prefill_attention_kernel)
from repro.kernels.ssm_scan import gla_scan_bwd_kernel, gla_scan_kernel


def default_interpret() -> bool:
    """True off-TPU: Pallas kernels run in the (slow, exact) interpreter so
    the kernel-backed paths stay testable on CPU hosts."""
    return jax.default_backend() != "tpu"


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if not pad:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


# ---------------------------------------------------------------------------
# Flash attention with a fused-kernel VJP. The custom_vjp core operates on
# the folded, block-padded layout (q [BH, Sq, d]; k/v [BKV, Sk, d]) so the
# residuals are exactly the kernel operands; head fold/unfold and padding
# live in the public wrapper, where plain jax AD transposes them.
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9, 10))
def _flash_core(qh, kh, vh, causal, window, q_offset, bq, bk, group,
                sk_valid, interpret):
    out, _ = flash_attention_kernel(
        qh, kh, vh, causal=causal, window=window, q_offset=q_offset,
        bq=bq, bk=bk, group=group, sk_valid=sk_valid, interpret=interpret)
    return out


def _flash_core_fwd(qh, kh, vh, causal, window, q_offset, bq, bk, group,
                    sk_valid, interpret):
    out, lse = flash_attention_kernel(
        qh, kh, vh, causal=causal, window=window, q_offset=q_offset,
        bq=bq, bk=bk, group=group, sk_valid=sk_valid, interpret=interpret)
    return out, (qh, kh, vh, out, lse)


def _flash_core_bwd(causal, window, q_offset, bq, bk, group, sk_valid,
                    interpret, res, do):
    qh, kh, vh, out, lse = res
    # delta pass: D_i = sum_d dO_id * O_id, one fused elementwise-reduce
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    kw = dict(causal=causal, window=window, q_offset=q_offset, bq=bq, bk=bk,
              group=group, sk_valid=sk_valid, interpret=interpret)
    dq = flash_attention_bwd_dq(qh, kh, vh, do, lse, delta, **kw)
    dk, dv = flash_attention_bwd_dkv(qh, kh, vh, do, lse, delta, **kw)
    return dq, dk, dv


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


@partial(jax.jit, static_argnames=("causal", "window", "q_offset", "bq",
                                   "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, bq: int = 128, bk: int = 128,
                    interpret: bool = False):
    """q: [B,Sq,H,dh]; k,v: [B,Sk,KV,dv] -> [B,Sq,H,dv]. Heads fold into the
    grid's batch dim; GQA via the kv index map (group = H // KV).

    Differentiable — ``jax.grad`` through this runs the Pallas dq + dk/dv
    kernels. kv padding beyond ``Sk`` is masked inside every kernel
    (``sk_valid``); q padding is sliced off here (forward) and carries zero
    cotangents (backward)."""
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    group = H // KV
    bq = min(bq, Sq)
    bk = min(bk, Sk)

    qh = jnp.moveaxis(q, 2, 1).reshape(B * H, Sq, dh)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * KV, Sk, dh)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * KV, Sk, dv)
    qh, sq0 = _pad_to(qh, 1, bq)
    kh, sk0 = _pad_to(kh, 1, bk)
    vh, _ = _pad_to(vh, 1, bk)
    # padded kv positions are masked because kv_pos < sk is checked with the
    # ORIGINAL length baked into the kernel closure
    out = _flash_core(qh, kh, vh, causal, window, q_offset, bq, bk, group,
                      sk0, interpret)
    out = out[:, :sq0]
    return jnp.moveaxis(out.reshape(B, H, Sq, dv), 1, 2)


@partial(jax.jit, static_argnames=("window", "bk", "interpret"))
def decode_attention(q, k, v, cache_len, *, window: int = 0, bk: int = 512,
                     interpret: bool = False):
    """q: [B,1,H,dh]; k: [B,T,KV,dh]; v: [B,T,KV,dv]; cache_len: [B]
    -> [B,1,H,dv]. This is the ``decode_attn="pallas"`` registry op; dv may
    differ from dh (MLA latent decode). ``window`` > 0 applies sliding-window
    masking on a linear cache (ring-buffer callers pass window=0 — the
    wrapped ``cache_len`` semantics already cover the ring)."""
    B, _, H, dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    group = H // KV
    bk = min(bk, T)
    qh = q[:, 0].reshape(B, H, dh).reshape(B * H, dh)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * KV, T, dh)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * KV, T, dv)
    kh, _ = _pad_to(kh, 1, bk)
    vh, _ = _pad_to(vh, 1, bk)
    ln = jnp.repeat(cache_len, KV, axis=0)
    out = decode_attention_kernel(qh, kh, vh, ln, bk=bk, group=group,
                                  window=window, interpret=interpret)
    return out.reshape(B, 1, H, dv)


@partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pool, v_pool, table, cache_len, *,
                           interpret: bool = False):
    """q: [B,1,H,dh]; k/v_pool: [NB+1,bs,KV,dh] (block pool, last block is
    the trash block); table: [B,nb] int32; cache_len: [B] -> [B,1,H,dv].
    The ``paged_attn="pallas"`` decode op: KV blocks are read through the
    scalar-prefetched block table, no gather materialises the row's cache."""
    B, _, H, dh = q.shape
    dv = v_pool.shape[-1]
    qh = q[:, 0].reshape(B * H, dh)
    out = paged_decode_attention_kernel(
        qh, k_pool, v_pool, table.reshape(-1).astype(jnp.int32),
        cache_len.astype(jnp.int32), heads=H, interpret=interpret)
    return out.reshape(B, 1, H, dv)


@partial(jax.jit, static_argnames=("bq", "interpret"))
def paged_prefill_attention(q, k_pool, v_pool, table, q_start, kv_len, *,
                            bq: int = 128, interpret: bool = False):
    """q: [B,Sq,H,dh] ragged tail (row b's token i is at absolute position
    ``q_start[b] + i``; the tail's K/V must already be scattered into the
    pool); table: [B,nb]; kv_len: [B] total valid length -> [B,Sq,H,dv].
    Forward-only (serving admission); padding rows are masked by kv_len."""
    B, Sq, H, dh = q.shape
    dv = v_pool.shape[-1]
    bq = min(bq, Sq)
    qh = jnp.moveaxis(q, 2, 1).reshape(B * H, Sq, dh)
    qh, sq0 = _pad_to(qh, 1, bq)
    out = paged_prefill_attention_kernel(
        qh, k_pool, v_pool, table.reshape(-1).astype(jnp.int32),
        q_start.astype(jnp.int32), kv_len.astype(jnp.int32),
        heads=H, bq=bq, interpret=interpret)
    out = out[:, :sq0]
    return jnp.moveaxis(out.reshape(B, H, Sq, dv), 1, 2)


# ---------------------------------------------------------------------------
# GLA chunk scan with a fused-kernel VJP. Like flash attention, the
# custom_vjp core operates on the folded, chunk-padded layout (q,k [BH,S,dk];
# v [BH,S,dv]; g [BH,S]) so the residuals — inputs + the per-chunk entering
# states the forward checkpoints — are exactly the kernel operands; head
# fold/unfold and padding live in the public wrapper, where plain jax AD
# transposes them (padded rows therefore carry zero cotangents).
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _gla_core(qh, kh, vh, gh, chunk, s_valid, interpret):
    y, _ = gla_scan_kernel(qh, kh, vh, gh, chunk=chunk, s_valid=s_valid,
                           interpret=interpret)
    return y


def _gla_core_fwd(qh, kh, vh, gh, chunk, s_valid, interpret):
    y, states, _ = gla_scan_kernel(qh, kh, vh, gh, chunk=chunk,
                                   s_valid=s_valid, collect_states=True,
                                   interpret=interpret)
    return y, (qh, kh, vh, gh, states)


def _gla_core_bwd(chunk, s_valid, interpret, res, dy):
    qh, kh, vh, gh, states = res
    return gla_scan_bwd_kernel(qh, kh, vh, gh, states, dy, chunk=chunk,
                               s_valid=s_valid, interpret=interpret)


_gla_core.defvjp(_gla_core_fwd, _gla_core_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _gla_core_with_state(qh, kh, vh, gh, chunk, s_valid, interpret):
    return gla_scan_kernel(qh, kh, vh, gh, chunk=chunk, s_valid=s_valid,
                           interpret=interpret)


def _gla_core_with_state_fwd(qh, kh, vh, gh, chunk, s_valid, interpret):
    return _gla_core_with_state(qh, kh, vh, gh, chunk, s_valid,
                                interpret), None


def _gla_core_with_state_bwd(chunk, s_valid, interpret, res, dy):
    raise NotImplementedError(
        "ops.gla_scan(return_final_state=True) is a forward-only path "
        "(prefill/decode-cache fill); differentiate the default "
        "gla_scan(...) instead — its custom_vjp runs the fused reverse "
        "chunk-scan kernel.")


_gla_core_with_state.defvjp(_gla_core_with_state_fwd,
                            _gla_core_with_state_bwd)


@partial(jax.jit, static_argnames=("chunk", "interpret",
                                   "return_final_state"))
def gla_scan(q, k, v, g, *, chunk: int = 64, interpret: bool = False,
             return_final_state: bool = False):
    """Chunked gated-linear-attention. q,k: [B,S,H,dk]; v: [B,S,H,dv];
    g: [B,S,H] log-decay. Returns y: [B,S,H,dv].

    Differentiable — ``jax.grad`` through this runs the fused reverse
    chunk-scan kernel (single backward pass; the forward checkpoints its
    per-chunk states). With ``return_final_state=True`` also returns the
    [B,H,dk,dv] float32 state after the last VALID position — padded rows
    are masked out of the state update inside the kernel, so the state is
    exact for any S (this path is forward-only; training consumers use the
    default)."""
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, S)

    def fold(x):
        return jnp.moveaxis(x, 2, 1).reshape((B * H, S) + x.shape[3:])

    qh, kh, vh = fold(q), fold(k), fold(v)
    gh = jnp.moveaxis(g, 2, 1).reshape(B * H, S)
    qh, s0 = _pad_to(qh, 1, chunk)
    kh, _ = _pad_to(kh, 1, chunk)
    vh, _ = _pad_to(vh, 1, chunk)
    gh, _ = _pad_to(gh, 1, chunk)
    if return_final_state:
        # forward-only path: the custom_vjp exists solely to turn an AD
        # attempt into a clear error at the API (not deep inside pallas)
        y, fin = _gla_core_with_state(qh, kh, vh, gh, chunk, s0, interpret)
        y = jnp.moveaxis(y[:, :s0].reshape(B, H, S, dv), 1, 2)
        return y, fin.reshape(B, H, dk, dv)
    y = _gla_core(qh, kh, vh, gh, chunk, s0, interpret)
    y = y[:, :s0]
    return jnp.moveaxis(y.reshape(B, H, S, dv), 1, 2)
