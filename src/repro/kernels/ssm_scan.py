"""Pallas TPU chunked gated-linear-attention scan (Mamba2 SSD / mLSTM core)
— forward AND fused one-pass backward.

Layout: q,k [BH, S, dk]; v [BH, S, dv]; g [BH, S] (log-decay <= 0).

Forward — grid (BH, nchunks) with the chunk axis sequential: the [dk, dv]
recurrent state lives in VMEM scratch and is carried across chunk
iterations; within a chunk the recurrence becomes two MXU contractions plus
a masked [Q, Q] contraction — the state-space-duality form, tiled so the
working set (3 chunk tiles + state + [Q,Q] mask) fits VMEM. Rows at or past
``s_valid`` (the block-padding tail) are masked out of the state update, so
the final state — emitted as a second output — is exact for any padding.
In training the forward also checkpoints the state ENTERING each chunk
(``collect_states=True``), the residual the backward consumes.

Backward — one reverse chunk-scan kernel (grid (BH, nchunks), iterated
newest chunk first via index-map remapping): the [dk, dv] adjoint state
``D_c = dL/dState_c`` lives in VMEM scratch and is carried backwards across
chunks, the per-chunk checkpointed forward states replay the inter-chunk
term, and all four gradients come out in a single pass:

    dq_i = (dSc @ k)_i + e_i * (dy_i @ P^T)        dSc = (dy v^T) . dmat
    dk_j = (dSc^T q)_j + w_j * (v_j @ D^T)
    dv_j = (A^T dy)_j  + w_j * (k_j @ D)           A = (q k^T) . dmat
    dg   = reverse-cumsum of  q.dq - k.dk          (suffix carried across
                                                    chunks in SMEM scratch)

where e = exp(cumsum g), w = exp(cum[-1] - cum), P is the chunk's entering
state and the dg identity dL/dG_t = q_t.dq_t - k_t.dk_t (G = global cumsum
of g) turns the decay gradient into two row-sums — no second forward, no
recompute through the jnp scan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams


def _chunk_decays(g_raw, rows_valid):
    """(cum, e, a, w) of one chunk with padded rows masked out of the state
    path: g forced to 0 (decay 1) and w forced to 0 (no kv contribution)."""
    g = jnp.where(rows_valid, g_raw.astype(jnp.float32), 0.0)
    cum = jnp.cumsum(g)                       # inclusive
    e = jnp.exp(cum)
    a = jnp.exp(cum[-1])
    w = jnp.where(rows_valid, jnp.exp(cum[-1] - cum), 0.0)
    return cum, e, a, w


def _rows_valid(chunk_id, chunk: int, s_valid: int):
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)[:, 0]
    return chunk_id * chunk + rows < s_valid


def _intra_decay(cum, chunk: int):
    """[Q, Q] lower-triangular decay matrix exp(cum_i - cum_j), j <= i."""
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    return jnp.exp(jnp.where(jj <= ii, cum[:, None] - cum[None, :],
                             -jnp.inf))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _gla_fwd_kernel(q_ref, k_ref, v_ref, g_ref, o_ref, fin_ref, *rest,
                    chunk: int, nc: int, s_valid: int, collect: bool):
    if collect:
        states_ref, state_ref = rest
    else:
        (state_ref,) = rest
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    q = q_ref[0].astype(jnp.float32)          # [Q, dk]
    k = k_ref[0].astype(jnp.float32)          # [Q, dk]
    v = v_ref[0].astype(jnp.float32)          # [Q, dv]
    cum, e, a, w = _chunk_decays(g_ref[0], _rows_valid(c, chunk, s_valid))

    # intra-chunk: A_ij = (q_i . k_j) * exp(cum_i - cum_j), j <= i
    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y = jax.lax.dot(scores * _intra_decay(cum, chunk), v,
                    preferred_element_type=jnp.float32)

    # carried-state contribution and state update
    s0 = state_ref[...]                       # [dk, dv]
    if collect:
        states_ref[0, 0] = s0                 # checkpoint: state entering c
    y = y + jax.lax.dot(q * e[:, None], s0,
                        preferred_element_type=jnp.float32)
    s_local = jax.lax.dot_general(k * w[:, None], v,
                                  (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    state_ref[...] = a * s0 + s_local
    o_ref[0] = y.astype(o_ref.dtype)

    @pl.when(c == nc - 1)
    def _emit_final():
        fin_ref[0] = state_ref[...]


def gla_scan_kernel(q, k, v, g, *, chunk: int = 64, s_valid: int = 0,
                    collect_states: bool = False, interpret: bool = False):
    """Forward chunk scan. S must be a multiple of chunk (ops.py pads);
    ``s_valid`` is the true length — padded rows never touch the state.

    Returns (y [BH, S, dv], final_state [BH, dk, dv] f32), plus the
    per-chunk entering states [BH, nc, dk, dv] f32 in the middle when
    ``collect_states`` (the backward's residual):
    (y, states, final_state)."""
    BH, S, dk = q.shape
    dv = v.shape[-1]
    nc = S // chunk

    kernel = functools.partial(_gla_fwd_kernel, chunk=chunk, nc=nc,
                               s_valid=s_valid or S, collect=collect_states)
    out_specs = [
        pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
        pl.BlockSpec((1, dk, dv), lambda b, c: (b, 0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((BH, S, dv), q.dtype),
        jax.ShapeDtypeStruct((BH, dk, dv), jnp.float32),
    ]
    if collect_states:
        out_specs.append(
            pl.BlockSpec((1, 1, dk, dv), lambda b, c: (b, c, 0, 0)))
        out_shape.append(
            jax.ShapeDtypeStruct((BH, nc, dk, dv), jnp.float32))

    outs = pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, g)
    y, fin = outs[0], outs[1]
    return (y, outs[2], fin) if collect_states else (y, fin)


# ---------------------------------------------------------------------------
# Backward: one reverse chunk scan, adjoint state in VMEM scratch
# ---------------------------------------------------------------------------

def _gla_bwd_kernel(q_ref, k_ref, v_ref, g_ref, st_ref, dy_ref,
                    dq_ref, dk_ref, dv_ref, dg_ref, dstate_ref, carry_ref, *,
                    chunk: int, nc: int, s_valid: int):
    r = pl.program_id(1)                      # 0 = NEWEST chunk (index maps
    c = nc - 1 - r                            # walk the chunks reversed)

    @pl.when(r == 0)
    def _init():
        dstate_ref[...] = jnp.zeros_like(dstate_ref)
        carry_ref[0] = 0.0

    q = q_ref[0].astype(jnp.float32)          # [Q, dk]
    k = k_ref[0].astype(jnp.float32)          # [Q, dk]
    v = v_ref[0].astype(jnp.float32)          # [Q, dv]
    dy = dy_ref[0].astype(jnp.float32)        # [Q, dv]
    rows_valid = _rows_valid(c, chunk, s_valid)
    cum, e, a, w = _chunk_decays(g_ref[0], rows_valid)
    dmat = _intra_decay(cum, chunk)

    P = st_ref[0, 0]                          # state entering this chunk
    D = dstate_ref[...]                       # adjoint of the LEAVING state

    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    dsc = jax.lax.dot_general(dy, v, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32) * dmat

    dq = jax.lax.dot(dsc, k, preferred_element_type=jnp.float32) + \
        e[:, None] * jax.lax.dot_general(dy, P, (((1,), (1,)), ((), ())),
                                         preferred_element_type=jnp.float32)
    dk = jax.lax.dot_general(dsc, q, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32) + \
        w[:, None] * jax.lax.dot_general(v, D, (((1,), (1,)), ((), ())),
                                         preferred_element_type=jnp.float32)
    dv = jax.lax.dot_general(scores * dmat, dy, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32) + \
        w[:, None] * jax.lax.dot(k, D, preferred_element_type=jnp.float32)

    # decay gradient: dL/dG_t = q_t.dq_t - k_t.dk_t, dg = suffix-sum of dG
    # (within-chunk reverse cumsum + the cross-chunk suffix carried in SMEM)
    dG = jnp.where(rows_valid,
                   jnp.sum(q * dq, axis=-1) - jnp.sum(k * dk, axis=-1), 0.0)
    tot = jnp.sum(dG)
    carry = carry_ref[0]
    dg = carry + (tot - jnp.cumsum(dG) + dG)
    carry_ref[0] = carry + tot

    # adjoint state entering this chunk, for the next (earlier) iteration
    dstate_ref[...] = a * D + jax.lax.dot_general(
        q * e[:, None], dy, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    dq_ref[0] = dq.astype(dq_ref.dtype)
    dk_ref[0] = dk.astype(dk_ref.dtype)
    dv_ref[0] = dv.astype(dv_ref.dtype)
    dg_ref[0] = dg.astype(dg_ref.dtype)


def gla_scan_bwd_kernel(q, k, v, g, states, dy, *, chunk: int = 64,
                        s_valid: int = 0, interpret: bool = False):
    """Fused VJP of :func:`gla_scan_kernel` (zero initial state, y output).
    ``states``: the per-chunk entering states checkpointed by the forward.
    Returns (dq, dk, dv, dg) in the input dtypes — one reverse pass."""
    BH, S, dk = q.shape
    dv = v.shape[-1]
    nc = S // chunk

    kernel = functools.partial(_gla_bwd_kernel, chunk=chunk, nc=nc,
                               s_valid=s_valid or S)
    rev = lambda b, r: (b, nc - 1 - r, 0)
    rev_g = lambda b, r: (b, nc - 1 - r)

    return pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), rev),
            pl.BlockSpec((1, chunk, dk), rev),
            pl.BlockSpec((1, chunk, dv), rev),
            pl.BlockSpec((1, chunk), rev_g),
            pl.BlockSpec((1, 1, dk, dv), lambda b, r: (b, nc - 1 - r, 0, 0)),
            pl.BlockSpec((1, chunk, dv), rev),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dk), rev),
            pl.BlockSpec((1, chunk, dk), rev),
            pl.BlockSpec((1, chunk, dv), rev),
            pl.BlockSpec((1, chunk), rev_g),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct(k.shape, k.dtype),
            jax.ShapeDtypeStruct(v.shape, v.dtype),
            jax.ShapeDtypeStruct(g.shape, g.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32),
                        pltpu.SMEM((1,), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, g, states, dy)
