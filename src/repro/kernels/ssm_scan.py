"""Pallas TPU chunked gated-linear-attention scan (Mamba2 SSD / mLSTM core).

Layout: q,k [BH, S, dk]; v [BH, S, dv]; g [BH, S] (log-decay <= 0).
Grid (BH, nchunks) with the chunk axis sequential: the [dk, dv] recurrent
state lives in VMEM scratch and is carried across chunk iterations; within a
chunk the recurrence becomes two MXU contractions plus a masked [Q, Q]
contraction — the state-space-duality form, tiled so the working set
(3 chunk tiles + state + [Q,Q] mask) fits VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams


def _gla_kernel(q_ref, k_ref, v_ref, g_ref, o_ref, state_ref, *, chunk: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    q = q_ref[0].astype(jnp.float32)          # [Q, dk]
    k = k_ref[0].astype(jnp.float32)          # [Q, dk]
    v = v_ref[0].astype(jnp.float32)          # [Q, dv]
    g = g_ref[0].astype(jnp.float32)          # [Q]
    cum = jnp.cumsum(g)                       # inclusive

    # intra-chunk: A_ij = (q_i . k_j) * exp(cum_i - cum_j), j <= i
    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    dmat = jnp.exp(jnp.where(jj <= ii, cum[:, None] - cum[None, :], -jnp.inf))
    y = jax.lax.dot(scores * dmat, v, preferred_element_type=jnp.float32)

    # carried-state contribution and state update
    s0 = state_ref[...]                       # [dk, dv]
    y = y + jax.lax.dot(q * jnp.exp(cum)[:, None], s0,
                        preferred_element_type=jnp.float32)
    decay_to_end = jnp.exp(cum[-1] - cum)     # [Q]
    s_local = jax.lax.dot_general(k * decay_to_end[:, None], v,
                                  (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    state_ref[...] = jnp.exp(cum[-1]) * s0 + s_local
    o_ref[0] = y.astype(o_ref.dtype)


def gla_scan_kernel(q, k, v, g, *, chunk: int = 64, interpret: bool = False):
    """Returns y [BH, S, dv]; S must be a multiple of chunk (ops.py pads)."""
    BH, S, dk = q.shape
    dv = v.shape[-1]
    nc = S // chunk

    kernel = functools.partial(_gla_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, dv), q.dtype),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, g)
