"""xLSTM-350M [arXiv:2405.04517].

24 recurrent blocks, d_model 1024, 4 mLSTM heads, vocab 50304, no separate
FFN (d_ff=0; mLSTM blocks carry the up-projection). sLSTM block every 6th
position (xLSTM[7:1]-style mixed stack).
"""
from repro.configs.base import FAMILY_SSM, ModelConfig, SSMConfig, reduce_config

CONFIG = ModelConfig(
    name="xlstm-350m",
    family=FAMILY_SSM,
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    ssm=SSMConfig(expand=2, head_dim=256, chunk=64, slstm_every=6,
                  mlstm_qk_dim_factor=0.5),
    source="arXiv:2405.04517",
)


def reduced():
    return reduce_config(CONFIG)
