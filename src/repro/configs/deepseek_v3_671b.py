"""DeepSeek-V3 671B [arXiv:2412.19437].

61 layers, d_model 7168, 128 heads (MLA), MoE with 1 shared + 256 routed
experts (top-8, expert d_ff 2048), vocab 129280, multi-token prediction.
First 3 layers use a dense FFN (d_ff 18432).
"""
from repro.configs.base import (ATTN_MLA, FAMILY_MOE, MLAConfig, ModelConfig,
                                MoEConfig, reduce_config)

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family=FAMILY_MOE,
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=18432,                      # dense-FFN layers (first_k_dense)
    vocab_size=129280,
    head_dim=192,                    # qk_nope(128) + qk_rope(64)
    attn_kind=ATTN_MLA,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=256, top_k=8, expert_d_ff=2048,
                  num_shared_experts=1, shared_d_ff=2048,
                  capacity_factor=1.25, first_k_dense=3),
    rope_theta=10000.0,
    mtp=True,
    source="arXiv:2412.19437",
)


def reduced():
    return reduce_config(CONFIG)
