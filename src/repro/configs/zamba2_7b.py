"""Zamba2-7B [arXiv:2411.15242].

81 Mamba2 layers, d_model 3584, ssm_state 64, plus ONE shared attention+MLP
block (32 heads, d_ff 14336) re-applied every 6 Mamba layers with shared
weights.
"""
from repro.configs.base import (FAMILY_HYBRID, HybridConfig, ModelConfig,
                                SSMConfig, reduce_config)

CONFIG = ModelConfig(
    name="zamba2-7b",
    family=FAMILY_HYBRID,
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm=SSMConfig(state_dim=64, conv_dim=4, expand=2, head_dim=64, chunk=64),
    hybrid=HybridConfig(shared_attn_every=6, shared_d_ff=14336),
    source="arXiv:2411.15242",
)


def reduced():
    return reduce_config(CONFIG)
