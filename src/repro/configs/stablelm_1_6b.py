"""StableLM-2 1.6B [hf:stabilityai/stablelm-2-1_6b].

24 layers, d_model 2048, 32 heads (MHA: kv=32), d_ff 5632, vocab 100352,
partial rotary (25% of head dim), LayerNorm.
"""
from repro.configs.base import FAMILY_DENSE, ModelConfig, reduce_config

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family=FAMILY_DENSE,
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    norm="layernorm",
    partial_rotary_factor=0.25,
    source="hf:stabilityai/stablelm-2-1_6b",
)


def reduced():
    return reduce_config(CONFIG)
