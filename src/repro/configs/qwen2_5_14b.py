"""Qwen2.5-14B [hf:Qwen/Qwen2.5-14B].

48 layers, d_model 5120, 40 heads, GQA kv=8, d_ff 13824, vocab 152064,
QKV bias.
"""
from repro.configs.base import FAMILY_DENSE, ModelConfig, reduce_config

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family=FAMILY_DENSE,
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=13824,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1000000.0,
    source="hf:Qwen/Qwen2.5-14B",
)


def reduced():
    return reduce_config(CONFIG)
