"""Architecture registry.

``get_config(arch)`` returns the exact published config; ``get_reduced(arch)``
the smoke-test variant. ``ARCHS`` lists every assigned architecture id.
"""
from __future__ import annotations

import importlib

from repro.configs.base import (INPUT_SHAPES, InputShape, ModelConfig,
                                reduce_config)

# arch-id -> module name
_MODULES = {
    "deepseek-v3-671b": "deepseek_v3_671b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "internvl2-26b": "internvl2_26b",
    "chatglm3-6b": "chatglm3_6b",
    "mixtral-8x22b": "mixtral_8x22b",
    "stablelm-1.6b": "stablelm_1_6b",
    "xlstm-350m": "xlstm_350m",
    "zamba2-7b": "zamba2_7b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "qwen2.5-14b": "qwen2_5_14b",
}

ARCHS = tuple(_MODULES)


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_reduced(arch: str) -> ModelConfig:
    return _module(arch).reduced()


__all__ = ["ARCHS", "INPUT_SHAPES", "InputShape", "ModelConfig",
           "get_config", "get_reduced", "reduce_config"]
