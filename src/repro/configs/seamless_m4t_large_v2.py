"""SeamlessM4T-Large v2 [arXiv:2308.11596] — transformer backbone only.

24 encoder + 24 decoder layers, d_model 1024, 16 heads, d_ff 8192,
vocab 256206 (text unit vocabulary). The speech frontend (mel-spectrogram +
conformer feature extractor) is a stub: ``input_specs`` supplies precomputed
frame embeddings of shape (B, T_frames, frontend_dim).
"""
from repro.configs.base import (FAMILY_ENCDEC, EncDecConfig, ModelConfig,
                                reduce_config)

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family=FAMILY_ENCDEC,
    num_layers=24,                   # decoder layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    norm="layernorm",
    act="gelu",
    encdec=EncDecConfig(encoder_layers=24, frontend_dim=1024,
                        frame_rate_divisor=8),
    source="arXiv:2308.11596",
)


def reduced():
    return reduce_config(CONFIG)
