"""ChatGLM3-6B [arXiv:2406.12793].

28 layers, d_model 4096, 32 heads, multi-query GQA kv=2, d_ff 13696,
vocab 65024. 2D-RoPE applied to half of each head dim
(partial_rotary_factor=0.5), QKV bias.
"""
from repro.configs.base import FAMILY_DENSE, ModelConfig, reduce_config

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family=FAMILY_DENSE,
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    qkv_bias=True,
    partial_rotary_factor=0.5,
    rope_2d=True,
    source="arXiv:2406.12793",
)


def reduced():
    return reduce_config(CONFIG)
