"""Configuration dataclasses for all supported architectures.

Every assigned architecture gets a module in this package exporting a
``CONFIG`` (the exact published numbers, cited) and a ``reduced()`` variant
(same family, <=2 layers, d_model<=512, <=4 experts) used by CPU smoke tests.

``ModelConfig`` is purely architectural plus the per-op kernel backend
choice (``kernels``); the *parallelism* strategy (DP/CDP/ZeRO plans) is
deliberately not a model property — it lives in ``repro.parallel`` and is
selected per run on ``RunSpec``/``TrainerConfig``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

from repro.kernels.registry import KernelSpec

# ---------------------------------------------------------------------------
# Enumerations (plain strings; keeps configs trivially serialisable)
# ---------------------------------------------------------------------------

FAMILY_DENSE = "dense"          # decoder-only transformer
FAMILY_MOE = "moe"              # decoder-only transformer with MoE FFN
FAMILY_SSM = "ssm"              # xLSTM-style recurrent blocks
FAMILY_HYBRID = "hybrid"        # Mamba2 backbone + shared attention block
FAMILY_ENCDEC = "encdec"        # encoder-decoder (audio frontend stub)
FAMILY_VLM = "vlm"              # vision stub + decoder-only LM

ATTN_GQA = "gqa"                # grouped-query attention (MHA if kv==heads)
ATTN_MLA = "mla"                # DeepSeek multi-head latent attention


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_d_ff: int
    num_shared_experts: int = 0
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    # layers [0, first_k_dense) use a dense FFN instead of MoE (DeepSeek-V3
    # keeps the first 3 layers dense).
    first_k_dense: int = 0


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention dims [arXiv:2412.19437]."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 block dims (zamba2) or xLSTM dims (xlstm)."""
    state_dim: int = 64           # N (SSM state per head channel)
    conv_dim: int = 4             # depthwise conv kernel size
    expand: int = 2               # inner dim = expand * d_model
    head_dim: int = 64            # Mamba2 P (channels per SSM head)
    chunk: int = 64               # chunked-scan block length
    # xLSTM specifics
    slstm_every: int = 0          # every k-th block is an sLSTM block (0=never)
    mlstm_qk_dim_factor: float = 0.5


@dataclass(frozen=True)
class HybridConfig:
    """zamba2-style shared attention block interleave."""
    shared_attn_every: int = 6    # apply the shared attn+MLP block every k mamba layers
    shared_d_ff: int = 14336


@dataclass(frozen=True)
class EncDecConfig:
    encoder_layers: int = 24
    # audio frontend stub: pre-computed frame embeddings (B, T_frames, frontend_dim)
    frontend_dim: int = 1024
    frame_rate_divisor: int = 8   # T_frames = seq_len // divisor for dry-run shapes


@dataclass(frozen=True)
class VLMConfig:
    # vision frontend stub: pre-computed patch embeddings (B, num_patches, vision_dim)
    vision_dim: int = 3200        # InternViT-6B hidden size
    num_patches: int = 1025
    projector_hidden: int = 12288


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                     # 0 -> d_model // num_heads
    attn_kind: str = ATTN_GQA
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    partial_rotary_factor: float = 1.0    # fraction of head_dim rotated
    rope_2d: bool = False                 # chatglm-style paired-channel rope
    norm: str = "rmsnorm"                 # rmsnorm | layernorm
    act: str = "silu"                     # silu (swiglu) | gelu
    tie_embeddings: bool = False
    attn_window: int = 0                  # >0 -> sliding-window attention
    max_seq_len: int = 524288
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    mtp: bool = False                     # DeepSeek multi-token prediction head
    # Per-op kernel backend registry (train_attn / prefill_attn / decode_attn
    # / ssm_scan, each "jnp" | "pallas"); None -> derived from the deprecated
    # ``attn_backend`` alias below. See repro.kernels.registry.
    kernels: Optional[KernelSpec] = None
    # DEPRECATED alias (populates train_attn/prefill_attn when ``kernels`` is
    # unset): "jnp" = blockwise online-softmax in pure jnp; "pallas" = fused
    # Pallas TPU flash-attention kernels (fwd AND bwd via custom_vjp),
    # interpreter mode automatically off-TPU.
    attn_backend: str = "jnp"
    dtype: str = "bfloat16"
    # citation for the exact numbers above
    source: str = ""

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads

    @property
    def sub_quadratic(self) -> bool:
        """True if decode state does not grow linearly with context without bound."""
        return self.family in (FAMILY_SSM, FAMILY_HYBRID) or self.attn_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (matches models.count_params on init)."""
        from repro.models.model import analytic_param_count
        return analytic_param_count(self)

    def active_param_count(self) -> int:
        from repro.models.model import analytic_param_count
        return analytic_param_count(self, active_only=True)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def reduce_config(cfg: ModelConfig, *, layers: int = 2, d_model: int = 256,
                  heads: int = 4, vocab: int = 512) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests."""
    kv = max(1, min(cfg.num_kv_heads, heads))
    # keep the head grouping ratio where possible
    if cfg.num_kv_heads < cfg.num_heads:
        kv = max(1, heads // max(1, cfg.num_heads // cfg.num_kv_heads))
    d_ff = d_model * 2 if cfg.d_ff else 0
    kw = dict(
        num_layers=layers, d_model=d_model, num_heads=heads, num_kv_heads=kv,
        d_ff=d_ff, vocab_size=vocab, head_dim=0, max_seq_len=1024,
        name=cfg.name + "-reduced", dtype="float32",
    )
    if cfg.moe is not None:
        kw["moe"] = replace(
            cfg.moe, num_experts=min(4, cfg.moe.num_experts),
            top_k=min(2, cfg.moe.top_k), expert_d_ff=d_model,
            num_shared_experts=min(1, cfg.moe.num_shared_experts),
            shared_d_ff=d_model if cfg.moe.num_shared_experts else 0,
            first_k_dense=min(1, cfg.moe.first_k_dense))
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                              qk_nope_head_dim=32, qk_rope_head_dim=16,
                              v_head_dim=32)
        kw["head_dim"] = 0
    if cfg.ssm is not None:
        kw["ssm"] = replace(cfg.ssm, state_dim=16, head_dim=32, chunk=16,
                            slstm_every=cfg.ssm.slstm_every and 2)
    if cfg.hybrid is not None:
        kw["hybrid"] = replace(cfg.hybrid, shared_attn_every=2, shared_d_ff=d_model * 2)
    if cfg.encdec is not None:
        kw["encdec"] = replace(cfg.encdec, encoder_layers=layers,
                               frontend_dim=d_model, frame_rate_divisor=2)
    if cfg.vlm is not None:
        kw["vlm"] = VLMConfig(vision_dim=d_model, num_patches=16,
                              projector_hidden=d_model * 2)
    if cfg.attn_window:
        kw["attn_window"] = 64
    return cfg.with_(**kw)
