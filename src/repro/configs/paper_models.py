"""Analytic layer profiles for the paper's own models (ResNet-50, ViT-B/16).

The paper's Fig. 4 tracks activation memory of a forward-backward pass of a
ResNet-50 and a ViT-B/16 on ImageNet (input 224x224), removes the parameter
memory, and extrapolates per-worker activation memory for DP vs CDP with
N = 4, 8, 32 workers. We reproduce that with an *analytic* per-module
activation profile (bytes of activations retained per module, fp32) —
equivalent to what the paper measures with fvcore-based partitioning.

Each profile is a list of (module_name, act_bytes, flops) triples in forward
execution order. Stage partitioning follows the paper: split into N stages
with (approximately) equal FLOPs.
"""
from __future__ import annotations

from typing import List, Tuple

Profile = List[Tuple[str, int, int]]

_F32 = 4


def _conv(name, cin, cout, hw, k, stride=1) -> Tuple[str, int, int]:
    out_hw = hw // stride
    act = cout * out_hw * out_hw * _F32          # output retained for bwd
    flops = 2 * cin * cout * k * k * out_hw * out_hw
    return (name, act, flops)


def resnet50_profile(image_hw: int = 224) -> Profile:
    """ResNet-50 v1.5 activation/FLOPs profile per bottleneck block."""
    prof: Profile = []
    prof.append(_conv("stem", 3, 64, image_hw, 7, 2))
    hw = image_hw // 4                            # after stem + maxpool
    cin = 64
    stage_defs = [(64, 3, 1), (128, 4, 2), (256, 6, 2), (512, 3, 2)]
    for si, (width, blocks, stride) in enumerate(stage_defs):
        for b in range(blocks):
            s = stride if b == 0 else 1
            cout = width * 4
            name = f"layer{si+1}.{b}"
            c1 = _conv(name + ".conv1", cin, width, hw, 1)
            c2 = _conv(name + ".conv2", width, width, hw, 3, s)
            hw_b = hw // s
            c3 = _conv(name + ".conv3", width, cout, hw_b, 1)
            prof.extend([c1, c2, c3])
            if b == 0:
                prof.append(_conv(name + ".down", cin, cout, hw, 1, s))
            cin = cout
            hw = hw_b
    prof.append(("head", 1000 * _F32, 2 * 2048 * 1000))
    return prof


def vit_b16_profile(image_hw: int = 224) -> Profile:
    """ViT-B/16: 12 homogeneous encoder blocks, d=768, 12 heads, mlp 3072."""
    d, L, mlp = 768, 12, 3072
    n = (image_hw // 16) ** 2 + 1                # tokens (+cls)
    prof: Profile = [("patch_embed", n * d * _F32, 2 * 3 * 16 * 16 * d * (n - 1))]
    attn_act = (4 * n * d + 2 * 12 * n * n) * _F32   # qkv, attn probs, out
    attn_flops = 2 * n * d * 3 * d + 2 * n * n * d * 2 + 2 * n * d * d
    mlp_act = (n * mlp * 2 + n * d) * _F32
    mlp_flops = 2 * n * d * mlp * 2
    for i in range(L):
        prof.append((f"block{i}.attn", attn_act, attn_flops))
        prof.append((f"block{i}.mlp", mlp_act, mlp_flops))
    prof.append(("head", 1000 * _F32, 2 * d * 1000))
    return prof


def resnet50_param_bytes() -> int:
    return 25_557_032 * _F32


def vit_b16_param_bytes() -> int:
    return 86_567_656 * _F32
