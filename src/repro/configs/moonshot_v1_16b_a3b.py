"""Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B].

48 layers, d_model 2048, 16 heads (kv=16), MoE 64 experts top-6 with expert
d_ff 1408, vocab 163840, 2 shared experts (DeepSeek-style), first layer dense.
"""
from repro.configs.base import (FAMILY_MOE, ModelConfig, MoEConfig,
                                reduce_config)

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family=FAMILY_MOE,
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=11264,                      # dense-FFN first layer
    vocab_size=163840,
    moe=MoEConfig(num_experts=64, top_k=6, expert_d_ff=1408,
                  num_shared_experts=2, shared_d_ff=1408,
                  capacity_factor=1.25, first_k_dense=1),
    source="hf:moonshotai/Moonlight-16B-A3B",
)


def reduced():
    return reduce_config(CONFIG)
