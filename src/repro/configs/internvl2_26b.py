"""InternVL2-26B [arXiv:2404.16821] — language backbone (InternLM2-20B).

48 layers, d_model 6144, 48 heads, GQA kv=8, d_ff 16384, vocab 92553.
The InternViT-6B vision encoder is a stub: ``input_specs`` supplies
precomputed patch embeddings (B, num_patches, vision_dim); a 2-layer MLP
projector maps them into the LM embedding space (that projector IS part of
this model).
"""
from repro.configs.base import FAMILY_VLM, ModelConfig, VLMConfig, reduce_config

CONFIG = ModelConfig(
    name="internvl2-26b",
    family=FAMILY_VLM,
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    rope_theta=1000000.0,
    vlm=VLMConfig(vision_dim=3200, num_patches=1025, projector_hidden=12288),
    source="arXiv:2404.16821",
)


def reduced():
    return reduce_config(CONFIG)
