"""Mixtral-8x22B [arXiv:2401.04088].

56 layers, d_model 6144, 48 heads, GQA kv=8, MoE 8 experts top-2 with expert
d_ff 16384, vocab 32768, sliding-window attention (window 4096 per the
Mixtral paper lineage; the assignment specifies SWA).
"""
from repro.configs.base import (FAMILY_MOE, ModelConfig, MoEConfig,
                                reduce_config)

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family=FAMILY_MOE,
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    attn_window=4096,
    rope_theta=1000000.0,
    moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=16384,
                  capacity_factor=1.25),
    source="arXiv:2401.04088",
)


def reduced():
    return reduce_config(CONFIG)
